"""Benchmark runner: one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (stdout).  Heavy model-level
benches run on reduced configs; the full-size numbers come from the dry-run
artifacts (see EXPERIMENTS.md).

Usage::

    python benchmarks/run.py                       # every section
    python benchmarks/run.py bench_serving         # one section
    python benchmarks/run.py bench_serving --smoke # tiny CI instance
    python benchmarks/run.py --json out.json       # also write rows as JSON

``--smoke`` is forwarded to sections whose ``run()`` accepts it (CI keeps
the serving benchmark from rotting via ``test_bench_serving_smoke``);
``--json`` records the rows as structured data so CI can upload the per-PR
perf trajectory as a workflow artifact.
"""

from __future__ import annotations

import argparse
import inspect
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
os.makedirs(os.path.join(os.path.dirname(__file__), "..", "experiments"),
            exist_ok=True)


def _sections():
    from benchmarks import (bench_alternatives, bench_casestudy,
                            bench_compression, bench_interacting,
                            bench_overhead, bench_roofline, bench_serving,
                            bench_slo, bench_tradeoff)

    mods = (bench_tradeoff, bench_casestudy, bench_alternatives,
            bench_interacting, bench_overhead, bench_compression,
            bench_serving, bench_slo, bench_roofline)
    return {m.__name__.rsplit(".", 1)[-1]: m for m in mods}


def main(argv: list[str] | None = None) -> None:
    sections = _sections()
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("sections", nargs="*", choices=[[], *sections],
                    help="section names to run (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny instance of each section that supports it")
    ap.add_argument("--prefill-mode", default=None,
                    choices=["auto", "bucketed", "packed", "one_shot"],
                    help="restrict serving sections to one engine prefill "
                         "mode (vs the built-in legacy oracle) instead of "
                         "the full mode sweep")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a JSON list to PATH")
    args = ap.parse_args(argv)

    picked = args.sections or list(sections)
    rows: list[str] = []
    print("name,us_per_call,derived")
    for name in picked:
        mod = sections[name]
        params = inspect.signature(mod.run).parameters
        kwargs = {}
        if args.smoke and "smoke" in params:
            kwargs["smoke"] = True
        if args.prefill_mode and "prefill_mode" in params:
            kwargs["prefill_mode"] = args.prefill_mode
        for row in mod.run(**kwargs):
            rows.append(row)
            print(row, flush=True)
    if args.json:
        recs = []
        for row in rows:
            name, us, derived = row.split(",", 2)
            recs.append({"name": name, "us_per_call": float(us),
                         "derived": derived})
        with open(args.json, "w") as f:
            json.dump(recs, f, indent=2)


if __name__ == "__main__":
    main()
