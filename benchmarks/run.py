"""Benchmark runner: one section per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows (stdout).  Heavy model-level
benches run on reduced configs; the full-size numbers come from the dry-run
artifacts (see EXPERIMENTS.md).
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
os.makedirs(os.path.join(os.path.dirname(__file__), "..", "experiments"),
            exist_ok=True)


def main() -> None:
    from benchmarks import (bench_alternatives, bench_casestudy,
                            bench_compression, bench_interacting,
                            bench_overhead, bench_roofline, bench_serving,
                            bench_tradeoff)

    print("name,us_per_call,derived")
    for mod in (bench_tradeoff, bench_casestudy, bench_alternatives,
                bench_interacting, bench_overhead, bench_compression,
                bench_serving, bench_roofline):
        for row in mod.run():
            print(row, flush=True)


if __name__ == "__main__":
    main()
