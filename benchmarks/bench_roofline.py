"""Roofline table (DESIGN.md §9): reads the dry-run artifacts and emits the
three terms + dominant bottleneck + useful-FLOPs ratio per cell."""

from __future__ import annotations

import glob
import json
import os

from .common import fmt_row

ART = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def run(mesh: str = "pod16x16") -> list[str]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, f"{mesh}__*.json"))):
        r = json.load(open(path))
        if not r.get("ok"):
            rows.append(fmt_row(f"roofline_{r['arch']}_{r['shape']}", 0.0,
                                f"FAILED:{r.get('error')}"))
            continue
        t = r["roofline"]
        derived = (f"compute_s={t['compute_s']:.4g};memory_s={t['memory_s']:.4g};"
                   f"collective_s={t['collective_s']:.4g};dom={t['dominant']};"
                   f"useful_ratio={r['useful_flops_ratio'] or 0:.3f};"
                   f"peak_gb={(r['memory'].get('peak_bytes') or 0) / 1e9:.2f}")
        rows.append(fmt_row(f"roofline_{r['arch']}_{r['shape']}", 0.0, derived))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
