"""Paper Figure 5: trade-off performance, SmartConf vs static settings.

For each of the six case studies: SmartConf vs {buggy default, patched
default, random static, hindsight-best static}.  Constraint failures are the
paper's red crosses.  Normalization is to the best static, as in the figure.
"""

from __future__ import annotations

import warnings

import numpy as np

from repro.core import simenv as se
from .common import fmt_row, synthesize, timed_controller_us

warnings.filterwarnings("ignore", category=RuntimeWarning)


def run(seeds=(1, 2, 3)) -> list[str]:
    rows = []
    for name, cls in se.ALL_CASES.items():
        env = cls()
        pol, model, sc = synthesize(env)
        speedups, fails = [], 0
        for seed in seeds:
            tr = env.evaluate(pol, seed=seed)
            bs_val, best = env.best_static(seed=seed)
            speedups.append(tr.total_tradeoff / max(best.total_tradeoff, 1e-9))
            fails += tr.failed
        buggy = env.evaluate(se.StaticPolicy(env.buggy_default), seed=1)
        patched = env.evaluate(se.StaticPolicy(env.patched_default), seed=1)
        rng = np.random.default_rng(0)
        rand_conf = float(rng.choice(env.conf_grid))
        rand = env.evaluate(se.StaticPolicy(rand_conf), seed=1)
        us = timed_controller_us(sc, env.indirect, n=2000)
        derived = (f"speedup_vs_best_static={np.mean(speedups):.3f};"
                   f"sc_fail={fails}/{len(seeds)};"
                   f"buggy_fail={buggy.failed};patched_fail={patched.failed};"
                   f"random_static({rand_conf:.0f})_fail={rand.failed};"
                   f"random_speedup={rand.total_tradeoff / max(env.best_static(seed=1)[1].total_tradeoff, 1e-9):.3f}")
        rows.append(fmt_row(f"fig5_tradeoff_{name}", us, derived))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
