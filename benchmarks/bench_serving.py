"""Serving hot-path benchmark: token-packed vs bucketed vs per-length
prefill, and paged vs. dense KV residency, on a mixed-prompt-length workload.

This is the serving-perf trajectory entry (ROADMAP): the workload substrate
the SmartConf serve controllers are evaluated against.  The prefill sweep
runs with ``serve.prefill_chunk_tokens`` actuated at ``PREFILL_KNOB`` — the
regime the knob exists for — so the three modes expose exactly the deputy
question: legacy ignores the knob (one-shot), bucketed quantizes it
(``bucket x n_slots`` true cost), packed spends it literally (one ragged
stream per tick, chunks from many requests back-to-back).  Rows report:

  * prefill jit-compile count (packed: one stream shape in steady state;
    bucketed: one program per power-of-two bucket; legacy: one per distinct
    prompt length) and total model programs (split paths pay the
    standalone decode program on top; unified packed ticks fuse it),
  * model dispatches per tick (``serving_unified_ticks``): the packed
    engine's unified prefill+decode stream costs exactly ONE compiled
    dispatch per steady-state tick, the split paths up to two — asserted,
    and re-checked from the emitted JSON by the CI bench-smoke job,
  * ``pad_fraction`` — dead padding per issued prefill token; asserted to
    DROP under packing, and (full run) to sit under 5% on the mixed
    workload,
  * TTFT p50/p99 across requests (expect packed <= bucketed on mixed
    lengths: no cross-slot bucket padding),
  * decode throughput (tokens/s over steady-state decode ticks) for the
    paged block-table cache vs. the dense per-slot cache,
  * the ``serve.kv_block_budget`` actuation check: cutting the budget on a
    paged engine must drop ``hbm_bytes`` (the physical block store shrinks,
    preempting sequences), while on a dense engine the same cut only moves
    the logical ledger,
  * mixed-arch rows (``serving_arch_*``): the same mode sweep for the
    families universal chunked prefill unlocked — a recurrent arch (rwkv6),
    a hybrid recurrent/attention arch (recurrentgemma), and a MoE arch
    (deepseek) — each asserted token-identical across every mode.

Reduced config on CPU — the *ratios* (compile count, pad fraction, relative
tokens/s, hbm deltas) are the reproducible signal, not absolute
microseconds.

``--smoke`` (or ``run(smoke=True)``) runs a tiny instance of every section
so CI can keep the benchmark from rotting (see tests/test_paging.py);
``--prefill-mode`` restricts the sweep to one engine mode vs the legacy
oracle.
"""

from __future__ import annotations

import time

import numpy as np

from .common import fmt_row

N_REQUESTS = 24
MAX_NEW = 8
MAX_BATCH = 4
CACHE_LEN = 128
# the actuated serve.prefill_chunk_tokens for the prefill-mode sweep: small
# enough that long prompts span several ticks (chunked serving's raison
# d'etre) and that packed streams stay saturated by the workload
PREFILL_KNOB = 16
SWEEP_MAX_NEW = 4

SMOKE_N_REQUESTS = 5
SMOKE_MAX_BATCH = 2
SMOKE_CACHE_LEN = 64
SMOKE_DECODE_TICKS = 8


def _workload(vocab: int, n_requests: int, seed: int = 7):
    """Mixed lengths: short chat-like, mid, and a long tail."""
    rng = np.random.default_rng(seed)
    lengths = np.concatenate([
        rng.integers(5, 16, n_requests // 3 + 1),
        rng.integers(16, 40, n_requests // 3 + 1),
        rng.integers(40, 56, n_requests // 3 + 1),
    ])[:n_requests]
    rng.shuffle(lengths)
    return [rng.integers(0, vocab, int(n)).astype(np.int32) for n in lengths]


def _run_engine(cfg, params, prompts, mode: str, *, max_batch: int,
                cache_len: int, max_new: int = MAX_NEW,
                prefill_chunk: int | None = None):
    from repro.serve import Request, ServeEngine, ServeOptions

    eng = ServeEngine(cfg, params, options=ServeOptions(
        max_batch=max_batch, cache_len=cache_len,
        enable_smartconf=False, prefill_mode=mode))
    if prefill_chunk is not None and mode != "legacy":
        eng.prefill_chunk = prefill_chunk     # actuate the soft knob
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new))
    t0 = time.perf_counter()
    ticks = 0
    max_segments = max_dispatches = total_dispatches = 0
    while len(eng.finished) < len(prompts) and ticks < 4000:
        stats = eng.tick()
        max_segments = max(max_segments, stats["packed_segments"])
        max_dispatches = max(max_dispatches, stats["dispatches"])
        total_dispatches += stats["dispatches"]
        ticks += 1
    wall = time.perf_counter() - t0
    assert len(eng.finished) == len(prompts), f"{mode}: incomplete"
    ttfts = sorted(r.first_token_t - r.submitted_t for r in eng.finished)
    out = {
        "ticks": ticks,
        "wall_s": wall,
        "prefill_compiles": eng.prefill_compiles,
        "model_programs": eng.model_programs,
        "prefill_calls": eng.prefill_calls,
        "pad_fraction": eng.pad_fraction,
        "max_segments": max_segments,
        "max_dispatches": max_dispatches,
        "dispatches_per_tick": total_dispatches / max(1, ticks),
        "ttft_p50": ttfts[len(ttfts) // 2],
        "ttft_p99": ttfts[min(len(ttfts) - 1, int(len(ttfts) * 0.99))],
        "generated": {r.req_id: list(r.generated) for r in eng.finished},
    }
    eng.close()
    return out


def _decode_throughput(cfg, params, kv_mode: str, *, max_batch: int,
                       cache_len: int, n_ticks: int = 60,
                       prefill_mode: str = "auto"):
    """Steady-state decode tokens/s at full batch occupancy: all slots
    prefill first (outside the timed region), then pure decode ticks are
    timed.  kv_mode isolates the paged block-table gather + kernel against
    the dense per-slot cache on the identical schedule; prefill_mode
    chooses unified (packed: decode segments ride the stream dispatch) vs
    split (bucketed: the standalone decode program) ticks."""
    from repro.serve import Request, ServeEngine, ServeOptions

    eng = ServeEngine(cfg, params, options=ServeOptions(
        max_batch=max_batch, cache_len=cache_len,
        enable_smartconf=False, kv_mode=kv_mode,
        prefill_mode=prefill_mode))
    rng = np.random.default_rng(11)
    for i in range(max_batch):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 16)
                           .astype(np.int32), cache_len - 16))
    ticks = 0
    while len(eng.running) < max_batch and ticks < 50:
        eng.tick()                          # prefill + warm the decode compile
        ticks += 1
    assert len(eng.running) == max_batch, f"{kv_mode}: slots did not fill"
    eng.tick()   # two steady-state ticks outside the timed region, so any
    eng.tick()   # shape compiled only once slots fill never lands inside
    #              the measurement
    t0 = time.perf_counter()
    tokens = sum(eng.tick()["tokens"] for _ in range(n_ticks))
    tok_s = tokens / (time.perf_counter() - t0)
    eng.close()
    return tok_s


def _budget_cut(cfg, params, kv_mode: str, *, max_batch: int, cache_len: int):
    """Fill every slot, then cut ``serve.kv_block_budget`` to one sequence's
    worth.  Returns (hbm_before, hbm_after, preemptions): paged engines
    preempt + physically shrink the block store; dense engines only move the
    logical threshold, so hbm is unchanged."""
    from repro.serve import Request, ServeEngine, ServeOptions

    eng = ServeEngine(cfg, params, options=ServeOptions(
        max_batch=max_batch, cache_len=cache_len,
        enable_smartconf=False, kv_mode=kv_mode))
    rng = np.random.default_rng(13)
    for i in range(max_batch):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 16)
                           .astype(np.int32), cache_len // 2))
    ticks = 0
    while len(eng.running) < max_batch and ticks < 50:
        eng.tick()
        ticks += 1
    assert len(eng.running) == max_batch, f"{kv_mode}: slots did not fill"
    hbm0 = eng.hbm_bytes()
    eng.set_kv_budget(eng.blocks_per_seq)
    eng.tick()
    hbm1 = eng.hbm_bytes()
    preempted = eng.preemptions
    eng.close()
    return hbm0, hbm1, preempted


def _prefix_workload(vocab: int, *, groups: int = 2, followers: int = 2,
                     prefix_len: int = 40, prompt_len: int = 56,
                     seed: int = 17):
    """Shared-prefix tenancy: per group, one leader + ``followers`` prompts
    opening with the same ``prefix_len`` tokens.  ``prefix_len`` is chosen
    OFF the block boundary (40 = 2.5 blocks of 16) so a warm hit lands
    mid-block and the copy-on-write path is genuinely exercised, not just
    whole-block adoption.  Returns (leaders, followers) so the caller can
    warm the cache with the leaders before measuring the followers."""
    rng = np.random.default_rng(seed)
    leaders, follows = [], []
    for _ in range(groups):
        pre = rng.integers(1, vocab, prefix_len).astype(np.int32)
        leaders.append(np.concatenate(
            [pre, rng.integers(1, vocab, prompt_len - prefix_len)
             .astype(np.int32)]))
        for _ in range(followers):
            follows.append(np.concatenate(
                [pre, rng.integers(1, vocab, prompt_len - prefix_len)
                 .astype(np.int32)]))
    return leaders, follows


def _prefix_cache_run(cfg, params, leaders, followers, cached: bool, *,
                      max_batch: int, cache_len: int, max_new: int = 4):
    """Two-phase run: the leaders warm the engine (and, when ``cached``,
    the radix tree), then the followers are served and their prefill cost
    measured in isolation.  Returns per-request tokens + the follower-phase
    issued-prefill-token count and the cache counters."""
    from repro.serve import Request, ServeEngine, ServeOptions

    eng = ServeEngine(cfg, params, options=ServeOptions(
        max_batch=max_batch, cache_len=cache_len, enable_smartconf=False,
        kv_mode="paged", prefix_cache=cached))
    for i, p in enumerate(leaders):
        assert eng.submit(Request(i, p, max_new))
    ticks = 0
    while len(eng.finished) < len(leaders) and ticks < 2000:
        eng.tick()
        ticks += 1
    assert len(eng.finished) == len(leaders), "warmup incomplete"
    issued0 = eng.prefill_issued_tokens
    for j, p in enumerate(followers):
        assert eng.submit(Request(len(leaders) + j, p, max_new))
    while len(eng.finished) < len(leaders) + len(followers) and ticks < 4000:
        eng.tick()
        ticks += 1
    assert len(eng.finished) == len(leaders) + len(followers), \
        "follower phase incomplete"
    out = {
        "generated": {r.req_id: list(r.generated) for r in eng.finished},
        "follower_issued": eng.prefill_issued_tokens - issued0,
        "hit_tokens": eng.prefix_hit_tokens_total,
        "cow_blocks": eng.cow_copied_blocks,
        "hit_rate": (eng._prefix_cache.hit_rate
                     if eng._prefix_cache is not None else 0.0),
        "cache_blocks": (eng._prefix_cache.blocks_held
                         if eng._prefix_cache is not None else 0),
    }
    eng.close()
    return out


def _spec_run(cfg, params, prompts, depth: int, *, max_batch: int,
              cache_len: int, max_new: int):
    """Packed engine at a fixed draft depth; returns per-request tokens
    plus the decode economics: emitted decode tokens per decoding slot
    per dispatch (1.0 exactly at k=0; speculation's win is this ratio)."""
    from repro.serve import Request, ServeEngine, ServeOptions

    eng = ServeEngine(cfg, params, options=ServeOptions(
        max_batch=max_batch, cache_len=cache_len, enable_smartconf=False,
        prefill_mode="packed", spec_depth=depth))
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new))
    t0 = time.perf_counter()
    ticks = dec_tokens = dec_slots = max_dispatches = 0
    while len(eng.finished) < len(prompts) and ticks < 4000:
        st = eng.tick()
        dec_tokens += st["decode_tokens"]
        dec_slots += st["decode_slots"]
        max_dispatches = max(max_dispatches, st["dispatches"])
        ticks += 1
    wall = time.perf_counter() - t0
    assert len(eng.finished) == len(prompts), f"spec k={depth}: incomplete"
    out = {
        "generated": {r.req_id: list(r.generated) for r in eng.finished},
        "ticks": ticks,
        "wall_s": wall,
        "proposed": eng.spec_proposed,
        "accepted": eng.spec_accepted,
        "max_dispatches": max_dispatches,
        "tokens_per_slot_dispatch": dec_tokens / max(1, dec_slots),
    }
    eng.close()
    return out


def _sweep_modes(prefill_mode: str | None) -> list[str]:
    if prefill_mode in (None, "auto"):
        return ["legacy", "bucketed", "packed"]
    if prefill_mode == "one_shot":
        return ["legacy"]
    return ["legacy", prefill_mode]


def run(smoke: bool = False, prefill_mode: str | None = None) -> list[str]:
    import jax
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import zoo

    n_requests = SMOKE_N_REQUESTS if smoke else N_REQUESTS
    max_batch = SMOKE_MAX_BATCH if smoke else MAX_BATCH
    cache_len = SMOKE_CACHE_LEN if smoke else CACHE_LEN
    decode_ticks = SMOKE_DECODE_TICKS if smoke else 60
    modes = _sweep_modes(prefill_mode)

    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = _workload(cfg.vocab_size, n_requests)
    n_lengths = len({len(p) for p in prompts})

    rows = []
    res = {m: _run_engine(cfg, params, prompts, m, max_batch=max_batch,
                          cache_len=cache_len, max_new=SWEEP_MAX_NEW,
                          prefill_chunk=PREFILL_KNOB)
           for m in modes}
    # the fused engines serve from the paged KV cache (kv_mode auto), the
    # legacy engine from the dense per-slot cache: identical tokens is the
    # end-to-end paged/dense parity check — and, for packed, the
    # token-identity bar against the one-shot oracle
    for m in modes[1:]:
        assert res["legacy"]["generated"] == res[m]["generated"], \
            f"{m} engine disagrees with the one-shot oracle on tokens"
    for mode, r in res.items():
        rows.append(fmt_row(
            f"serving_prefill_{mode}", r["wall_s"] / r["ticks"] * 1e6,
            f"compiles={r['prefill_compiles']} "
            f"programs={r['model_programs']} calls={r['prefill_calls']} "
            f"pad_fraction={r['pad_fraction']:.3f} "
            f"dispatches_per_tick={r['dispatches_per_tick']:.2f} "
            f"distinct_lengths={n_lengths}"))
        rows.append(fmt_row(
            f"serving_ttft_{mode}", r["ttft_p50"] * 1e6,
            f"p50_ms={r['ttft_p50']*1e3:.1f} p99_ms={r['ttft_p99']*1e3:.1f}"))
    if "bucketed" in res:
        ratio = res["legacy"]["prefill_compiles"] / max(
            1, res["bucketed"]["prefill_compiles"])
        rows.append(fmt_row(
            "serving_compile_reduction", 0.0,
            f"legacy/bucketed={ratio:.1f}x (goal >=2x)"))
    if "packed" in res and "bucketed" in res:
        b, p = res["bucketed"], res["packed"]
        # deterministic scheduling facts, asserted so CI pins them: packing
        # may never pad more, compile more, or attend fewer requests per
        # call than the bucketed path it replaces
        assert p["prefill_compiles"] <= b["prefill_compiles"], \
            (p["prefill_compiles"], b["prefill_compiles"])
        assert p["pad_fraction"] < b["pad_fraction"], \
            f"packed pad {p['pad_fraction']:.3f} >= " \
            f"bucketed {b['pad_fraction']:.3f}"
        if not smoke:
            assert p["pad_fraction"] < 0.05, \
                f"packed pad_fraction {p['pad_fraction']:.3f} >= 5%"
        rows.append(fmt_row(
            "serving_packed_vs_bucketed", 0.0,
            f"ttft_p50_bucketed/packed="
            f"{b['ttft_p50'] / max(p['ttft_p50'], 1e-9):.2f}x "
            f"pad_bucketed={b['pad_fraction']:.3f} "
            f"pad_packed={p['pad_fraction']:.3f} "
            f"compiles={b['prefill_compiles']}/{p['prefill_compiles']} "
            f"max_segments_per_call={p['max_segments']}"))
        # unified prefill+decode ticks: the packed engine fuses decode into
        # the stream dispatch, so its steady-state tick costs exactly ONE
        # compiled dispatch while the split (bucketed) path pays two when
        # prefill and decode overlap — a deterministic scheduling fact,
        # asserted so CI pins it (.github/workflows/ci.yml re-checks the
        # ordering from the emitted JSON)
        assert p["max_dispatches"] == 1, \
            f"unified tick issued {p['max_dispatches']} dispatches"
        assert b["max_dispatches"] == 2, \
            "split path should overlap prefill + decode on this workload"
        assert p["dispatches_per_tick"] <= b["dispatches_per_tick"], \
            (p["dispatches_per_tick"], b["dispatches_per_tick"])
        assert p["model_programs"] <= b["model_programs"], \
            (p["model_programs"], b["model_programs"])
        rows.append(fmt_row(
            "serving_unified_ticks", 0.0,
            f"dispatches_per_tick_unified={p['dispatches_per_tick']:.2f} "
            f"dispatches_per_tick_split={b['dispatches_per_tick']:.2f} "
            f"max_unified={p['max_dispatches']} "
            f"max_split={b['max_dispatches']} "
            f"programs={p['model_programs']}/{b['model_programs']}"))
        # end-to-end tokens/s on the mixed workload (same requests, same
        # generated token count): the tick where prefill and decode overlap
        # is where unification pays — this is the fused dispatch measured,
        # not the drain tail
        gen_tokens = sum(len(g) for g in p["generated"].values())
        rows.append(fmt_row(
            "serving_e2e_unified_vs_split", 0.0,
            f"unified/split={(gen_tokens / p['wall_s']) / max(gen_tokens / b['wall_s'], 1e-9):.2f}x "
            f"tokens_per_s_unified={gen_tokens / p['wall_s']:.1f} "
            f"tokens_per_s_split={gen_tokens / b['wall_s']:.1f}"))

    tok_s = {m: _decode_throughput(cfg, params, m, max_batch=max_batch,
                                   cache_len=cache_len, n_ticks=decode_ticks)
             for m in ("dense", "paged")}
    for m, t in tok_s.items():
        rows.append(fmt_row(
            f"serving_decode_{m}", 1e6 / max(t, 1e-9),
            f"steady_state_tokens_per_s={t:.1f}"))
    rows.append(fmt_row(
        "serving_decode_paged_vs_dense", 0.0,
        f"paged/dense={tok_s['paged'] / max(tok_s['dense'], 1e-9):.2f}x "
        "(goal >=0.9x)"))
    # drain-phase routing parity: decode-ONLY ticks on the packed engine
    # route to the same specialized decode program the split path runs
    # (engine `_tick_unified` fuses only where prefill and decode overlap),
    # so this ratio is ~1.0 *by construction* — the row pins that routing
    # and would catch it regressing to a mostly-dead stream dispatch.  The
    # fused mixed-tick cost is what `serving_e2e_unified_vs_split` and the
    # dispatches/tick row above measure.
    split_tok = _decode_throughput(cfg, params, "paged",
                                   max_batch=max_batch, cache_len=cache_len,
                                   n_ticks=decode_ticks,
                                   prefill_mode="bucketed")
    rows.append(fmt_row(
        "serving_decode_unified_vs_split", 0.0,
        f"unified/split={tok_s['paged'] / max(split_tok, 1e-9):.2f}x "
        "(decode-only drain ticks share the decode program: parity by "
        "construction, goal >=0.9x)"))

    for m in ("dense", "paged"):
        hbm0, hbm1, pre = _budget_cut(cfg, params, m, max_batch=max_batch,
                                      cache_len=cache_len)
        rows.append(fmt_row(
            f"serving_kv_budget_cut_{m}", 0.0,
            f"hbm_before={hbm0} hbm_after={hbm1} freed={hbm0 - hbm1} "
            f"preempted={pre}"))

    # ---- radix prefix cache: shared-prefix tenancy -----------------------
    # cold (no cache) vs warm (radix tree) on the identical two-phase
    # workload: warm followers must produce bit-identical tokens while
    # issuing >= 30% fewer prefill tokens (the reclaimed-prefill win the
    # cache exists for), with the mid-block prefix forcing real COW copies
    # smoke's 8-block budget fits exactly one cached group next to a live
    # lease + its COW block; the full run exercises multi-group tenancy
    leaders, followers = _prefix_workload(
        cfg.vocab_size, groups=1 if smoke else 2,
        followers=1 if smoke else 2,
        prompt_len=min(56, cache_len - SWEEP_MAX_NEW))
    cold = _prefix_cache_run(cfg, params, leaders, followers, False,
                             max_batch=max_batch, cache_len=cache_len)
    warm = _prefix_cache_run(cfg, params, leaders, followers, True,
                             max_batch=max_batch, cache_len=cache_len)
    assert cold["generated"] == warm["generated"], \
        "prefix-cache hits changed generated tokens"
    assert warm["hit_rate"] > 0.0 and warm["hit_tokens"] > 0, \
        "warm run never hit the cache"
    assert warm["cow_blocks"] > 0, \
        "mid-block prefix should force copy-on-write"
    reduction = 1.0 - warm["follower_issued"] / max(1, cold["follower_issued"])
    assert reduction >= 0.30, \
        f"prefix cache reclaimed only {reduction:.0%} of follower prefill"
    rows.append(fmt_row(
        "serving_prefix_cache", 0.0,
        f"identical=True hit_rate={warm['hit_rate']:.2f} "
        f"reclaimed_tokens={warm['hit_tokens']} "
        f"cow_blocks={warm['cow_blocks']} "
        f"cache_blocks={warm['cache_blocks']} "
        f"issued_cold={cold['follower_issued']} "
        f"issued_warm={warm['follower_issued']} "
        f"prefill_reduction={reduction:.2f} (goal >=0.30)"))

    # ---- self-speculative decode: the repetitive/code-like regime --------
    # crafted markov weights make greedy decode a 12-token cycle, and the
    # prompts lap that cycle, so the n-gram drafter's proposals land: the
    # regime speculation exists for (code, templated text, retrieval fill).
    # Token identity vs the k=0 engine is asserted IN the bench, and the
    # emitted-tokens-per-slot-per-dispatch ratio (exactly 1.0 at k=0) is
    # the JSON-gated headline: every accepted draft is a decode tick the
    # engine never had to run.
    from repro.serve.speculation import markov_params

    cyc = np.arange(1, 13, dtype=np.int32)
    sparams = markov_params(
        cfg, zoo.init(cfg, jax.random.key(0))[0],
        {int(cyc[i]): int(cyc[(i + 1) % 12]) for i in range(12)})
    sprompts = [cyc[(i + np.arange(16 + 2 * i)) % 12]
                for i in range(4 if smoke else 8)]
    spec_new = 12 if smoke else 24
    sbase = _spec_run(cfg, sparams, sprompts, 0, max_batch=max_batch,
                      cache_len=cache_len, max_new=spec_new)
    sres = _spec_run(cfg, sparams, sprompts, 4, max_batch=max_batch,
                     cache_len=cache_len, max_new=spec_new)
    assert sres["generated"] == sbase["generated"], \
        "speculative engine disagrees with k=0 on tokens"
    assert sres["max_dispatches"] == 1, \
        f"speculation broke the unified tick ({sres['max_dispatches']})"
    assert sres["tokens_per_slot_dispatch"] > 1.3, \
        f"accepted tokens/slot/dispatch " \
        f"{sres['tokens_per_slot_dispatch']:.2f} <= 1.3 on the " \
        "repetitive workload"
    assert abs(sbase["tokens_per_slot_dispatch"] - 1.0) < 1e-9
    rows.append(fmt_row(
        "serving_speculative", 0.0,
        f"identical=True "
        f"tokens_per_slot_dispatch={sres['tokens_per_slot_dispatch']:.2f} "
        f"baseline={sbase['tokens_per_slot_dispatch']:.2f} "
        f"accept_rate={sres['accepted'] / max(1, sres['proposed']):.2f} "
        f"accepted={sres['accepted']} proposed={sres['proposed']} "
        f"max_dispatches={sres['max_dispatches']} "
        f"ticks_spec={sres['ticks']} ticks_k0={sbase['ticks']} "
        f"(goal >1.3)"))

    # ---- universal chunked prefill: the newly-unlocked families ----------
    import dataclasses

    mixed = ["rwkv6-7b", "deepseek-moe-16b"]
    if not smoke:
        mixed.append("recurrentgemma-9b")
    for arch in mixed:
        acfg = reduced(get_config(arch))
        if acfg.moe:
            # ample expert capacity -> deterministic routing, so the
            # legacy/bucketed token-identity assertion is exact
            acfg = dataclasses.replace(acfg, capacity_factor=8.0)
        aparams, _ = zoo.init(acfg, jax.random.key(0))
        aprompts = _workload(acfg.vocab_size, n_requests)
        ares = {m: _run_engine(acfg, aparams, aprompts, m,
                               max_batch=max_batch, cache_len=cache_len,
                               max_new=SWEEP_MAX_NEW,
                               prefill_chunk=PREFILL_KNOB)
                for m in modes}
        for m in modes[1:]:
            assert ares["legacy"]["generated"] == ares[m]["generated"], \
                f"{arch}: {m} chunked prefill diverged from one-shot"
        short = arch.split("-")[0]
        for mode, r in ares.items():
            rows.append(fmt_row(
                f"serving_arch_{short}_{mode}",
                r["wall_s"] / r["ticks"] * 1e6,
                f"compiles={r['prefill_compiles']} "
                f"pad_fraction={r['pad_fraction']:.3f} "
                f"ttft_p50_ms={r['ttft_p50']*1e3:.1f} "
                f"ttft_p99_ms={r['ttft_p99']*1e3:.1f}"))
        if "bucketed" in ares:
            rows.append(fmt_row(
                f"serving_arch_{short}_compile_reduction", 0.0,
                f"legacy/bucketed="
                f"{ares['legacy']['prefill_compiles'] / max(1, ares['bucketed']['prefill_compiles']):.1f}x "
                f"ttft_p50_legacy/bucketed="
                f"{ares['legacy']['ttft_p50'] / max(ares['bucketed']['ttft_p50'], 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    for row in run(smoke="--smoke" in sys.argv):
        print(row)
