"""Serving hot-path benchmark: bucketed/chunked prefill vs. per-length
compile, and paged vs. dense KV residency, on a mixed-prompt-length workload.

This is the serving-perf trajectory entry (ROADMAP): the workload substrate
the SmartConf serve controllers are evaluated against.  Rows report:

  * prefill jit-compile count (the bucketed path compiles one program per
    power-of-two bucket; the legacy path one per distinct prompt length),
  * decode throughput (tokens/s over steady-state decode ticks) for the
    paged block-table cache vs. the dense per-slot cache,
  * TTFT p50/p99 across requests,
  * the ``serve.kv_block_budget`` actuation check: cutting the budget on a
    paged engine must drop ``hbm_bytes`` (the physical block store shrinks,
    preempting sequences), while on a dense engine the same cut only moves
    the logical ledger,
  * mixed-arch rows (``serving_arch_*``): the same legacy-vs-bucketed
    comparison for the families universal chunked prefill newly unlocked —
    a recurrent arch (rwkv6), a hybrid recurrent/attention arch
    (recurrentgemma), and a MoE arch (deepseek) — each asserted
    token-identical between the two paths.

Reduced config on CPU — the *ratios* (compile count, relative tokens/s,
hbm deltas) are the reproducible signal, not absolute microseconds.

``--smoke`` (or ``run(smoke=True)``) runs a tiny instance of every section
so CI can keep the benchmark from rotting (see tests/test_paging.py).
"""

from __future__ import annotations

import time

import numpy as np

from .common import fmt_row

N_REQUESTS = 24
MAX_NEW = 8
MAX_BATCH = 4
CACHE_LEN = 128

SMOKE_N_REQUESTS = 5
SMOKE_MAX_BATCH = 2
SMOKE_CACHE_LEN = 64
SMOKE_DECODE_TICKS = 8


def _workload(vocab: int, n_requests: int, seed: int = 7):
    """Mixed lengths: short chat-like, mid, and a long tail."""
    rng = np.random.default_rng(seed)
    lengths = np.concatenate([
        rng.integers(5, 16, n_requests // 3 + 1),
        rng.integers(16, 40, n_requests // 3 + 1),
        rng.integers(40, 56, n_requests // 3 + 1),
    ])[:n_requests]
    rng.shuffle(lengths)
    return [rng.integers(0, vocab, int(n)).astype(np.int32) for n in lengths]


def _run_engine(cfg, params, prompts, mode: str, *, max_batch: int,
                cache_len: int, max_new: int = MAX_NEW):
    from repro.serve import Request, ServeEngine

    eng = ServeEngine(cfg, params, max_batch=max_batch, cache_len=cache_len,
                      enable_smartconf=False, prefill_mode=mode)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new))
    t0 = time.perf_counter()
    ticks = 0
    while len(eng.finished) < len(prompts) and ticks < 4000:
        eng.tick()
        ticks += 1
    wall = time.perf_counter() - t0
    assert len(eng.finished) == len(prompts), f"{mode}: incomplete"
    ttfts = sorted(r.first_token_t - r.submitted_t for r in eng.finished)
    out = {
        "ticks": ticks,
        "wall_s": wall,
        "prefill_compiles": eng.prefill_compiles,
        "prefill_calls": eng.prefill_calls,
        "ttft_p50": ttfts[len(ttfts) // 2],
        "ttft_p99": ttfts[min(len(ttfts) - 1, int(len(ttfts) * 0.99))],
        "generated": {r.req_id: list(r.generated) for r in eng.finished},
    }
    eng.close()
    return out


def _decode_throughput(cfg, params, kv_mode: str, *, max_batch: int,
                       cache_len: int, n_ticks: int = 60):
    """Steady-state decode tokens/s at full batch occupancy: all slots
    prefill first (outside the timed region), then pure decode ticks are
    timed.  kv_mode isolates the paged block-table gather + kernel against
    the dense per-slot cache on the identical schedule."""
    from repro.serve import Request, ServeEngine

    eng = ServeEngine(cfg, params, max_batch=max_batch, cache_len=cache_len,
                      enable_smartconf=False, kv_mode=kv_mode)
    rng = np.random.default_rng(11)
    for i in range(max_batch):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 16)
                           .astype(np.int32), cache_len - 16))
    ticks = 0
    while len(eng.running) < max_batch and ticks < 50:
        eng.tick()                          # prefill + warm the decode compile
        ticks += 1
    assert len(eng.running) == max_batch, f"{kv_mode}: slots did not fill"
    t0 = time.perf_counter()
    tokens = sum(eng.tick()["tokens"] for _ in range(n_ticks))
    tok_s = tokens / (time.perf_counter() - t0)
    eng.close()
    return tok_s


def _budget_cut(cfg, params, kv_mode: str, *, max_batch: int, cache_len: int):
    """Fill every slot, then cut ``serve.kv_block_budget`` to one sequence's
    worth.  Returns (hbm_before, hbm_after, preemptions): paged engines
    preempt + physically shrink the block store; dense engines only move the
    logical threshold, so hbm is unchanged."""
    from repro.serve import Request, ServeEngine

    eng = ServeEngine(cfg, params, max_batch=max_batch, cache_len=cache_len,
                      enable_smartconf=False, kv_mode=kv_mode)
    rng = np.random.default_rng(13)
    for i in range(max_batch):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 16)
                           .astype(np.int32), cache_len // 2))
    ticks = 0
    while len(eng.running) < max_batch and ticks < 50:
        eng.tick()
        ticks += 1
    assert len(eng.running) == max_batch, f"{kv_mode}: slots did not fill"
    hbm0 = eng.hbm_bytes()
    eng.set_kv_budget(eng.blocks_per_seq)
    eng.tick()
    hbm1 = eng.hbm_bytes()
    preempted = eng.preemptions
    eng.close()
    return hbm0, hbm1, preempted


def run(smoke: bool = False) -> list[str]:
    import jax
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import zoo

    n_requests = SMOKE_N_REQUESTS if smoke else N_REQUESTS
    max_batch = SMOKE_MAX_BATCH if smoke else MAX_BATCH
    cache_len = SMOKE_CACHE_LEN if smoke else CACHE_LEN
    max_new = 4 if smoke else MAX_NEW
    decode_ticks = SMOKE_DECODE_TICKS if smoke else 60

    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = _workload(cfg.vocab_size, n_requests)
    n_lengths = len({len(p) for p in prompts})

    rows = []
    res = {m: _run_engine(cfg, params, prompts, m, max_batch=max_batch,
                          cache_len=cache_len, max_new=max_new)
           for m in ("legacy", "bucketed")}
    # the bucketed engine serves from the paged KV cache (kv_mode auto),
    # the legacy engine from the dense per-slot cache: identical tokens is
    # the end-to-end paged/dense parity check
    assert res["legacy"]["generated"] == res["bucketed"]["generated"], \
        "paged (bucketed) and dense (legacy) engines disagree on tokens"
    for mode, r in res.items():
        rows.append(fmt_row(
            f"serving_prefill_{mode}", r["wall_s"] / r["ticks"] * 1e6,
            f"compiles={r['prefill_compiles']} calls={r['prefill_calls']} "
            f"distinct_lengths={n_lengths}"))
        rows.append(fmt_row(
            f"serving_ttft_{mode}", r["ttft_p50"] * 1e6,
            f"p50_ms={r['ttft_p50']*1e3:.1f} p99_ms={r['ttft_p99']*1e3:.1f}"))
    ratio = res["legacy"]["prefill_compiles"] / max(
        1, res["bucketed"]["prefill_compiles"])
    rows.append(fmt_row(
        "serving_compile_reduction", 0.0,
        f"legacy/bucketed={ratio:.1f}x (goal >=2x)"))

    tok_s = {m: _decode_throughput(cfg, params, m, max_batch=max_batch,
                                   cache_len=cache_len, n_ticks=decode_ticks)
             for m in ("dense", "paged")}
    for m, t in tok_s.items():
        rows.append(fmt_row(
            f"serving_decode_{m}", 1e6 / max(t, 1e-9),
            f"steady_state_tokens_per_s={t:.1f}"))
    rows.append(fmt_row(
        "serving_decode_paged_vs_dense", 0.0,
        f"paged/dense={tok_s['paged'] / max(tok_s['dense'], 1e-9):.2f}x "
        "(goal >=0.9x)"))

    for m in ("dense", "paged"):
        hbm0, hbm1, pre = _budget_cut(cfg, params, m, max_batch=max_batch,
                                      cache_len=cache_len)
        rows.append(fmt_row(
            f"serving_kv_budget_cut_{m}", 0.0,
            f"hbm_before={hbm0} hbm_after={hbm1} freed={hbm0 - hbm1} "
            f"preempted={pre}"))

    # ---- universal chunked prefill: the newly-unlocked families ----------
    import dataclasses

    mixed = ["rwkv6-7b", "deepseek-moe-16b"]
    if not smoke:
        mixed.append("recurrentgemma-9b")
    for arch in mixed:
        acfg = reduced(get_config(arch))
        if acfg.moe:
            # ample expert capacity -> deterministic routing, so the
            # legacy/bucketed token-identity assertion is exact
            acfg = dataclasses.replace(acfg, capacity_factor=8.0)
        aparams, _ = zoo.init(acfg, jax.random.key(0))
        aprompts = _workload(acfg.vocab_size, n_requests)
        ares = {m: _run_engine(acfg, aparams, aprompts, m,
                               max_batch=max_batch, cache_len=cache_len,
                               max_new=max_new)
                for m in ("legacy", "bucketed")}
        assert ares["legacy"]["generated"] == ares["bucketed"]["generated"], \
            f"{arch}: bucketed chunked prefill diverged from one-shot"
        short = arch.split("-")[0]
        for mode, r in ares.items():
            rows.append(fmt_row(
                f"serving_arch_{short}_{mode}",
                r["wall_s"] / r["ticks"] * 1e6,
                f"compiles={r['prefill_compiles']} "
                f"ttft_p50_ms={r['ttft_p50']*1e3:.1f} "
                f"ttft_p99_ms={r['ttft_p99']*1e3:.1f}"))
        rows.append(fmt_row(
            f"serving_arch_{short}_compile_reduction", 0.0,
            f"legacy/bucketed="
            f"{ares['legacy']['prefill_compiles'] / max(1, ares['bucketed']['prefill_compiles']):.1f}x "
            f"ttft_p50_legacy/bucketed="
            f"{ares['legacy']['ttft_p50'] / max(ares['bucketed']['ttft_p50'], 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    import sys
    print("name,us_per_call,derived")
    for row in run(smoke="--smoke" in sys.argv):
        print(row)
