"""Serving hot-path benchmark: bucketed/chunked prefill vs. per-length
compile, on a mixed-prompt-length workload.

This is the first entry in the serving-perf trajectory (ROADMAP): the
workload substrate the SmartConf serve controllers are evaluated against.
Rows report, for each prefill mode:

  * prefill jit-compile count (the bucketed path compiles one program per
    power-of-two bucket; the legacy path one per distinct prompt length),
  * decode throughput (tokens/s over all decode ticks),
  * TTFT p50/p99 across requests.

Reduced config on CPU — the *ratios* (compile count, relative tokens/s) are
the reproducible signal, not absolute microseconds.
"""

from __future__ import annotations

import time

import numpy as np

from .common import fmt_row

N_REQUESTS = 24
MAX_NEW = 8
MAX_BATCH = 4
CACHE_LEN = 128


def _workload(vocab: int, seed: int = 7):
    """Mixed lengths: short chat-like, mid, and a long tail."""
    rng = np.random.default_rng(seed)
    lengths = np.concatenate([
        rng.integers(5, 16, N_REQUESTS // 3),
        rng.integers(16, 48, N_REQUESTS // 3),
        rng.integers(48, 100, N_REQUESTS - 2 * (N_REQUESTS // 3)),
    ])
    rng.shuffle(lengths)
    return [rng.integers(0, vocab, int(n)).astype(np.int32) for n in lengths]


def _run_engine(cfg, params, prompts, mode: str):
    from repro.serve import Request, ServeEngine

    eng = ServeEngine(cfg, params, max_batch=MAX_BATCH, cache_len=CACHE_LEN,
                      enable_smartconf=False, prefill_mode=mode)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, MAX_NEW))
    t0 = time.perf_counter()
    ticks = 0
    while len(eng.finished) < len(prompts) and ticks < 4000:
        eng.tick()
        ticks += 1
    wall = time.perf_counter() - t0
    assert len(eng.finished) == len(prompts), f"{mode}: incomplete"
    ttfts = sorted(r.first_token_t - r.submitted_t for r in eng.finished)
    out = {
        "ticks": ticks,
        "wall_s": wall,
        "prefill_compiles": eng.prefill_compiles,
        "prefill_calls": eng.prefill_calls,
        "ttft_p50": ttfts[len(ttfts) // 2],
        "ttft_p99": ttfts[min(len(ttfts) - 1, int(len(ttfts) * 0.99))],
    }
    eng.close()
    return out


def _decode_throughput(cfg, params, mode: str, n_ticks: int = 60):
    """Steady-state decode tokens/s at full batch occupancy: all slots
    prefill first (outside the timed region), then pure decode ticks are
    timed.  The decode step is shared between modes, so this isolates the
    donation + deferred-sync hot path from scheduling composition."""
    from repro.serve import Request, ServeEngine

    eng = ServeEngine(cfg, params, max_batch=MAX_BATCH, cache_len=CACHE_LEN,
                      enable_smartconf=False, prefill_mode=mode)
    rng = np.random.default_rng(11)
    for i in range(MAX_BATCH):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 16)
                           .astype(np.int32), CACHE_LEN - 16))
    eng.tick()                              # prefill + warm the decode compile
    assert len(eng.running) == MAX_BATCH
    t0 = time.perf_counter()
    tokens = sum(eng.tick()["tokens"] for _ in range(n_ticks))
    tok_s = tokens / (time.perf_counter() - t0)
    eng.close()
    return tok_s


def run() -> list[str]:
    import jax
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import zoo

    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = _workload(cfg.vocab_size)
    n_lengths = len({len(p) for p in prompts})

    rows = []
    res = {m: _run_engine(cfg, params, prompts, m)
           for m in ("legacy", "bucketed")}
    for mode, r in res.items():
        rows.append(fmt_row(
            f"serving_prefill_{mode}", r["wall_s"] / r["ticks"] * 1e6,
            f"compiles={r['prefill_compiles']} calls={r['prefill_calls']} "
            f"distinct_lengths={n_lengths}"))
        tok_s = _decode_throughput(cfg, params, mode)
        rows.append(fmt_row(
            f"serving_decode_{mode}", 1e6 / max(tok_s, 1e-9),
            f"steady_state_tokens_per_s={tok_s:.1f}"))
        rows.append(fmt_row(
            f"serving_ttft_{mode}", r["ttft_p50"] * 1e6,
            f"p50_ms={r['ttft_p50']*1e3:.1f} p99_ms={r['ttft_p99']*1e3:.1f}"))
    ratio = res["legacy"]["prefill_compiles"] / max(
        1, res["bucketed"]["prefill_compiles"])
    rows.append(fmt_row(
        "serving_compile_reduction", 0.0,
        f"legacy/bucketed={ratio:.1f}x (goal >=2x)"))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run():
        print(row)
