"""Paper Figure 6: HB3813 time-series case study — memory under control,
queue cap adapting at the workload shift, throughput vs static settings."""

from __future__ import annotations

import warnings

import numpy as np

from repro.core import simenv as se
from .common import fmt_row, synthesize

warnings.filterwarnings("ignore", category=RuntimeWarning)


def run(seed: int = 1) -> list[str]:
    env = se.HB3813()
    pol, model, sc = synthesize(env)
    tr = env.evaluate(pol, seed=seed)
    bs_val, best = env.best_static(seed=seed)
    buggy = env.evaluate(se.StaticPolicy(env.buggy_default), seed=seed)

    ph1 = slice(40, 200)
    ph2 = slice(240, 400)
    derived = (
        f"goal=495MB;vgoal={sc.controller.virtual_goal:.0f}MB;"
        f"mem_ph1_mean={tr.metric[ph1].mean():.0f};"
        f"mem_ph2_mean={tr.metric[ph2].mean():.0f};"
        f"mem_max={tr.metric.max():.0f};violations={tr.violations};"
        f"conf_ph1={tr.conf[ph1].mean():.0f};conf_ph2={tr.conf[ph2].mean():.0f};"
        f"buggy_first_oom_t={buggy.first_violation};"
        f"throughput_vs_best={tr.total_tradeoff / best.total_tradeoff:.3f}"
    )
    # trace dump for plots
    np.savez("experiments/fig6_hb3813_trace.npz",
             t=tr.t, mem=tr.metric, conf=tr.conf, queue=tr.deputy,
             served=tr.tradeoff, goal=tr.goal)
    return [fmt_row("fig6_casestudy_HB3813", 0.0, derived)]


if __name__ == "__main__":
    print("\n".join(run()))
