"""Paper Figure 8 / §6.5: two interacting PerfConfs (request + response
queues) sharing one hard memory constraint.  The workload starts write-heavy
(request queue fills), then a read workload joins at t=50 (response queue
jumps) — SmartConf must rebalance both without ever violating the budget.
"""

from __future__ import annotations

import warnings

import numpy as np

from repro.core import GoalSpec, fit_model
from repro.core import simenv as se
from repro.core.smartconf import ConfRegistry, SmartConfIndirect
from .common import fmt_row

warnings.filterwarnings("ignore", category=RuntimeWarning)

GOAL = GoalSpec(495.0, hard=True, super_hard=True)


class TwoQueueEnv:
    """Request queue (1MB items) + response queue (1.8MB items) in one
    memory budget.  Reads arrive at t>=50 (paper Fig. 8 recipe)."""

    base_mem = 150.0
    svc_req, svc_resp = 60.0, 50.0
    horizon = 400

    def run(self, policies, seed=1):
        rng = np.random.default_rng(seed)
        q1 = q2 = 0.0
        c1 = c2 = 0.0
        trace = {"mem": [], "c1": [], "c2": [], "q1": [], "q2": []}
        viol = 0
        served = 0.0
        for t in range(self.horizon):
            writes = rng.poisson(55.0)
            reads = rng.poisson(45.0) if t >= 50 else 0
            mem = (self.base_mem + 4.0 * rng.standard_normal()
                   + q1 * 1.0 + q2 * 1.8)
            c1 = policies[0](mem, q1, t)
            c2 = policies[1](mem, q2, t)
            # admissions at the new caps
            q1 += min(float(writes), max(0.0, c1 - q1))
            q2 += min(float(reads), max(0.0, c2 - q2))
            mem = (self.base_mem + 4.0 * rng.standard_normal()
                   + q1 * 1.0 + q2 * 1.8)
            viol += mem > GOAL.value
            s1 = min(q1, self.svc_req * (0.4 + 0.6 * min(1, q1 / 200))
                     * (1 + 0.05 * rng.standard_normal()))
            s2 = min(q2, self.svc_resp * (0.4 + 0.6 * min(1, q2 / 200))
                     * (1 + 0.05 * rng.standard_normal()))
            q1 -= max(s1, 0.0)
            q2 -= max(s2, 0.0)
            served += s1 + s2
            for k, v in (("mem", mem), ("c1", c1), ("c2", c2),
                         ("q1", q1), ("q2", q2)):
                trace[k].append(v)
        return viol, served, {k: np.asarray(v) for k, v in trace.items()}


def _profile_alpha(item_mb):
    # profiling: memory vs queue depth slope == item size
    return fit_model([50, 100, 200], [[150 + c * item_mb + d for d in (-8, 0, 8)]
                                      for c in [50, 100, 200]],
                     conf_min=0, conf_max=5000)


def run(seeds=(1, 2, 3)) -> list[str]:
    rows = []
    for seed in seeds:
        registry = ConfRegistry()
        m1, m2 = _profile_alpha(1.0), _profile_alpha(1.8)
        import dataclasses
        m1 = dataclasses.replace(m1, lam=0.06)
        m2 = dataclasses.replace(m2, lam=0.06)
        sc1 = SmartConfIndirect("q1.max", metric="mem", goal=GOAL, initial=0.0,
                                model=m1, registry=registry)
        sc2 = SmartConfIndirect("q2.max", metric="mem", goal=GOAL, initial=0.0,
                                model=m2, registry=registry)
        n_interact = sc1.controller.n_interacting
        pols = [se.SmartConfPolicy(sc1, True), se.SmartConfPolicy(sc2, True)]
        env = TwoQueueEnv()
        viol, served, trace = env.run(pols, seed=seed)
        if seed == seeds[0]:
            np.savez("experiments/fig8_interacting_trace.npz", **trace)
        derived = (f"N_interacting={n_interact};violations={viol};"
                   f"served={served:.0f};"
                   f"q1_preread={trace['q1'][:50].mean():.0f};"
                   f"q1_postread={trace['q1'][60:].mean():.0f};"
                   f"q2_postread={trace['q2'][60:].mean():.0f}")
        rows.append(fmt_row(f"fig8_interacting_seed{seed}", 0.0, derived))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
