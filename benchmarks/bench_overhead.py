"""Paper Table 7: adoption effort + runtime overhead of SmartConf.

LOC: lines of SmartConf-specific integration in this framework's own
subsystems (sensors wiring + API calls), counted from the source the way the
paper counts patch sizes.  Runtime: microseconds per setPerf+getConf pair.

Also the telemetry-overhead gate: the flight recorder's hard constraint is
*off by default, free when off* — a disabled (or absent) Telemetry hub
collapses to ``engine._tel = None``, so the disabled hot path must measure
within 1% of the no-telemetry baseline.  ``telemetry_overhead_rows`` times
the three variants interleaved (min-of-reps, identical workloads) and
asserts the bound; CI re-checks it from the emitted JSON.
"""

from __future__ import annotations

import os
import re
import time

from repro.core import ControllerModel, GoalSpec
from repro.core.smartconf import ConfRegistry, SmartConf, SmartConfIndirect
from .common import fmt_row, timed_controller_us

_SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")

# disabled-mode tick-latency overhead bound (percent) vs. the no-telemetry
# baseline: the CI bench-smoke leg gates on the emitted value
TELEMETRY_DISABLED_MAX_PCT = 1.0


def _overhead_engine(cfg, params, telemetry):
    from repro.core.smartconf import ConfRegistry
    from repro.serve import SLOSpec, ServeEngine
    return ServeEngine(
        cfg, params, max_batch=4, cache_len=64, block_tokens=16,
        enable_smartconf=True, slo=SLOSpec(ttft_s=5.0, window=24),
        registry=ConfRegistry(), telemetry=telemetry)


def _overhead_pass(eng, cfg, reqs: int, ticks_cap: int = 400) -> list[float]:
    """Submit a fixed batch of same-shaped requests and tick the engine to
    drain; returns per-tick wall seconds (GC parked: a collection landing
    in one variant's pass and not another's is the dominant noise source
    when the code paths under test are identical)."""
    import gc

    import numpy as np
    from repro.serve import Request

    rng = np.random.default_rng(7)
    done0 = len(eng.finished)
    for i in range(reqs):
        prompt = rng.integers(1, cfg.vocab_size, size=12, dtype=np.int32)
        eng.submit(Request(req_id=i, prompt=prompt, max_new_tokens=4))
    ticks: list[float] = []
    gc_was_on = gc.isenabled()
    gc.disable()
    try:
        while len(eng.finished) - done0 < reqs and len(ticks) < ticks_cap:
            t0 = time.perf_counter()
            eng.tick()
            ticks.append(time.perf_counter() - t0)
    finally:
        if gc_was_on:
            gc.enable()
    return ticks


def _floor_us_per_tick(passes: list[list[float]]) -> float:
    """Noise-floor estimator over repeated identical passes: the schedule
    is deterministic, so tick index i does the same work in every rep —
    take the min over reps at each position, then average.  Far tighter
    than min-of-pass-averages: one slow tick (timer interrupt, allocator
    stall) only poisons its own position in its own rep."""
    n = min(len(p) for p in passes)
    floors = [min(p[i] for p in passes) for i in range(n)]
    return sum(floors) / max(1, n) * 1e6


def telemetry_overhead_rows(smoke: bool = False) -> list[str]:
    """Time identical serve workloads on three engines — no telemetry,
    telemetry constructed but disabled, telemetry enabled — interleaved,
    min-of-reps (the stable estimator under scheduler noise), and assert
    the disabled variant is within TELEMETRY_DISABLED_MAX_PCT of baseline.
    Meaningful because the disabled path stores ``_tel = None``: it runs
    literally the same code as the baseline."""
    import jax
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.core.telemetry import Telemetry
    from repro.models import zoo

    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    engines = {
        "baseline": _overhead_engine(cfg, params, None),
        "disabled": _overhead_engine(cfg, params, Telemetry(enabled=False)),
        "enabled": _overhead_engine(cfg, params, Telemetry(enabled=True)),
    }
    reqs = 4 if smoke else 8
    reps = 6 if smoke else 10
    for eng in engines.values():        # untimed warm pass: compile + caches
        _overhead_pass(eng, cfg, reqs)
    passes: dict[str, list[list[float]]] = {name: [] for name in engines}
    for _ in range(reps):               # interleave variants across reps
        for name, eng in engines.items():
            passes[name].append(_overhead_pass(eng, cfg, reqs))
    for eng in engines.values():
        eng.close()

    best = {name: _floor_us_per_tick(p) for name, p in passes.items()}
    base = best["baseline"]
    disabled_pct = (best["disabled"] - base) / base * 100.0
    enabled_pct = (best["enabled"] - base) / base * 100.0
    rows = [
        fmt_row("telemetry_overhead_baseline", base, "us_per_tick"),
        fmt_row("telemetry_overhead_disabled", best["disabled"],
                f"disabled_overhead_pct={disabled_pct:.3f} "
                f"bound_pct={TELEMETRY_DISABLED_MAX_PCT}"),
        fmt_row("telemetry_overhead_enabled", best["enabled"],
                f"enabled_overhead_pct={enabled_pct:.3f}"),
    ]
    assert disabled_pct < TELEMETRY_DISABLED_MAX_PCT, (
        f"telemetry-disabled tick latency {best['disabled']:.1f}us is "
        f"{disabled_pct:.2f}% over the {base:.1f}us baseline "
        f"(bound {TELEMETRY_DISABLED_MAX_PCT}%)")
    return rows

_INTEGRATIONS = {
    "serve.max_queue_tokens+kv_budget": ("serve/engine.py",
                                         r"sc_queue|sc_kv|SmartConfIndirect|accountant"),
    "serve.prefill_chunk": ("serve/engine.py", r"sc_chunk"),
    "data.prefetch_depth": ("train/trainer.py", r"sc_prefetch|accountant"),
    "train.ckpt_interval": ("train/trainer.py", r"sc_ckpt|write_seconds"),
}


def _loc(path: str, pattern: str) -> int:
    rx = re.compile(pattern)
    n = 0
    with open(os.path.join(_SRC, path)) as fh:
        for line in fh:
            if rx.search(line):
                n += 1
    return n


def run(smoke: bool = False) -> list[str]:
    rows = []
    for name, (path, pat) in _INTEGRATIONS.items():
        rows.append(fmt_row(f"table7_loc_{name}", 0.0,
                            f"integration_loc={_loc(path, pat)}"))
    # controller runtime cost
    reg = ConfRegistry()
    model = ControllerModel(alpha=1.0, delta=1.3, lam=0.1, conf_max=1e9)
    sc = SmartConf("bench.direct", metric="m", goal=GoalSpec(100.0, hard=True),
                   initial=0.0, model=model, registry=reg)
    us = timed_controller_us(sc, False, n=20000)
    rows.append(fmt_row("table7_runtime_direct", us, "per setPerf+getConf"))
    sci = SmartConfIndirect("bench.indirect", metric="m2",
                            goal=GoalSpec(100.0, hard=True), initial=0.0,
                            model=model, registry=reg)
    us = timed_controller_us(sci, True, n=20000)
    rows.append(fmt_row("table7_runtime_indirect", us, "per setPerf+getConf"))
    # jitted in-graph controller
    import jax
    import jax.numpy as jnp
    from repro.core import jax_controller as jc
    spec = jc.make_spec(model, GoalSpec(100.0, hard=True))
    state = jc.init_state(0.0)
    step = jax.jit(jc.controller_step)
    step(spec, state, jnp.asarray(1.0))  # warm
    import time
    t0 = time.perf_counter()
    n = 2000
    for i in range(n):
        state, _ = step(spec, state, jnp.asarray(float(i % 7)))
    jax.block_until_ready(state.conf)
    rows.append(fmt_row("table7_runtime_jax_controller",
                        (time.perf_counter() - t0) / n * 1e6,
                        "per in-graph step (dispatch-bound on CPU)"))
    rows.extend(telemetry_overhead_rows(smoke=smoke))
    return rows


if __name__ == "__main__":
    print("\n".join(run(smoke=True)))
