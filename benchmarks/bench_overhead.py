"""Paper Table 7: adoption effort + runtime overhead of SmartConf.

LOC: lines of SmartConf-specific integration in this framework's own
subsystems (sensors wiring + API calls), counted from the source the way the
paper counts patch sizes.  Runtime: microseconds per setPerf+getConf pair.
"""

from __future__ import annotations

import os
import re

from repro.core import ControllerModel, GoalSpec
from repro.core.smartconf import ConfRegistry, SmartConf, SmartConfIndirect
from .common import fmt_row, timed_controller_us

_SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")

_INTEGRATIONS = {
    "serve.max_queue_tokens+kv_budget": ("serve/engine.py",
                                         r"sc_queue|sc_kv|SmartConfIndirect|accountant"),
    "serve.prefill_chunk": ("serve/engine.py", r"sc_chunk"),
    "data.prefetch_depth": ("train/trainer.py", r"sc_prefetch|accountant"),
    "train.ckpt_interval": ("train/trainer.py", r"sc_ckpt|write_seconds"),
}


def _loc(path: str, pattern: str) -> int:
    rx = re.compile(pattern)
    n = 0
    with open(os.path.join(_SRC, path)) as fh:
        for line in fh:
            if rx.search(line):
                n += 1
    return n


def run() -> list[str]:
    rows = []
    for name, (path, pat) in _INTEGRATIONS.items():
        rows.append(fmt_row(f"table7_loc_{name}", 0.0,
                            f"integration_loc={_loc(path, pat)}"))
    # controller runtime cost
    reg = ConfRegistry()
    model = ControllerModel(alpha=1.0, delta=1.3, lam=0.1, conf_max=1e9)
    sc = SmartConf("bench.direct", metric="m", goal=GoalSpec(100.0, hard=True),
                   initial=0.0, model=model, registry=reg)
    us = timed_controller_us(sc, False, n=20000)
    rows.append(fmt_row("table7_runtime_direct", us, "per setPerf+getConf"))
    sci = SmartConfIndirect("bench.indirect", metric="m2",
                            goal=GoalSpec(100.0, hard=True), initial=0.0,
                            model=model, registry=reg)
    us = timed_controller_us(sci, True, n=20000)
    rows.append(fmt_row("table7_runtime_indirect", us, "per setPerf+getConf"))
    # jitted in-graph controller
    import jax
    import jax.numpy as jnp
    from repro.core import jax_controller as jc
    spec = jc.make_spec(model, GoalSpec(100.0, hard=True))
    state = jc.init_state(0.0)
    step = jax.jit(jc.controller_step)
    step(spec, state, jnp.asarray(1.0))  # warm
    import time
    t0 = time.perf_counter()
    n = 2000
    for i in range(n):
        state, _ = step(spec, state, jnp.asarray(float(i % 7)))
    jax.block_until_ready(state.conf)
    rows.append(fmt_row("table7_runtime_jax_controller",
                        (time.perf_counter() - t0) / n * 1e6,
                        "per in-graph step (dispatch-bound on CPU)"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
