"""SLO-guarded serving benchmark: open-loop multi-tenant traffic, faults on.

The headline robustness claim of this repo's serving stack: on a trace
whose regime *shifts* — a calm first half every tier can be served inside
the SLO, then a sustained storm where only the premium tier fits capacity —
**with fault injection live** (slow ticks, a mid-run KV budget cut, a NaN
sensor window, one worker preemption), the SmartConf-adaptive engine —
TTFT-actuated graceful brownout via ``serve.admit_tier_max`` — must
deliver strictly more *goodput under SLO* than every static admission
setting, with zero unhandled exceptions.

Every static setting loses one side of the shift, which is the paper's
point about one-size configurations (§2): ``static_open`` (admit
everything) harvests the calm phase but lets the storm build a queue whose
TTFT is blown for every tier including premium; ``static_tight`` (premium
only) rides out the storm but throws away two thirds of the calm-phase
traffic it never admits; ``static_mid`` splits the difference and wins
neither.  The controller rides the shift: gate open while TTFT-p99 holds,
shed the cheapest tiers the moment it crosses the goal, re-open in the
storm's off-burst troughs.

Every engine sees the *same* trace, the same deterministic chaos schedule,
and the same virtual-time cost model; goodput is comparable
token-for-token.  Rows report goodput/throughput (virtual tok/s), SLO
attainment, typed-rejection counts, and guardrail activity.  The
assertions run in ``--smoke`` too — that is the CI chaos-smoke gate.
"""

from __future__ import annotations

import time

from .common import fmt_row

# Virtual-time cost model (seconds per tick / per token) puts engine
# capacity at roughly 20 req/s at the mean output length.  The calm phase
# offers about half that — every tier fits.  The storm phase offers ~2x
# capacity sustained (peaks of ~4x), so an open gate drowns while the
# premium tier alone still fits — the regime shift no static gate can
# match on both sides.
CALM_RPS = 15.0
STORM_RPS = 60.0
STORM_FACTOR = 4.0
STORM_DUTY = 0.5
TTFT_SLO_S = 0.8
HORIZON_S = 12.0
SMOKE_HORIZON_S = 6.0
MAX_BATCH = 4
CACHE_LEN = 64
NUM_TIERS = 3


def _tiers():
    from repro.serve import TierSpec
    return (TierSpec(0, 0.25, deadline_s=6.0),
            TierSpec(1, 0.35, deadline_s=10.0),
            TierSpec(2, 0.40, deadline_s=14.0))


def _make_trace(horizon_s: float):
    """Calm poisson half, then a sustained bursty storm half."""
    from repro.serve import TraceConfig, concat_traces, synthesize_trace
    half = horizon_s / 2.0
    shape = dict(prompt_lo=4, prompt_hi=24, prompt_alpha=1.3,
                 new_lo=2, new_hi=8, new_alpha=1.6, tiers=_tiers())
    calm = TraceConfig(process="poisson", rate_rps=CALM_RPS,
                       horizon_s=half, seed=17, **shape)
    storm = TraceConfig(process="bursty", rate_rps=STORM_RPS,
                        horizon_s=half, t_start=half, seed=23,
                        burst_factor=STORM_FACTOR, burst_period_s=half / 2.0,
                        burst_duty=STORM_DUTY, **shape)
    return concat_traces(synthesize_trace(calm), synthesize_trace(storm))


def _chaos_spec(horizon_s: float):
    from repro.serve import ChaosSpec
    # tick indices assume ~0.03-0.06 virtual s/tick: everything lands well
    # inside the run for both smoke and full horizons
    return ChaosSpec(
        seed=5, slow_tick_prob=0.04, slow_tick_s=0.15,
        budget_cut_tick=30, budget_cut_frac=0.6, budget_restore_tick=60,
        sensor_fault_tick=40, sensor_fault_ticks=10, sensor_fault_mode="nan",
        preempt_tick=20, preempt_resume_ticks=3)


def _run_policy(cfg, params, trace, horizon_s: float, *,
                adaptive: bool, admit_tier_max: int | None = None,
                telemetry_dir: str | None = None) -> dict:
    from repro.core.smartconf import ConfRegistry
    from repro.core.telemetry import Telemetry
    from repro.serve import (ChaosMonkey, OpenLoopDriver, SLOSpec,
                             ServeEngine, ServeOptions, TickCostModel,
                             VirtualClock, as_requests)

    # fresh Request objects per policy: the engine mutates requests
    # in-place (timestamps, generated tokens, slot state), so sharing one
    # arrival list across runs would poison every run after the first.
    # Same trace + same seed -> token-identical workloads.
    arrivals = as_requests(trace, vocab=cfg.vocab_size, seed=1)

    vc = VirtualClock()
    # flight recorder on the virtual clock: trace.json timestamps are
    # virtual microseconds, so the artifact set is deterministic
    tel = Telemetry(enabled=True, clock=vc) if telemetry_dir else None
    eng = ServeEngine(
        cfg, params, options=ServeOptions(
            max_batch=MAX_BATCH, cache_len=CACHE_LEN, block_tokens=16,
            enable_smartconf=adaptive,
            slo=SLOSpec(ttft_s=TTFT_SLO_S, window=24), num_tiers=NUM_TIERS,
            admit_tier_max=admit_tier_max, telemetry=tel),
        registry=ConfRegistry(), clock=vc)
    monkey = ChaosMonkey(_chaos_spec(horizon_s)).install(eng)
    drv = OpenLoopDriver(
        eng, arrivals, clock=vc,
        cost=TickCostModel(base_s=0.02, prefill_token_s=1e-3,
                           decode_token_s=8e-3),
        chaos=monkey, drain_s=max(t.deadline_s or 0.0
                                  for t in _tiers()) + 8.0)
    wall0 = time.perf_counter()
    out = drv.run()
    out["wall_s"] = time.perf_counter() - wall0
    out["chaos_events"] = len(monkey.events)
    out["chaos_schedule"] = list(monkey.events)
    out["sensor_faults"] = sum(
        sc.sensor_faults for sc in
        (eng.sc_queue, eng.sc_kv, eng.sc_chunk, eng.sc_admit, eng.sc_cache,
         eng.sc_spec)
        if sc is not None)
    if tel is not None:
        out["telemetry_paths"] = tel.write(telemetry_dir)
    eng.close()
    return out


# ---- speculation-depth sweep: adaptive vs static k on a shifting trace ---
# The serve.spec_depth analogue of the admission story above.  Crafted
# markov weights make greedy decode a token cycle; the first half of the
# trace sends prompts that lap the cycle FORWARD (the n-gram drafter's
# proposals all land -> deep drafts pay), the second half laps it in
# REVERSE (the drafter stays confident — every emitted token appears in
# the prompt — but its continuations are all wrong, so every verify lane
# is wasted).  Under a virtual-time cost model that charges per verify
# lane, every static depth loses one half: k=0 forgoes the multi-token
# ticks the forward phase offers, deep k burns lanes all through the
# reverse phase.  The accept-rate controller rides the shift — deepen
# while the windowed rate holds above the setpoint, shrink to the floor
# of 1 when it collapses.  Chaos stays ON (same schedule), so the sweep
# also pins speculation's coexistence with preemption, budget cuts and
# NaN sensor windows.
SPEC_CYCLE = 12
SPEC_RATE_RPS = 28.0
SPEC_LANE_S = 8e-3
SPEC_STATIC_DEPTHS = (0, 1, 2, 4, 8)


def _spec_workload(cfg, horizon_s: float):
    """(arrival, Request) pairs whose prompt *content* flips regime at
    half-horizon; lengths/output sizes/tiers still come from the trace."""
    import numpy as np

    from repro.serve import TraceConfig, as_requests, synthesize_trace

    trace = synthesize_trace(TraceConfig(
        process="poisson", rate_rps=SPEC_RATE_RPS, horizon_s=horizon_s,
        seed=29, prompt_lo=16, prompt_hi=24, prompt_alpha=1.3,
        # decode-heavy outputs: the draft-depth clamp is max_new-bounded,
        # so short outputs would collapse every k >= 4 onto the same
        # effective depth and mute the sweep
        new_lo=8, new_hi=16, new_alpha=1.6, tiers=_tiers()))
    cyc = np.arange(1, SPEC_CYCLE + 1, dtype=np.int32)   # token 0 is EOS
    half = horizon_s / 2.0
    arrivals = []
    for t, req in as_requests(trace, vocab=cfg.vocab_size, seed=1):
        idx = np.arange(len(req.prompt))
        a = req.req_id % SPEC_CYCLE
        if t < half:                          # forward laps: drafts land
            req.prompt = cyc[(a + idx) % SPEC_CYCLE]
        else:                                 # reverse laps: drafts never do
            req.prompt = cyc[(a - idx) % SPEC_CYCLE]
        arrivals.append((t, req))
    return arrivals


def _run_spec_policy(cfg, params, horizon_s: float, *, depth: int,
                     adaptive: bool) -> dict:
    from repro.core.smartconf import ConfRegistry
    from repro.serve import (ChaosMonkey, OpenLoopDriver, SLOSpec,
                             ServeEngine, ServeOptions, TickCostModel,
                             VirtualClock)

    vc = VirtualClock()
    eng = ServeEngine(
        cfg, params, options=ServeOptions(
            max_batch=MAX_BATCH, cache_len=CACHE_LEN, block_tokens=16,
            enable_smartconf=True, prefill_mode="packed",
            slo=SLOSpec(ttft_s=TTFT_SLO_S, window=24), num_tiers=NUM_TIERS,
            spec_depth=depth, spec_adaptive=adaptive),
        registry=ConfRegistry(), clock=vc)
    monkey = ChaosMonkey(_chaos_spec(horizon_s)).install(eng)
    drv = OpenLoopDriver(
        eng, _spec_workload(cfg, horizon_s), clock=vc,
        cost=TickCostModel(base_s=0.02, prefill_token_s=1e-3,
                           decode_token_s=8e-3, spec_lane_s=SPEC_LANE_S),
        chaos=monkey, drain_s=max(t.deadline_s or 0.0
                                  for t in _tiers()) + 8.0)
    out = drv.run()
    out["chaos_events"] = len(monkey.events)
    out["proposed"] = eng.spec_proposed
    out["accepted"] = eng.spec_accepted
    out["final_depth"] = eng.spec_depth
    out["sensor_faults"] = sum(
        sc.sensor_faults for sc in
        (eng.sc_queue, eng.sc_kv, eng.sc_chunk, eng.sc_admit, eng.sc_spec)
        if sc is not None)
    eng.close()
    return out


def _spec_rows(cfg, params, horizon_s: float) -> list[str]:
    from repro.serve.speculation import markov_params

    import jax
    import numpy as np

    from repro.models import zoo

    cyc = np.arange(1, SPEC_CYCLE + 1)
    sparams = markov_params(
        cfg, zoo.init(cfg, jax.random.key(0))[0],
        {int(cyc[i]): int(cyc[(i + 1) % SPEC_CYCLE])
         for i in range(SPEC_CYCLE)})
    res = {"adaptive": _run_spec_policy(cfg, sparams, horizon_s,
                                        depth=2, adaptive=True)}
    for k in SPEC_STATIC_DEPTHS:
        res[f"static_k{k}"] = _run_spec_policy(cfg, sparams, horizon_s,
                                               depth=k, adaptive=False)
    rows = []
    for name, r in res.items():
        rows.append(fmt_row(
            f"slo_spec_{name}", 0.0,
            f"goodput_tps={r['goodput_tps']:.2f} "
            f"throughput_tps={r['throughput_tps']:.2f} "
            f"finished={r['finished']} rejected={r['rejected']} "
            f"accepted={r['accepted']} proposed={r['proposed']} "
            f"final_depth={r['final_depth']} "
            f"chaos_events={r['chaos_events']} "
            f"unhandled={len(r['unhandled'])}"))
        assert r["unhandled"] == [], \
            f"slo_spec_{name}: unhandled under chaos: {r['unhandled']}"
    ad = res["adaptive"]
    assert ad["final_depth"] == 1, (
        "the reverse-lap second half should leave the adaptive depth at "
        f"the floor, got {ad['final_depth']}")
    for k in SPEC_STATIC_DEPTHS:
        r = res[f"static_k{k}"]
        assert ad["goodput_tps"] >= r["goodput_tps"], (
            f"adaptive spec goodput {ad['goodput_tps']:.2f} tok/s below "
            f"static k={k} ({r['goodput_tps']:.2f} tok/s)")
    best_k, best = max(((k, res[f"static_k{k}"])
                        for k in SPEC_STATIC_DEPTHS),
                       key=lambda kr: kr[1]["goodput_tps"])
    rows.append(fmt_row(
        "slo_spec_adaptive_vs_best_static", 0.0,
        f"adaptive={ad['goodput_tps']:.2f}tps "
        f"best_static={best['goodput_tps']:.2f}tps(k={best_k}) "
        f"margin={ad['goodput_tps'] / max(best['goodput_tps'], 1e-9):.2f}x"))
    return rows


# ---- replica-router sweep: adaptive weights vs static splits -------------
# The route.replica_weights analogue of the admission story: two
# data-parallel replicas behind one ReplicaRouter, and a *skewed* fault —
# in the storm half replica 1 only gets every third tick (a straggler
# co-tenant), plus a mid-calm preemption of the same replica and a NaN
# window on the router's weight sensor for it.  Weighted-least-loaded
# dispatch equalizes ``backlog / weight``; what goodput-under-SLO needs is
# equalized *delay*, which requires weighting by effective service rate —
# exactly what each replica's TTFT-p99 controller discovers.  Every static
# split loses one side of the shift: ``equal`` keeps half the backlog on a
# replica serving it at a third the rate all storm long, ``favor0``
# overloads replica 0 during the calm half it should be sharing, and
# ``favor1`` leans into the straggler.  The adaptive weights ride it —
# symmetric while both replicas hold the SLO, shed the straggler's weight
# the moment its TTFT-p99 crosses the goal, recover when the stall clears.
ROUTER_CALM_RPS = 36.0
ROUTER_STORM_RPS = 50.0
ROUTER_STALL_PERIOD = 4          # storm: replica 1 runs 1 tick in 4
ROUTER_SPLITS = {"equal": (1.0, 1.0), "favor0": (3.0, 1.0),
                 "favor1": (1.0, 3.0)}


def _router_trace(horizon_s: float):
    from repro.serve import TraceConfig, concat_traces, synthesize_trace
    half = horizon_s / 2.0
    shape = dict(prompt_lo=4, prompt_hi=24, prompt_alpha=1.3,
                 new_lo=2, new_hi=8, new_alpha=1.6, tiers=_tiers())
    calm = TraceConfig(process="poisson", rate_rps=ROUTER_CALM_RPS,
                       horizon_s=half, seed=31, **shape)
    storm = TraceConfig(process="bursty", rate_rps=ROUTER_STORM_RPS,
                        horizon_s=half, t_start=half, seed=37,
                        burst_factor=2.0, burst_period_s=half / 2.0,
                        burst_duty=0.5, **shape)
    return concat_traces(synthesize_trace(calm), synthesize_trace(storm))


def _run_router_policy(cfg, params, trace, horizon_s: float, *,
                       adaptive: bool, weights=None,
                       telemetry_dir: str | None = None) -> dict:
    from repro.core.telemetry import Telemetry
    from repro.serve import (ChaosMonkey, ChaosSpec, OpenLoopDriver,
                             ReplicaRouter, SLOSpec, ServeEngine,
                             ServeOptions, TickCostModel, VirtualClock,
                             as_requests)

    arrivals = as_requests(trace, vocab=cfg.vocab_size, seed=1)
    vc = VirtualClock()
    tel = Telemetry(enabled=True, clock=vc) if telemetry_dir else None
    slo = SLOSpec(ttft_s=TTFT_SLO_S, window=24)
    # engine-level SmartConf off: the four policies differ ONLY in how the
    # router weights the replicas, so the margin is attributable
    engines = [ServeEngine(cfg, params, options=ServeOptions(
        max_batch=MAX_BATCH, cache_len=CACHE_LEN, block_tokens=16,
        enable_smartconf=False, prefill_mode="packed", slo=slo,
        num_tiers=NUM_TIERS), clock=vc) for _ in range(2)]
    half = horizon_s / 2.0

    def stall(tick):
        # the skewed fault: replica 1 is a straggler all storm long
        if vc.now >= half and tick % ROUTER_STALL_PERIOD:
            return 1
        return None

    rt = ReplicaRouter(engines, clock=vc, slo=slo, adaptive=adaptive,
                       weights=weights, telemetry=tel, stall=stall)
    m_eng = ChaosMonkey(ChaosSpec(
        seed=5, slow_tick_prob=0.03, slow_tick_s=0.1,
        preempt_tick=12, preempt_resume_ticks=3)).install(engines[1])
    m_rt = ChaosMonkey(ChaosSpec(
        seed=7, sensor_fault_tick=40, sensor_fault_ticks=10,
        sensor_fault_mode="nan",
        sensor_names=("route.replica1.ttft_p99_s",))).install(rt)
    drv = OpenLoopDriver(
        rt, arrivals, clock=vc,
        cost=TickCostModel(base_s=0.02, prefill_token_s=1e-3,
                           decode_token_s=8e-3),
        chaos=lambda d, t: m_eng(d, t) + m_rt(d, t),
        drain_s=max(t.deadline_s or 0.0 for t in _tiers()) + 8.0)
    out = drv.run()
    out["chaos_events"] = len(m_eng.events) + len(m_rt.events)
    out["sensor_faults"] = rt.sensor_faults
    out["final_weights"] = [round(w, 2) for w in rt.weights]
    out["reroutes"] = rt.reroutes
    out["stalled_ticks"] = rt.stalled_ticks
    if tel is not None:
        out["telemetry_paths"] = tel.write(telemetry_dir)
    rt.close()
    return out


def _router_rows(cfg, params, horizon_s: float) -> list[str]:
    import json
    import os

    tel_dir = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "slo_router_telemetry")
    trace = _router_trace(horizon_s)
    res = {"adaptive": _run_router_policy(cfg, params, trace, horizon_s,
                                          adaptive=True,
                                          telemetry_dir=tel_dir)}
    for name, w in ROUTER_SPLITS.items():
        res[f"static_{name}"] = _run_router_policy(
            cfg, params, trace, horizon_s, adaptive=False, weights=w)

    rows = []
    for name, r in res.items():
        rows.append(fmt_row(
            f"slo_router_{name}", 0.0,
            f"goodput_tps={r['goodput_tps']:.2f} "
            f"throughput_tps={r['throughput_tps']:.2f} "
            f"finished={r['finished']} rejected={r['rejected']} "
            f"reroutes={r['reroutes']} stalled_ticks={r['stalled_ticks']} "
            f"weights={r['final_weights']} "
            f"chaos_events={r['chaos_events']} "
            f"sensor_faults={r['sensor_faults']} "
            f"unhandled={len(r['unhandled'])}"))
        assert r["unhandled"] == [], \
            f"slo_router_{name}: unhandled under chaos: {r['unhandled']}"
        assert r["chaos_events"] > 0, \
            f"slo_router_{name}: chaos schedule never fired"
        assert r["stalled_ticks"] > 0, \
            f"slo_router_{name}: the straggler stall never engaged"
    ad = res["adaptive"]
    assert ad["sensor_faults"] > 0, \
        "router NaN window never reached a weight controller"
    for name in ROUTER_SPLITS:
        r = res[f"static_{name}"]
        assert ad["goodput_tps"] > r["goodput_tps"], (
            f"adaptive router goodput {ad['goodput_tps']:.2f} tok/s not "
            f"above static_{name} ({r['goodput_tps']:.2f} tok/s)")
    # weight Decisions — asserted from the *written* audit trail
    with open(ad["telemetry_paths"]["audit"]) as fh:
        audit = [json.loads(line) for line in fh]
    wdec = [d for d in audit if d["conf"].startswith("route.replica_weights")]
    assert wdec, "no route.replica_weights Decisions in audit.jsonl"
    fallback = [d for d in wdec if d["fallback"]]
    assert fallback, ("router NaN window never engaged last-known-good "
                      "fallback on a weight controller")
    best_name, best = max(
        ((n, r) for n, r in res.items() if n != "adaptive"),
        key=lambda nr: nr[1]["goodput_tps"])
    rows.append(fmt_row(
        "slo_router_adaptive_vs_best_static", 0.0,
        f"adaptive={ad['goodput_tps']:.2f}tps "
        f"best_static={best['goodput_tps']:.2f}tps({best_name}) "
        f"margin={ad['goodput_tps'] / max(best['goodput_tps'], 1e-9):.2f}x "
        f"weight_decisions={len(wdec)} fallback_decisions={len(fallback)}"))
    return rows


# a chaos fault at tick T must have a controller Decision recorded within
# [T, T + window]: decisions land every non-drain tick, and the worker
# preemption drains for preempt_resume_ticks=3 ticks, so 6 covers the
# longest decision-free gap the schedule can create
REACTION_WINDOW_TICKS = 6


def _assert_telemetry(res: dict) -> str:
    """The flight-recorder acceptance gates, asserted from the *written*
    artifacts (not engine internals): every chaos fault is followed by a
    recorded controller Decision inside the reaction window, and the NaN
    sensor window shows fallback_engaged=True in the audit log."""
    import json

    r = res["adaptive"]
    paths = r["telemetry_paths"]
    with open(paths["audit"]) as fh:
        audit = [json.loads(line) for line in fh]
    assert audit, "adaptive chaos run produced an empty audit.jsonl"
    decision_ticks = sorted({d["tick"] for d in audit})
    uncovered = []
    for tick, name in r["chaos_schedule"]:
        if not any(tick <= t <= tick + REACTION_WINDOW_TICKS
                   for t in decision_ticks):
            uncovered.append((tick, name))
    assert not uncovered, (
        f"chaos events with no controller Decision within "
        f"{REACTION_WINDOW_TICKS} ticks: {uncovered}")
    fallback = [d for d in audit
                if d["fallback"] and d["conf"] == "serve.admit_tier_max"]
    assert fallback, (
        "NaN sensor window never showed fallback_engaged=True in the "
        "audit log (guardrails should pin serve.admit_tier_max to "
        "last-known-good)")
    with open(paths["trace"]) as fh:
        trace = json.load(fh)["traceEvents"]
    chaos_marks = [e for e in trace if e["name"].startswith("chaos:")]
    assert chaos_marks, "trace.json carries no chaos instant markers"
    return (f"audit_records={len(audit)} "
            f"chaos_covered={len(r['chaos_schedule'])} "
            f"fallback_decisions={len(fallback)} "
            f"first_fallback_tick={fallback[0]['tick']} "
            f"trace_events={len(trace)}")


def run(smoke: bool = False) -> list[str]:
    import os

    import jax
    from repro.configs import get_config
    from repro.configs.base import reduced
    from repro.models import zoo

    horizon_s = SMOKE_HORIZON_S if smoke else HORIZON_S
    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    trace = _make_trace(horizon_s)

    tel_dir = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "slo_telemetry")
    policies = {
        "adaptive": dict(adaptive=True, telemetry_dir=tel_dir),
        "static_open": dict(adaptive=False, admit_tier_max=NUM_TIERS - 1),
        "static_mid": dict(adaptive=False, admit_tier_max=1),
        "static_tight": dict(adaptive=False, admit_tier_max=0),
    }
    res = {name: _run_policy(cfg, params, trace, horizon_s, **kw)
           for name, kw in policies.items()}

    rows = []
    for name, r in res.items():
        total = max(1, r["slo_good_tokens"] + r["slo_miss_tokens"])
        rows.append(fmt_row(
            f"slo_goodput_{name}", r["wall_s"] / max(1, r["ticks"]) * 1e6,
            f"goodput_tps={r['goodput_tps']:.2f} "
            f"throughput_tps={r['throughput_tps']:.2f} "
            f"slo_attainment={r['slo_good_tokens'] / total:.3f} "
            f"finished={r['finished']} rejected={r['rejected']} "
            f"preemptions={r['preemptions']} "
            f"recompute_tokens={r['recompute_tokens']} "
            f"chaos_events={r['chaos_events']} "
            f"sensor_faults={r['sensor_faults']} "
            f"unhandled={len(r['unhandled'])}"))

    # ---- the gates the CI chaos-smoke leg re-checks from the JSON ----
    for name, r in res.items():
        assert r["unhandled"] == [], \
            f"{name}: unhandled exceptions under chaos: {r['unhandled']}"
        assert r["chaos_events"] > 0, f"{name}: chaos schedule never fired"
    assert res["adaptive"]["sensor_faults"] > 0, \
        "NaN window never reached a guarded controller"
    for name, r in res.items():
        if name == "adaptive":
            continue
        assert res["adaptive"]["goodput_tps"] > r["goodput_tps"], (
            f"adaptive goodput {res['adaptive']['goodput_tps']:.2f} tok/s "
            f"not above {name} ({r['goodput_tps']:.2f} tok/s)")
    best_name, best = max(
        ((n, r) for n, r in res.items() if n != "adaptive"),
        key=lambda nr: nr[1]["goodput_tps"])
    rows.append(fmt_row(
        "slo_adaptive_vs_best_static", 0.0,
        f"adaptive={res['adaptive']['goodput_tps']:.2f}tps "
        f"best_static={best['goodput_tps']:.2f}tps({best_name}) "
        f"margin={res['adaptive']['goodput_tps'] / max(best['goodput_tps'], 1e-9):.2f}x"))

    # ---- speculation-depth sweep (same chaos schedule, markov regime) ----
    rows.extend(_spec_rows(cfg, params, horizon_s))

    # ---- replica-router sweep (skewed straggler chaos, adaptive weights) --
    rows.extend(_router_rows(cfg, params, horizon_s))

    # ---- flight-recorder gates (asserted from the written artifacts) ----
    rows.append(fmt_row("slo_telemetry", 0.0, _assert_telemetry(res)))
    # telemetry must be free when off: re-check the disabled-overhead bound
    # here so the chaos bench carries the whole observability contract
    from .bench_overhead import telemetry_overhead_rows
    rows.extend(telemetry_overhead_rows(smoke=smoke))
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for row in run(smoke=True):
        print(row)
