"""Gradient-compression benchmark (distributed-optimization trick for the
cross-pod axis): int8 block-quantized all-reduce payload vs f32/bf16, plus
quantization error on realistic gradient magnitudes."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.distributed.collectives import dequantize_int8, quantize_int8
from .common import fmt_row


def run() -> list[str]:
    rng = np.random.default_rng(0)
    rows = []
    # realistic grad tree: mixed scales across layers
    tree = {
        "embed": rng.standard_normal((4096, 512)) * 1e-3,
        "attn": rng.standard_normal((512, 512)) * 3e-3,
        "ffn": rng.standard_normal((512, 2048)) * 1e-2,
    }
    total_f32 = sum(v.size * 4 for v in tree.values())
    total_int8 = sum(v.size * 1 + (v.size // 128) * 4 for v in tree.values())
    rel_errs = []
    for v in tree.values():
        x = jnp.asarray(v, jnp.float32)
        q, scale, shape = quantize_int8(x)
        back = dequantize_int8(q, scale, shape)
        rel_errs.append(float(jnp.linalg.norm(back - x) / jnp.linalg.norm(x)))
    rows.append(fmt_row(
        "compression_int8_allreduce", 0.0,
        f"payload_bytes={total_int8}/{total_f32} "
        f"({total_f32 / total_int8:.2f}x_reduction_vs_f32;"
        f"{total_f32 / 2 / total_int8:.2f}x_vs_bf16);"
        f"rel_err_max={max(rel_errs):.2e};"
        f"cross_pod_seconds_saved_per_400B_step="
        f"{(400e9 * 2 - 400e9 * total_int8 / (total_f32 / 4)) / 512 / 50e9:.3f}"))
    return rows
