"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import collections
import time


from repro.core import fit_model
from repro.core import simenv as se
from repro.core.smartconf import ConfRegistry, SmartConf, SmartConfIndirect


def synthesize(env, registry=None, controller_cls=None):
    """Profile -> fit Eq.1 -> SmartConf object + policy (paper §5.5)."""
    samples = env.profile(seed=0)
    grouped = collections.defaultdict(list)
    vals = sorted(set(c for c, _ in samples))
    if len(vals) > 24:
        lo, hi = min(vals), max(vals)
        width = (hi - lo) / 16 or 1.0
        for c, p in samples:
            grouped[lo + (int((c - lo) / width) + 0.5) * width].append(p)
    else:
        for c, p in samples:
            grouped[c].append(p)
    confs = sorted(grouped)
    model = fit_model(confs, [grouped[c] for c in confs],
                      conf_min=env.conf_min, conf_max=env.conf_max,
                      integer=env.integer)
    registry = registry or ConfRegistry()
    cls = SmartConfIndirect if env.indirect else SmartConf
    sc = cls(f"bench.{env.name}", metric=env.metric_name, goal=env.goal,
             initial=env.initial_conf(), model=model, registry=registry)
    if controller_cls is not None:
        sc._controller = controller_cls(model, env.goal,
                                        env.initial_conf())
    return se.SmartConfPolicy(sc, env.indirect), model, sc


def timed_controller_us(sc, indirect: bool, n: int = 5000) -> float:
    t0 = time.perf_counter()
    for i in range(n):
        if indirect:
            sc.set_perf(100.0 + i % 7, 10.0 + i % 5)
        else:
            sc.set_perf(100.0 + i % 7)
        sc.get_conf()
    return (time.perf_counter() - t0) / n * 1e6


def fmt_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.2f},{derived}"
