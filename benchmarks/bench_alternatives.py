"""Paper Figure 7: SmartConf vs alternative controller designs on a less
stable HB3813 workload (70/30 write-read => hotter dynamics):

  * single conservative pole (0.9) + virtual goal  (ThermOS-style)
  * two-pole but NO virtual goal (targets the raw constraint)
"""

from __future__ import annotations

import warnings

import numpy as np

from repro.core import simenv as se
from repro.core.ablations import NoVirtualGoalController, SinglePoleController
from .common import fmt_row, synthesize

warnings.filterwarnings("ignore", category=RuntimeWarning)


class HB3813Hot(se.HB3813):
    """The paper's §6.4 variant: burstier mix destabilizes the queue."""
    name = "HB3813hot"
    calm_rate = 52.0
    burst_rate = 120.0
    burst_prob = 1.0 / 12.0
    burst_len = 12


def _eval(controller_cls, seed=1):
    env = HB3813Hot()
    pol, model, sc = synthesize(env, controller_cls=controller_cls)
    tr = env.evaluate(pol, seed=seed)
    return tr, sc


def run(seeds=(1, 2, 3, 4, 5)) -> list[str]:
    rows = []
    variants = [
        ("smartconf_two_pole", None),
        ("single_pole_0.9", lambda m, g, c0: SinglePoleController(
            m, g, c0, pole=0.9)),
        ("no_virtual_goal", NoVirtualGoalController),
    ]
    for name, cls in variants:
        fails, viols, rewards = 0, 0, []
        first = []
        for seed in seeds:
            tr, sc = _eval(cls, seed)
            fails += tr.failed
            viols += tr.violations
            rewards.append(tr.total_tradeoff)
            if tr.first_violation is not None:
                first.append(tr.first_violation)
        derived = (f"oom_runs={fails}/{len(seeds)};violations={viols};"
                   f"first_oom_t={min(first) if first else 'none'};"
                   f"reward={np.mean(rewards):.0f}")
        rows.append(fmt_row(f"fig7_alt_{name}", 0.0, derived))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
