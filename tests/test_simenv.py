"""The six paper case studies: SmartConf satisfies the constraints the
defaults break (paper §6.2), across seeds."""

import collections
import warnings

import pytest

from repro.core import fit_model
from repro.core import simenv as se
from repro.core.smartconf import ConfRegistry, SmartConf, SmartConfIndirect

warnings.filterwarnings("ignore", category=RuntimeWarning)


def synthesize_policy(env, registry):
    samples = env.profile(seed=0)
    grouped = collections.defaultdict(list)
    vals = sorted(set(c for c, _ in samples))
    if len(vals) > 24:
        lo, hi = min(vals), max(vals)
        width = (hi - lo) / 16 or 1.0
        for c, p in samples:
            grouped[lo + (int((c - lo) / width) + 0.5) * width].append(p)
    else:
        for c, p in samples:
            grouped[c].append(p)
    confs = sorted(grouped)
    model = fit_model(confs, [grouped[c] for c in confs],
                      conf_min=env.conf_min, conf_max=env.conf_max,
                      integer=env.integer)
    cls = SmartConfIndirect if env.indirect else SmartConf
    sc = cls("t", metric=env.metric_name, goal=env.goal,
             initial=env.initial_conf(), model=model, registry=registry)
    return se.SmartConfPolicy(sc, env.indirect), model


@pytest.mark.parametrize("case", list(se.ALL_CASES))
@pytest.mark.parametrize("seed", [1, 2, 3])
def test_smartconf_satisfies_constraint(case, seed):
    env = se.ALL_CASES[case]()
    registry = ConfRegistry()
    pol, model = synthesize_policy(env, registry)
    tr = env.evaluate(pol, seed=seed)
    assert not tr.failed, (f"{case} seed {seed}: violations={tr.violations} "
                           f"first={tr.first_violation}")


@pytest.mark.parametrize("case", list(se.ALL_CASES))
def test_buggy_default_fails(case):
    env = se.ALL_CASES[case]()
    tr = env.evaluate(se.StaticPolicy(env.buggy_default), seed=1)
    assert tr.failed, f"{case}: the reported-buggy default should fail"


@pytest.mark.parametrize("case", ["HB2149", "HB6728", "MR2820"])
def test_patched_default_still_fails(case):
    """Paper §6.2: even patched defaults fail for several issues."""
    env = se.ALL_CASES[case]()
    tr = env.evaluate(se.StaticPolicy(env.patched_default), seed=1)
    assert tr.failed


@pytest.mark.parametrize("case", list(se.ALL_CASES))
def test_smartconf_tradeoff_competitive(case):
    """SmartConf's trade-off metric stays within 10% of the hindsight-best
    static config (and usually beats it)."""
    env = se.ALL_CASES[case]()
    registry = ConfRegistry()
    pol, _ = synthesize_policy(env, registry)
    tr = env.evaluate(pol, seed=1)
    _, best = env.best_static(seed=1)
    assert tr.total_tradeoff >= 0.90 * best.total_tradeoff


def test_goal_change_at_phase2_tracked():
    """HB2149 tightens the latency goal 10s -> 5s mid-run; the controller
    must track the new goal in phase 2."""
    env = se.ALL_CASES["HB2149"]()
    registry = ConfRegistry()
    pol, _ = synthesize_policy(env, registry)
    tr = env.evaluate(pol, seed=1)
    ph2 = tr.metric[260:]
    assert ph2.mean() <= 5.0 * 1.1
