"""ReplicaRouter: weighted-least-loaded dispatch, SmartConf weights, faults.

Three layers of coverage:

* mechanics — dispatch balances replicas, merged tick stats keep the
  frozen schema, all-replicas-down parks work instead of dropping it;
* replica loss — a preemption mid-run drains the dead replica, takes its
  parked requests off both the queue and the ledger, resubmits them to
  the survivor, and rejoins on recovery with ZERO lost requests;
* the control story (the bench's tier-1 anchor) — on a regime-shifting
  trace with a skewed straggler fault, the SmartConf-actuated
  ``route.replica_weights`` strictly beat every static split on
  goodput-under-SLO, the weight Decisions land in the written
  ``audit.jsonl``, and a NaN'd replica sensor engages last-known-good
  fallback instead of poisoning the weights.
"""

import os
import sys

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import zoo
from repro.serve import (Request, ReplicaRouter, ServeEngine, ServeOptions,
                         SLOSpec, TICK_STATS_KEYS, VirtualClock)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    return cfg, params


def _engine(cfg, params, vc, slo=None):
    return ServeEngine(cfg, params, options=ServeOptions(
        max_batch=2, cache_len=64, enable_smartconf=False,
        prefill_mode="packed", slo=slo), clock=vc)


def _reqs(cfg, n, seed=3, plen=12, max_new=4):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(1, cfg.vocab_size, plen).astype(np.int32),
                    max_new) for i in range(n)]


def _drive(rt, vc, want, max_ticks=300):
    t = 0
    while len(rt.finished) < want and t < max_ticks:
        st = rt.tick()
        rt.charge_tick_cost(0.01, decoded=bool(st["decode_tokens"]))
        vc.advance(0.01)
        t += 1
    return t


def test_router_dispatch_and_merged_stats(setup):
    cfg, params = setup
    vc = VirtualClock()
    slo = SLOSpec(ttft_s=0.8)
    rt = ReplicaRouter([_engine(cfg, params, vc, slo) for _ in range(2)],
                       clock=vc, slo=slo, adaptive=True)
    reqs = _reqs(cfg, 6)
    for r in reqs:
        rt.note_arrival(r)
        assert rt.submit(r)
    # weighted-least-loaded must use both replicas for a balanced burst
    assert all(len(e.queued) + len(e.waiting) > 0 for e in rt.engines)
    st = rt.tick()
    assert tuple(st) == TICK_STATS_KEYS     # merged stats keep the schema
    _drive(rt, vc, len(reqs))
    assert len(rt.finished) == len(reqs)
    assert {r.req_id for r in rt.finished} == {r.req_id for r in reqs}
    rt.close()
    rt.close()                              # idempotent


def test_router_weights_frozen_when_static(setup):
    cfg, params = setup
    vc = VirtualClock()
    rt = ReplicaRouter([_engine(cfg, params, vc) for _ in range(2)],
                       clock=vc, adaptive=False, weights=(3.0, 1.0))
    reqs = _reqs(cfg, 4, seed=5)
    for r in reqs:
        rt.note_arrival(r)
        assert rt.submit(r)
    _drive(rt, vc, len(reqs))
    assert rt.weights == [3.0, 1.0]         # nothing actuated them
    assert rt.sensor_faults == 0
    rt.close()


def test_router_preemption_reroutes_without_loss(setup):
    cfg, params = setup
    vc = VirtualClock()
    rt = ReplicaRouter([_engine(cfg, params, vc) for _ in range(2)],
                       clock=vc, adaptive=False)
    reqs = _reqs(cfg, 6, seed=7)
    for r in reqs:
        rt.note_arrival(r)
        assert rt.submit(r)
    for _ in range(2):
        rt.tick(); vc.advance(0.01)
    rt.engines[0].preemption.trigger()
    for _ in range(3):
        rt.tick(); vc.advance(0.01)
    assert 0 in rt._down
    # the dead replica was stripped: queues AND ledger cleared, so a later
    # rejoin cannot double-serve the rerouted work
    assert not rt.engines[0].queued and not rt.engines[0].waiting
    assert rt.reroutes > 0
    rt.engines[0].preemption.reset()
    _drive(rt, vc, len(reqs))
    assert len(rt.finished) == len(reqs)    # zero lost requests
    assert {r.req_id for r in rt.finished} == {r.req_id for r in reqs}
    rt.close()


def test_router_parks_when_every_replica_down(setup):
    cfg, params = setup
    vc = VirtualClock()
    rt = ReplicaRouter([_engine(cfg, params, vc) for _ in range(2)],
                       clock=vc, adaptive=False)
    for eng in rt.engines:
        eng.preemption.trigger()
    rt.tick()
    req = _reqs(cfg, 1, seed=9)[0]
    rt.note_arrival(req)
    assert rt.submit(req)                   # parked, not dropped
    assert req in rt.waiting                # visible to the driver busy check
    rt.tick()
    assert len(rt.finished) == 0
    for eng in rt.engines:
        eng.preemption.reset()
    _drive(rt, vc, 1)
    assert len(rt.finished) == 1            # flushed on rejoin
    rt.close()


def test_router_adaptive_beats_every_static_split(setup, tmp_path):
    """The satellite acceptance gate, same harness as the SLO bench: a
    calm->storm trace, replica 1 a straggler all storm long (1 tick in 4),
    a preemption and a NaN'd router sensor riding along.  The adaptive
    weights must strictly beat every static split on goodput-under-SLO,
    with the Decisions — including the NaN window's last-known-good
    fallback — in the written audit trail."""
    import json

    from benchmarks import bench_slo as B

    cfg, params = setup
    horizon = B.SMOKE_HORIZON_S
    trace = B._router_trace(horizon)
    tel_dir = str(tmp_path / "router_telemetry")
    res = {"adaptive": B._run_router_policy(cfg, params, trace, horizon,
                                            adaptive=True,
                                            telemetry_dir=tel_dir)}
    for name, w in B.ROUTER_SPLITS.items():
        res[f"static_{name}"] = B._run_router_policy(
            cfg, params, trace, horizon, adaptive=False, weights=w)

    for name, r in res.items():
        assert r["unhandled"] == [], f"{name}: {r['unhandled']}"
        assert r["chaos_events"] > 0, name
        assert r["stalled_ticks"] > 0, name
    ad = res["adaptive"]
    for name in B.ROUTER_SPLITS:
        r = res[f"static_{name}"]
        assert ad["goodput_tps"] > r["goodput_tps"], (
            f"adaptive {ad['goodput_tps']:.2f} tok/s not above "
            f"static_{name} ({r['goodput_tps']:.2f} tok/s)")
    # the NaN window hit the weight controller's guardrails...
    assert ad["sensor_faults"] > 0
    # ...and the whole control trail is in the written artifact
    with open(ad["telemetry_paths"]["audit"]) as fh:
        audit = [json.loads(line) for line in fh]
    wdec = [d for d in audit
            if d["conf"].startswith("route.replica_weights")]
    assert wdec, "no route.replica_weights Decisions in audit.jsonl"
    assert any(d["fallback"] for d in wdec), \
        "NaN window never engaged last-known-good fallback"
    assert any(not d["sane"] for d in wdec), \
        "the insane NaN readings never appeared in the audit trail"
