"""Multi-device behaviour (8 fake CPU devices, in a subprocess so the main
test process stays single-device): sharded collectives, coordinated
controllers over a mesh axis, mini dry-run, elastic checkpoint reshard."""

import os
import subprocess
import sys


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))
sys.path.insert(0, "SRCPATH")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_map  # version-compat alias

assert len(jax.devices()) == 8

# ---- 1. compressed gradient all-reduce over a mesh axis -------------------
from repro.distributed.collectives import compressed_psum_grads
mesh = jax.make_mesh((8,), ("data",))
grads = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8) / 7.0}

def f(g):
    return compressed_psum_grads(g, "data")

out = jax.jit(shard_map(f, mesh=mesh, in_specs=({"w": P("data", None)},),
                            out_specs={"w": P("data", None)}))(grads)
# mean over the axis of identical shards... each shard holds a distinct row
# block; psum-mean of distinct contributions: compare against exact mean
def exact(g):
    return jax.tree.map(lambda x: jax.lax.pmean(x, "data"), g)
ref = jax.jit(shard_map(exact, mesh=mesh, in_specs=({"w": P("data", None)},),
                            out_specs={"w": P("data", None)}))(grads)
err = float(jnp.max(jnp.abs(out["w"] - ref["w"])))
rng_scale = float(jnp.max(jnp.abs(ref["w"]))) + 1e-9
assert err / rng_scale < 0.02, f"compressed allreduce err {err}"
print("compressed_psum OK", err)

# ---- 2. sequence-parallel decode combine ----------------------------------
from repro.kernels.decode_attention import decode_attention_ref
rng = np.random.default_rng(0)
B, H, KV, S, D = 2, 4, 2, 64, 16
q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
kpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
qpos = jnp.full((B,), S - 1, jnp.int32)
full = decode_attention_ref(q, k, v, kpos, qpos)

from repro.distributed.collectives import sp_decode_combine
mesh2 = jax.make_mesh((8,), ("model",))

def sp_decode(q, k, v, kpos, qpos):
    # each shard sees S/8 of the cache; partial (o, m, l) then combine
    kk = jnp.repeat(k, H // KV, axis=1)
    vv = jnp.repeat(v, H // KV, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q, kk) * (D ** -0.5)
    valid = (kpos >= 0) & (kpos <= qpos[:, None])
    s = jnp.where(valid[:, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.where(valid[:, None, :], jnp.exp(s - m[..., None]), 0.0)
    lsum = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhs,bhsd->bhd", p, vv)
    return sp_decode_combine(o, m, lsum, "model")

got = jax.jit(shard_map(
    sp_decode, mesh=mesh2,
    in_specs=(P(), P(None, None, "model", None), P(None, None, "model", None),
              P(None, "model"), P()),
    out_specs=P()))(q, k, v, kpos, qpos)
err = float(jnp.max(jnp.abs(got - full)))
assert err < 1e-5, f"sp decode err {err}"
print("sp_decode_combine OK", err)

# ---- 3. coordinated controllers over a mesh axis ---------------------------
from repro.core import ControllerModel, GoalSpec
from repro.core import jax_controller as jc
model = ControllerModel(alpha=1.0, delta=1.0, conf_max=1e9, integer=False)
specs = jc.stack_specs([jc.make_spec(model, GoalSpec(100.0, super_hard=True),
                                     metric_id=0) for _ in range(8)])
states = jc.ControllerState(conf=jnp.zeros(8))
step = jc.sharded_coordinated_step(mesh2, "model")
_, confs = jax.jit(step)(specs, states, jnp.full((8,), 60.0))
vg = float(specs.virtual_goal[0])
expect = (vg - 60.0) / 8.0
assert abs(float(confs[0]) - expect) < 1e-4, (float(confs[0]), expect)
print("sharded coordination OK")

# ---- 4. mini dry-run on a (2,2) and (2,2,2) mesh ---------------------------
import dataclasses
from repro.launch.dryrun import lower_cell
from repro.configs import get_config
from repro.configs.base import ShapeConfig, reduced
cfg = reduced(get_config("yi-6b"))
cfg = dataclasses.replace(cfg, d_model=64, num_heads=4, num_kv_heads=2,
                          vocab_size=512)
shape = ShapeConfig("mini", 64, 8, "train")
mesh_s = jax.make_mesh((2, 2), ("data", "model"))
lowered, _, _ = lower_cell("yi-6b", "mini", multi_pod=False, mesh=mesh_s,
                           shape=shape, cfg=cfg)
lowered.compile()
mesh_m = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
lowered, _, _ = lower_cell("yi-6b", "mini", multi_pod=True, mesh=mesh_m,
                           shape=shape, cfg=cfg)
compiled = lowered.compile()
assert compiled is not None
shape_d = ShapeConfig("mini_dec", 64, 8, "decode")
lowered, _, _ = lower_cell("yi-6b", "mini_dec", multi_pod=True, mesh=mesh_m,
                           shape=shape_d, cfg=cfg)
lowered.compile()
print("mini dry-run OK (train+decode, single+multi pod)")

# ---- 5. elastic checkpoint reshard -----------------------------------------
import tempfile
from jax.sharding import NamedSharding
from repro.checkpoint import restore, save
with tempfile.TemporaryDirectory() as td:
    x = jnp.arange(32, dtype=jnp.float32).reshape(8, 4)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))   # 8-way
    save(td, 1, {"x": xs})
    mesh_b = jax.make_mesh((2,), ("data",), devices=jax.devices()[:2])
    tgt = NamedSharding(mesh_b, P("data", None))                   # 2-way
    got, _, _ = restore(td, None, {"x": jax.ShapeDtypeStruct(x.shape, x.dtype)},
                        shardings={"x": tgt})
    assert got["x"].sharding == tgt
    np.testing.assert_array_equal(np.asarray(got["x"]), np.asarray(x))
print("elastic reshard OK")
print("ALL-MULTIDEVICE-OK")
"""


def test_multidevice_suite(tmp_path):
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    script = _SCRIPT.replace("SRCPATH", os.path.abspath(src))
    path = tmp_path / "md.py"
    path.write_text(script)
    proc = subprocess.run([sys.executable, str(path)], capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "ALL-MULTIDEVICE-OK" in proc.stdout
