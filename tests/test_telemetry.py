"""Flight recorder: metrics/tracer/flight/audit units, engine integration,
determinism (byte-identical artifacts under VirtualClock), the TickStats
schema freeze, and the profiler's metrics emission."""

import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.telemetry import (Decision, DecisionLog, FlightRecorder,
                                  Histogram, MetricsRegistry, Telemetry,
                                  Tracer)
from repro.models import zoo
from repro.serve import (OpenLoopDriver, Request, SLOSpec, ServeEngine,
                         TICK_STATS_KEYS, TickCostModel, TraceConfig,
                         VirtualClock, as_requests, synthesize_trace)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    return cfg, params


def _req(rng, cfg, rid, plen=12, new=4, **kw):
    return Request(rid, rng.integers(1, cfg.vocab_size, plen)
                   .astype(np.int32), new, **kw)


# ------------------------------------------------------------- metrics unit

def test_histogram_quantiles_and_nonfinite_skip():
    h = Histogram("h", buckets=(0.1, 0.2, 0.4, 0.8))
    for v in (0.05, 0.05, 0.15, 0.3, 0.3, 0.3, 0.5, 0.7, 2.0):
        h.record(v)
    h.record(float("nan"))
    h.record(float("inf"))
    assert h.count == 9          # non-finite never poisons stats
    assert h.p50() == 0.4        # rank 4.5 lands in the (0.2, 0.4] bucket
    assert h.p99() == h._max == 2.0   # overflow bucket reads back max
    assert h.p50() <= h.p90() <= h.p99()
    snap = h.snapshot()
    assert snap["count"] == 9 and snap["min"] == 0.05
    assert sum(snap["counts"]) == 9
    assert Histogram("e", buckets=(1.0,)).p99() == 0.0   # empty -> 0


def test_metrics_registry_get_or_create_and_write(tmp_path):
    m = MetricsRegistry()
    m.counter("a").inc()
    m.counter("a").inc(2)
    m.gauge("g").set(3.5)
    m.histogram("h").record(0.01)
    assert m.counter("a").value == 3
    path = str(tmp_path / "metrics.json")
    m.write(path)
    snap = json.load(open(path))
    assert snap["counters"]["a"] == 3
    assert snap["gauges"]["g"] == 3.5
    assert snap["histograms"]["h"]["count"] == 1


# -------------------------------------------------------------- tracer unit

def test_tracer_tick_spans_and_schema():
    t = [0.0]
    trc = Tracer(clock=lambda: t[0])
    trc.begin_tick(0)
    for name in ("admit", "pack", "dispatch"):
        trc.phase(name)
    t[0] = 0.03
    trc.end_tick(args={"tokens": 5})
    trc.instant("chaos:slow_tick", tid=Tracer.TID_CHAOS)
    trc.async_begin("request", 7, args={"tier": 0})
    trc.async_end("request", 7)
    evs = trc.events
    spans = [e for e in evs if e["ph"] == "X"]
    assert [e["name"] for e in spans] == ["tick 0", "admit", "pack",
                                         "dispatch"]
    tick = spans[0]
    assert tick["dur"] == 30_000          # 0.03s of virtual time, in us
    # phases tile the tick span exactly: ordering is the ground truth
    assert sum(e["dur"] for e in spans[1:]) == tick["dur"]
    assert spans[1]["ts"] == tick["ts"]
    for e in evs:                          # trace-event required fields
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] != "M":
            assert isinstance(e["ts"], int)
        if e["ph"] == "X":
            assert isinstance(e["dur"], int) and e["dur"] >= 1
        if e["ph"] in ("b", "e"):
            assert "id" in e and "cat" in e
    doc = trc.to_json()
    assert doc["traceEvents"] and doc["otherData"]["dropped_events"] == 0
    json.dumps(doc)                        # strictly serializable


def test_tracer_bounded_drops_counted():
    trc = Tracer(clock=lambda: 0.0, max_events=5)
    for i in range(10):
        trc.instant(f"e{i}")
    assert len(trc.events) == 5
    assert trc.dropped == 8                # 3 metadata events pre-fill the ring


# ----------------------------------------------------- flight recorder unit

def test_flight_recorder_ring_and_dedupe():
    fr = FlightRecorder(window=4, max_dumps=2)
    for tick in range(10):
        fr.record(tick, {"s": (float(tick), float(tick))})
    assert [r["tick"] for r in fr._ring] == [6, 7, 8, 9]
    assert fr.dump("storm", 9) is True
    assert fr.dump("storm", 10) is False       # same reason inside window
    assert fr.dump("storm", 9 + 4) is True     # window elapsed
    assert fr.dump("other", 20) is False       # max_dumps reached
    assert fr.dropped_dumps == 1
    snap = fr.snapshot()
    assert len(snap["dumps"]) == 2
    assert snap["dumps"][0]["ring"][-1]["tick"] == 9


def test_flight_recorder_sanitizes_nonfinite(tmp_path):
    fr = FlightRecorder()
    fr.record(0, {"ttft_p99_s": (float("nan"), float("inf"))})
    fr.dump("chaos:sensor_nan", 0)
    path = str(tmp_path / "flight.json")
    fr.write(path)
    snap = json.load(open(path))               # strict JSON round-trips
    assert snap["dumps"][0]["ring"][0]["ttft_p99_s"] == ["nan", "inf"]


# --------------------------------------------------------------- audit unit

def _decision(**kw):
    base = dict(tick=0, conf="c", metric="m", goal=1.0, sensor=0.5,
                deputy=None, sane=True, error=0.5, raw=2.0, applied=1.5,
                clamped=True, fallback=False)
    base.update(kw)
    return Decision(**base)


def test_decision_log_query_bound_and_jsonl(tmp_path):
    log = DecisionLog(max_records=3)
    for i in range(5):
        log.tick = i
        log.append(_decision(tick=log.tick, fallback=i >= 3))
    assert len(log.records) == 3 and log.dropped == 2
    assert [d.tick for d in log.query(fallback=True)] == [3, 4]
    log.append(_decision(tick=9, sensor=float("nan")))
    path = str(tmp_path / "audit.jsonl")
    log.write_jsonl(path)
    lines = [json.loads(x) for x in open(path)]
    assert len(lines) == 3
    assert lines[-1]["sensor"] == "nan"        # strict-JSON sanitized


def test_smartconf_audit_records_fallback_and_clamp():
    from repro.core import ControllerModel, GoalSpec
    from repro.core.smartconf import ConfRegistry, Guardrails, SmartConf
    log = DecisionLog()
    sc = SmartConf(
        "t.knob", metric="lat", goal=GoalSpec(1.0, hard=True), initial=4.0,
        model=ControllerModel(alpha=1.0, delta=1.3, lam=0.1, conf_max=100.0),
        guardrails=Guardrails(perf_lo=0.0, perf_hi=10.0, fault_tolerance=2,
                              max_step=0.5),
        registry=ConfRegistry())
    sc.attach_audit(log)
    log.tick = 1
    sc.set_perf(5.0)
    v1 = sc.get_conf()
    d = log.records[-1]
    assert (d.conf, d.metric, d.tick) == ("t.knob", "lat", 1)
    assert d.sane and not d.fallback
    assert d.applied == v1
    # slew guard: a large error makes |raw - applied| exceed max_step
    if d.clamped:
        assert abs(d.raw - d.applied) > 0.0
    # NaN window: fault_tolerance=2 consecutive insane readings pin the conf
    log.tick = 2
    sc.set_perf(float("nan"))
    sc.get_conf()
    assert not log.records[-1].sane
    log.tick = 3
    sc.set_perf(float("nan"))
    pinned = sc.get_conf()
    d = log.records[-1]
    assert d.fallback and not d.sane
    assert d.applied == pinned
    assert log.query(fallback=True, tick=3)


def test_smartconf_indirect_audit_carries_deputy():
    from repro.core import ControllerModel, GoalSpec
    from repro.core.smartconf import ConfRegistry, SmartConfIndirect
    log = DecisionLog()
    sci = SmartConfIndirect(
        "t.ind", metric="hbm", goal=GoalSpec(100.0, hard=True), initial=8.0,
        model=ControllerModel(alpha=1.0, delta=1.3, lam=0.1, conf_max=1e6),
        registry=ConfRegistry())
    sci.attach_audit(log)
    log.tick = 4
    sci.set_perf(50.0, 7.0)
    sci.get_conf()
    d = log.records[-1]
    assert d.deputy == 7.0 and d.sensor == 50.0 and d.tick == 4


# -------------------------------------------------------- engine integration

def test_disabled_telemetry_is_absent_from_engine(small_model):
    cfg, params = small_model
    for tel in (None, Telemetry(enabled=False), Telemetry.disabled()):
        eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                          enable_smartconf=False, telemetry=tel)
        assert eng._tel is None            # disabled path IS the baseline path
        eng.tick()
        eng.close()


def test_repro_telemetry_env_force_enables(small_model, monkeypatch):
    cfg, params = small_model
    monkeypatch.setenv("REPRO_TELEMETRY", "1")
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      enable_smartconf=False)
    assert eng._tel is not None and eng._tel.enabled
    eng.close()
    monkeypatch.setenv("REPRO_TELEMETRY", "0")
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      enable_smartconf=False)
    assert eng._tel is None
    eng.close()


def test_tick_stats_schema_frozen(small_model):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      enable_smartconf=False)
    # TICK_STATS_KEYS is the documented contract: keys AND their order.
    # Growing it is fine (append + update the tuple); renames/removals
    # break downstream consumers of tick()'s return value.
    assert tuple(eng._stats(0)) == TICK_STATS_KEYS
    stats = eng.tick()
    assert tuple(stats) == TICK_STATS_KEYS
    assert stats["tick"] == 0 and eng.ticks_run == 1
    eng.close()


def test_engine_emits_spans_counters_and_readings(small_model, rng):
    cfg, params = small_model
    weights = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                  for x in jax.tree.leaves(params))
    tel = Telemetry(enabled=True)
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      block_tokens=16, enable_smartconf=True,
                      hbm_budget_bytes=weights + 2_000_000,
                      slo=SLOSpec(ttft_s=5.0, window=8), telemetry=tel)
    assert eng.submit(_req(rng, cfg, 0))
    assert not eng.submit(_req(rng, cfg, 1, plen=0))           # typed reject
    ticks = 0
    while len(eng.finished) < 1 and ticks < 50:
        eng.tick()
        ticks += 1
    assert len(eng.finished) == 1
    names = {e["name"] for e in tel.tracer.events}
    assert "tick 0" in names
    assert {"control", "admit", "schedule", "finish"} <= names
    assert "dispatch" in names             # at least one dispatching tick
    snap = tel.metrics.snapshot()
    assert snap["counters"]["serve.ticks"] == ticks
    assert snap["counters"]["serve.reject.empty_prompt"] == 1
    assert snap["histograms"]["serve.ttft_s"]["count"] == 1
    # every tick recorded its sensor stream into the flight ring
    assert tel.flight._ring and "ttft_p99_s" in tel.flight._ring[-1]
    # smartconf engine wrote audit decisions for the serve confs
    confs = {d.conf for d in tel.audit.records}
    assert {"serve.admit_tier_max", "serve.kv_block_budget",
            "serve.max_queue_tokens"} <= confs
    # request lifetime closed out as an async end (finish or rejection)
    ends = [e for e in tel.tracer.events if e["ph"] == "e"]
    assert {e["id"] for e in ends} == {0, 1}
    eng.close()


def test_chaos_note_marks_trace_and_dumps_flight(small_model):
    cfg, params = small_model
    tel = Telemetry(enabled=True)
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      enable_smartconf=False, telemetry=tel)
    eng.note_chaos("sensor_nan:ttft_p99_s")
    eng.note_chaos("sensor_nan:decode_p99_s")   # same family: deduped
    marks = [e for e in tel.tracer.events
             if e["ph"] == "i" and e["name"].startswith("chaos:")]
    assert len(marks) == 2 and marks[0]["tid"] == Tracer.TID_CHAOS
    assert tel.metrics.counter("chaos.sensor_nan").value == 2
    assert [d["reason"] for d in tel.flight.dumps] == ["chaos:sensor_nan"]
    eng.close()


# ------------------------------------------------------------- determinism

def _driven_run(cfg, params, tmp_dir):
    vc = VirtualClock()
    tel = Telemetry(enabled=True, clock=vc)
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      block_tokens=16, enable_smartconf=True,
                      slo=SLOSpec(ttft_s=0.5, window=8), num_tiers=2,
                      clock=vc, telemetry=tel)
    trace = synthesize_trace(TraceConfig(
        process="poisson", rate_rps=20.0, horizon_s=2.0, seed=11,
        prompt_lo=4, prompt_hi=16, new_lo=2, new_hi=6))
    drv = OpenLoopDriver(
        eng, as_requests(trace, vocab=cfg.vocab_size, seed=3), clock=vc,
        cost=TickCostModel(base_s=0.02, prefill_token_s=1e-3,
                           decode_token_s=8e-3))
    out = drv.run()
    assert out["unhandled"] == []
    paths = tel.write(tmp_dir)
    eng.close()
    return paths


def test_telemetry_deterministic_under_virtual_clock(small_model, tmp_path):
    cfg, params = small_model
    paths_a = _driven_run(cfg, params, str(tmp_path / "a"))
    paths_b = _driven_run(cfg, params, str(tmp_path / "b"))
    audit_a = open(paths_a["audit"], "rb").read()
    assert audit_a and audit_a == open(paths_b["audit"], "rb").read()
    assert open(paths_a["trace"], "rb").read() == \
        open(paths_b["trace"], "rb").read()
    assert open(paths_a["flight"], "rb").read() == \
        open(paths_b["flight"], "rb").read()
    # virtual timestamps: the span sequence is identical, and every complete
    # event in the written artifact satisfies the trace-event schema
    doc = json.load(open(paths_a["trace"]))
    for e in doc["traceEvents"]:
        assert {"name", "ph", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 1 and e["ts"] >= 0
    assert doc["otherData"]["dropped_events"] == 0
    # the audit log is replayable: decisions arrive in tick order
    ticks = [json.loads(x)["tick"] for x in open(paths_a["audit"])]
    assert ticks == sorted(ticks)


# ------------------------------------------------------------ profiler ties

def test_write_sysfile_never_leaves_tmp_on_failure(tmp_path):
    from repro.core.profiler import write_sysfile
    sys_dir = str(tmp_path)
    write_sysfile(sys_dir, "ok.conf", {"x": 1})
    with pytest.raises(TypeError):
        write_sysfile(sys_dir, "bad.conf", {"x": object()})  # not serializable
    leftovers = [f for f in os.listdir(sys_dir) if f.startswith(".")]
    assert leftovers == [], f"tmp files leaked: {leftovers}"
    assert sorted(os.listdir(sys_dir)) == ["ok.conf.smartconf.sys"]


def test_profile_buffer_emits_flush_metrics(tmp_path):
    from repro.core.profiler import ProfileBuffer
    m = MetricsRegistry()
    buf = ProfileBuffer(str(tmp_path), "t.knob", flush_every=4, metrics=m)
    for i in range(9):
        buf.record(float(i % 3), float(i))
    buf.flush()
    assert len(buf.samples) == 9
    assert m.counter("profiler.t.knob.samples").value == 9
    assert m.counter("profiler.t.knob.flushes").value == 3   # 4 + 4 + 1
