"""Hypothesis property tests on system invariants: ring KV caches, the KV
block pool ledger, and the prefetch queue accounting."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.serve.kv_cache import KVBlockPool


@given(st.integers(min_value=1, max_value=80),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_ring_cache_holds_last_window(n_tokens, seed):
    """After writing positions 0..n-1 one step at a time, a ring cache of
    size W contains exactly the last min(n, W) positions."""
    cfg = reduced(get_config("h2o-danube-3-4b"))  # swa kind
    W = 16
    cache = {
        "k": jnp.zeros((1, W, cfg.num_kv_heads, cfg.resolved_head_dim)),
        "v": jnp.zeros((1, W, cfg.num_kv_heads, cfg.resolved_head_dim)),
        "pos": jnp.full((1, W), -1, jnp.int32),
    }
    for pos in range(n_tokens):
        slot = pos % W
        cache["pos"] = cache["pos"].at[:, slot].set(pos)
    got = sorted(int(p) for p in np.asarray(cache["pos"][0]) if p >= 0)
    want = list(range(max(0, n_tokens - W), n_tokens))
    assert got == want


@given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 200)),
                min_size=1, max_size=40))
@settings(max_examples=50, deadline=None)
def test_kv_pool_ledger_invariants(ops):
    """Random ensure/free sequences: used_blocks == sum of live seq blocks,
    never exceeds budget, bytes ledger consistent."""
    cfg = reduced(get_config("yi-6b"))
    pool = KVBlockPool(cfg, block_tokens=16, max_blocks=24)
    live = {}
    for seq_id, tokens in ops:
        if seq_id in live and tokens % 3 == 0:
            pool.free(seq_id)
            live.pop(seq_id)
            continue
        need = (tokens + 15) // 16
        prev = live.get(seq_id, 0)
        want = max(prev, need)
        ok = pool.ensure(seq_id, tokens)
        if ok:
            live[seq_id] = want
        assert pool.used_blocks == sum(live.values())
        assert pool.used_blocks <= pool.max_blocks
        assert pool.used_bytes == pool.used_blocks * pool.block_bytes
    for s in list(live):
        pool.free(s)
    assert pool.used_blocks == 0


@given(st.integers(min_value=1, max_value=6),
       st.integers(min_value=1, max_value=12))
@settings(max_examples=10, deadline=None)
def test_prefetch_accounting_balances(depth, n_batches):
    """Every get() credits exactly what the producer charged."""
    from repro.core.sensors import HBMAccountant
    from repro.data import PrefetchPipeline, SyntheticTokens

    acct = HBMAccountant()
    pipe = PrefetchPipeline(SyntheticTokens(100, 2, 8), depth=depth,
                            accountant=acct)
    for _ in range(n_batches):
        pipe.get(timeout=10.0)
    pipe.close()
    # whatever remains charged equals what is still buffered
    assert acct.breakdown().get("prefetch", 0) == pipe.buffered_bytes()
