"""Self-speculative decode on the packed stream: drafter determinism,
engine-level token identity against the k=0 oracle for every text arch
(dense AND paged, including mid-draft rejection with KV truncation and
slot reuse afterward), the prefix-cache poison regression, SmartConf
depth actuation, and chaos survival with speculation live.

The acceptance rule makes token identity hold *by construction* — a
drafted token is kept iff it equals the model's own argmax — so every
parity test here is a test of the KV bookkeeping around rejection, not
of the drafter's quality.  ``OracleDrafter`` replays a reference
continuation with deterministic corruption, pinning the accept/reject
schedule independent of model content; ``markov_params`` builds the
full-accept regime through real weights."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.serve import (ChaosMonkey, ChaosSpec, Request, ServeEngine,
                         ServeOptions)
from repro.serve.speculation import NGramDrafter, markov_params
from repro.models import zoo

TEXT_ARCHS = [a for a in ARCH_IDS if a not in ("whisper-tiny",
                                               "internvl2-1b")]
PROMPT_LENS = (5, 19, 33)
MAX_NEW = 6


@pytest.fixture(autouse=True)
def _no_spec_env(monkeypatch):
    # the CI spec leg exports REPRO_SPEC_DEPTH for the whole suite; this
    # file builds its own k=0 baselines, which must stay genuinely k=0
    monkeypatch.delenv("REPRO_SPEC_DEPTH", raising=False)


def _smoke_cfg(arch_id):
    cfg = reduced(get_config(arch_id))
    if cfg.moe:   # ample capacity -> deterministic routing for equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


class OracleDrafter:
    """Replays a reference continuation as the draft, corrupting one
    token of every ``corrupt_every``-th proposal so mid-draft rejection
    (partial accept + KV overwrite of the rejected suffix) is exercised
    on a deterministic schedule.  Correct drafts are accepted in full;
    corrupted ones are rejected exactly at the corrupted offset."""

    def __init__(self, refs, vocab, corrupt_every=0):
        self.refs = {r: [int(t) for t in toks] for r, toks in refs.items()}
        self.vocab = int(vocab)
        self.corrupt_every = corrupt_every
        self.calls = 0
        self._rid = {}
        self._emitted = {}

    def begin(self, slot, req):
        self._rid[slot] = req.req_id
        self._emitted[slot] = 0

    def extend(self, slot, toks):
        if slot in self._emitted:
            self._emitted[slot] += int(np.asarray(toks).size)

    def drop(self, slot):
        self._rid.pop(slot, None)
        self._emitted.pop(slot, None)

    def propose(self, slot, k):
        rid = self._rid.get(slot)
        if rid is None or k <= 0:
            return np.zeros(0, np.int32)
        e = self._emitted[slot]
        d = np.asarray(self.refs.get(rid, [])[e:e + k], np.int32)
        self.calls += 1
        if (self.corrupt_every and d.size
                and self.calls % self.corrupt_every == 0):
            d = d.copy()
            j = d.size // 2
            d[j] = (int(d[j]) + 1) % self.vocab   # != the model's argmax
        return d


def _run(cfg, params, prompts, *, spec_depth=0, drafter=None,
         kv_mode="auto", max_batch=2, cache_len=96, max_new=MAX_NEW, **kw):
    eng = ServeEngine(cfg, params, max_batch=max_batch, cache_len=cache_len,
                      enable_smartconf=False, prefill_mode="packed",
                      kv_mode=kv_mode, spec_depth=spec_depth, **kw)
    if drafter is not None:
        eng._drafter = drafter
    for i, p in enumerate(prompts):
        eng.submit(Request(i, np.asarray(p, np.int32), max_new))
    ticks = max_dispatches = 0
    while len(eng.finished) < len(prompts) and ticks < 400:
        st = eng.tick()
        ticks += 1
        max_dispatches = max(max_dispatches, st["dispatches"])
    assert len(eng.finished) == len(prompts), cfg.name
    outs = {r.req_id: list(r.generated) for r in eng.finished}
    stats = dict(max_dispatches=max_dispatches, ticks=ticks,
                 proposed=eng.spec_proposed, accepted=eng.spec_accepted,
                 paged=eng.paged)
    eng.close()
    return outs, stats


# -------------------------------------------------------------- drafter unit

def test_ngram_drafter_deterministic_longest_suffix():
    hist = np.asarray([7, 1, 2, 3, 9, 1, 2], np.int32)
    d1, d2 = NGramDrafter(), NGramDrafter()
    d1.begin(0, Request(0, hist, 4))
    d2.begin(0, Request(0, hist, 4))
    a, b = d1.propose(0, 4), d2.propose(0, 4)
    np.testing.assert_array_equal(a, b)            # determinism
    # longest matching suffix is the bigram (1, 2) whose previous
    # occurrence ends at position 3 -> the draft copies what followed it
    np.testing.assert_array_equal(a, [3, 9, 1, 2])
    np.testing.assert_array_equal(d1.propose(0, 2), [3, 9])  # k caps it


def test_ngram_drafter_lifecycle_and_empty_cases():
    d = NGramDrafter()
    d.begin(0, Request(0, np.arange(5, dtype=np.int32), 4))
    assert d.propose(0, 4).size == 0      # no suffix has repeated
    assert d.propose(0, 0).size == 0      # k == 0
    assert d.propose(7, 4).size == 0      # unknown slot
    d.extend(7, [1, 2])                   # unknown slot: no-op
    d.extend(0, [3, 4])                   # history is now 0,1,2,3,4,3,4
    # bigram (3, 4) previously ended at position 5 -> copy what followed
    np.testing.assert_array_equal(d.propose(0, 3), [3, 4])
    d.drop(0)
    assert d.propose(0, 4).size == 0
    with pytest.raises(ValueError):
        NGramDrafter(ngram_max=0)


# ------------------------------------------------- engine-level token parity

@pytest.mark.parametrize("arch_id", TEXT_ARCHS)
def test_spec_matches_plain_every_text_arch(arch_id, rng):
    """All 8 text archs: the speculating engine (kv auto: paged where
    supported, dense rings/states elsewhere) is token-identical to the
    k=0 engine, in ONE dispatch per tick, with drafts corrupted on a
    fixed schedule so partial accepts and full rejections both occur —
    and slots are reused across requests (3 prompts, max_batch=2)."""
    cfg = _smoke_cfg(arch_id)
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in PROMPT_LENS]
    plain, _ = _run(cfg, params, prompts)
    oracle = OracleDrafter(plain, cfg.vocab_size, corrupt_every=2)
    spec, st = _run(cfg, params, prompts, spec_depth=3, drafter=oracle)
    assert spec == plain, arch_id
    assert st["max_dispatches"] == 1
    assert st["proposed"] > 0
    assert 0 < st["accepted"] < st["proposed"]   # accepts AND rejections


@pytest.mark.parametrize("arch_id", ["yi-6b", "gemma3-4b"])
@pytest.mark.parametrize("kv_mode", ["dense", "paged"])
def test_spec_dense_and_paged_explicit(arch_id, kv_mode, rng):
    """Explicit dense AND paged KV: dense covers the flat cache and the
    gemma3 windowed rings (whose ring margin absorbs in-flight drafts),
    paged covers write-then-gather with rejected-suffix overwrite."""
    cfg = _smoke_cfg(arch_id)
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in PROMPT_LENS]
    plain, _ = _run(cfg, params, prompts, kv_mode=kv_mode)
    oracle = OracleDrafter(plain, cfg.vocab_size, corrupt_every=2)
    spec, st = _run(cfg, params, prompts, spec_depth=4, drafter=oracle,
                    kv_mode=kv_mode)
    assert spec == plain, (arch_id, kv_mode)
    assert st["paged"] == (kv_mode == "paged")
    assert st["max_dispatches"] == 1
    assert st["proposed"] > 0 and st["accepted"] < st["proposed"]


def test_spec_always_rejected_is_still_identical(rng):
    """The adversarial floor: every draft wrong, every tick a full
    rejection + KV overwrite — output must not move, and throughput
    degrades to exactly one token per decode tick."""
    cfg = _smoke_cfg("yi-6b")
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, 19).astype(np.int32)]
    plain, plain_st = _run(cfg, params, prompts, max_new=8)
    bad = {r: [(t + 1) % cfg.vocab_size for t in toks]
           for r, toks in plain.items()}
    spec, st = _run(cfg, params, prompts, spec_depth=4, max_new=8,
                    drafter=OracleDrafter(bad, cfg.vocab_size))
    assert spec == plain
    assert st["proposed"] > 0 and st["accepted"] == 0
    assert st["ticks"] == plain_st["ticks"]   # no speedup, no slowdown


# ------------------------------------------------------------- k=0 contract

def test_spec_off_is_todays_path(rng):
    """k=0 builds no drafter, counts nothing, and IS the existing packed
    engine; explicitly requesting speculation off the packed path raises,
    while the env-forced CI leg silently degrades to k=0."""
    cfg = _smoke_cfg("yi-6b")
    params, _ = zoo.init(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=96,
                      enable_smartconf=False, prefill_mode="packed")
    assert not eng.spec_enabled and eng._drafter is None
    assert eng.spec_proposed == eng.spec_accepted == 0
    eng.close()
    with pytest.raises(ValueError, match="packed"):
        ServeEngine(cfg, params, max_batch=2, cache_len=96,
                    enable_smartconf=False, prefill_mode="bucketed",
                    spec_depth=2)
    # env-forced depth on an engine that cannot speculate: degrade, not die
    opts = ServeOptions(max_batch=2, cache_len=96, enable_smartconf=False,
                        prefill_mode="bucketed").resolve(
        env={"REPRO_SPEC_DEPTH": "2"})
    assert opts.spec_depth == 2 and opts.spec_env_forced
    eng = ServeEngine(cfg, params, options=opts)
    assert not eng.spec_enabled
    eng.close()
    # ... and on one that can: forced on at the env depth
    opts = ServeOptions(max_batch=2, cache_len=96, enable_smartconf=False,
                        prefill_mode="packed").resolve(
        env={"REPRO_SPEC_DEPTH": "2"})
    eng = ServeEngine(cfg, params, options=opts)
    assert eng.spec_enabled and eng.spec_depth == 2
    eng.close()


# ----------------------------------------------- acceptance regimes (markov)

@pytest.fixture(scope="module")
def markov():
    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    return cfg, markov_params(cfg, params, {t: (t + 1) % 8
                                            for t in range(8)})


def test_markov_full_accept_regime(markov):
    """Crafted weights whose decode IS a token cycle: the real NGram
    drafter reads the cycle out of the prompt, so accepts approach 100%
    and the spec engine finishes in strictly fewer ticks."""
    cfg, params = markov
    prompts = [np.tile(np.arange(8, dtype=np.int32), 3)]   # 24-token cycle
    plain, plain_st = _run(cfg, params, prompts, max_new=16)
    assert plain[0] == [(24 + i) % 8 for i in range(16)]   # the map, decoded
    spec, st = _run(cfg, params, prompts, spec_depth=4, max_new=16)
    assert spec == plain
    assert st["proposed"] > 0
    assert st["accepted"] / st["proposed"] > 0.8
    assert st["ticks"] < plain_st["ticks"]
    assert st["max_dispatches"] == 1


def test_sc_spec_depth_adapts_both_ways(markov, rng):
    """The serve.spec_depth controller: a fully-predictable stream holds
    the accept rate above the setpoint and the depth deepens from its
    initial value; an always-rejected stream drives it to the floor of 1
    (never 0 — spec off is an operator choice, not a controller state)."""
    cfg, params = markov
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=96,
                      prefill_mode="packed", spec_depth=2)
    assert eng.sc_spec is not None
    eng.submit(Request(0, np.tile(np.arange(8, dtype=np.int32), 3), 48))
    ticks = 0
    while len(eng.finished) < 1 and ticks < 400:
        eng.tick()
        ticks += 1
    assert len(eng.finished) == 1
    assert eng.spec_depth > 2, "full-accept stream should deepen the draft"
    audit_free = eng.sc_spec.sensor_faults == 0
    eng.close()
    assert audit_free

    cfg2 = _smoke_cfg("yi-6b")
    params2, _ = zoo.init(cfg2, jax.random.key(0))
    prompts = [rng.integers(0, cfg2.vocab_size, 19).astype(np.int32)]
    plain, _ = _run(cfg2, params2, prompts, max_new=24)
    bad = {0: [(t + 1) % cfg2.vocab_size for t in plain[0]]}
    eng = ServeEngine(cfg2, params2, max_batch=2, cache_len=96,
                      prefill_mode="packed", spec_depth=4)
    eng._drafter = OracleDrafter(bad, cfg2.vocab_size)
    eng.submit(Request(0, prompts[0], 24))
    ticks = 0
    while len(eng.finished) < 1 and ticks < 400:
        eng.tick()
        ticks += 1
    assert len(eng.finished) == 1
    assert list(eng.finished[0].generated) == plain[0]
    assert eng.spec_depth == 1, "all-rejected stream should hit the floor"
    eng.close()


# ------------------------------------------------- prefix-cache poison guard

def test_rejected_drafts_cannot_poison_prefix_cache(rng):
    """Regression: a warm prefix hit must never serve KV written for a
    rejected draft.  Request A decodes with every draft rejected (max
    junk written beyond the accepted frontier), its output extension is
    inserted into the radix cache at finish; request B's prompt extends
    A's accepted stream and takes a multi-block warm hit over exactly
    those blocks.  B's output must match a cold, spec-free engine."""
    cfg = _smoke_cfg("yi-6b")
    params, _ = zoo.init(cfg, jax.random.key(0))
    p1 = rng.integers(0, cfg.vocab_size, 26).astype(np.int32)
    ref1, _ = _run(cfg, params, [p1], kv_mode="paged", max_new=8)
    bad = {0: [(t + 1) % cfg.vocab_size for t in ref1[0]]}
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=96,
                      enable_smartconf=False, prefill_mode="packed",
                      kv_mode="paged", prefix_cache=True, spec_depth=4)
    eng._drafter = OracleDrafter(bad, cfg.vocab_size)
    eng.submit(Request(0, p1, 8))
    ticks = 0
    while len(eng.finished) < 1 and ticks < 200:
        eng.tick()
        ticks += 1
    assert eng.spec_proposed > 0 and eng.spec_accepted == 0
    gen1 = list(eng.finished[0].generated)
    assert gen1 == ref1[0]
    # B extends A's prompt + accepted output: 26 + 7 = 33 tokens, so the
    # warm hit spans 2 full blocks — the second one exists ONLY via the
    # output-extension insert, i.e. KV written while drafts were in flight
    p2 = np.concatenate([p1, np.asarray(gen1[:7], np.int32)])
    eng._drafter = NGramDrafter()
    eng.submit(Request(1, p2, 8))
    ticks = 0
    while len(eng.finished) < 2 and ticks < 200:
        eng.tick()
        ticks += 1
    assert len(eng.finished) == 2
    warm = next(r for r in eng.finished if r.req_id == 1)
    assert warm.prefix_hit > 16, "extension blocks should serve the hit"
    eng.close()
    ref2, _ = _run(cfg, params, [p2], kv_mode="paged", max_new=8)
    assert list(warm.generated) == ref2[0], "poisoned KV behind a warm hit"


# ------------------------------------------------------- telemetry and chaos

def test_spec_telemetry_counters_and_audit(markov):
    from repro.core.telemetry import Telemetry

    cfg, params = markov
    tel = Telemetry(enabled=True)
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=96,
                      prefill_mode="packed", spec_depth=2, telemetry=tel)
    eng.submit(Request(0, np.tile(np.arange(8, dtype=np.int32), 3), 16))
    ticks = 0
    last = {}
    while len(eng.finished) < 1 and ticks < 200:
        last = eng.tick()
        ticks += 1
    assert len(eng.finished) == 1
    assert tel.metrics.counter("serve.spec.proposed").value == eng.spec_proposed > 0
    assert tel.metrics.counter("serve.spec.accepted").value == eng.spec_accepted > 0
    assert tel.metrics.histogram("serve.spec.accepted_len").mean() > 0
    # per-tick stats carry the live knob and sensor values
    assert last["spec_depth"] == eng.spec_depth
    assert 0.0 <= last["accept_rate"] <= 1.0
    # every depth actuation left a Decision in the audit trail
    des = tel.audit.query(conf="serve.spec_depth")
    assert des and all(d.metric == "accept_rate" and d.sane for d in des)
    eng.close()


def test_spec_chaos_nan_accept_rate(markov):
    """A NaN accept-rate window with speculation live: the guardrails
    eat the insane readings (sensor_faults counts them), the knob pins
    to last-known-good, every request still finishes token-correct."""
    cfg, params = markov
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=96,
                      prefill_mode="packed", spec_depth=2)
    chaos = ChaosMonkey(ChaosSpec(
        seed=0, sensor_fault_tick=2, sensor_fault_ticks=10,
        sensor_fault_mode="nan",
        sensor_names=("accept_rate",))).install(eng)
    prompt = np.tile(np.arange(8, dtype=np.int32), 3)
    for i in range(3):
        eng.submit(Request(i, prompt, 16))
    ticks = 0
    while len(eng.finished) < 3 and ticks < 400:
        chaos(None, ticks)
        eng.tick()
        ticks += 1
    assert len(eng.finished) == 3
    want = [(24 + i) % 8 for i in range(16)]
    assert all(list(r.generated) == want for r in eng.finished)
    assert eng.sc_spec.sensor_faults > 0, "the NaN window was never sensed"
    assert any(n.startswith("sensor_nan:accept_rate")
               for _, n in chaos.events)
    assert 1 <= eng.spec_depth <= eng.spec_depth_max
    eng.close()
