"""Unit tests: open-loop trace synthesis, virtual clock, tick cost model."""

import dataclasses

import numpy as np
import pytest

from repro.serve.traffic import (TickCostModel, TierSpec, TraceConfig,
                                 TraceEvent, VirtualClock, as_requests,
                                 concat_traces, synthesize_trace)


def test_trace_deterministic_from_seed():
    cfg = TraceConfig(rate_rps=40, horizon_s=4.0, seed=3)
    assert synthesize_trace(cfg) == synthesize_trace(cfg)
    other = synthesize_trace(dataclasses.replace(cfg, seed=4))
    assert other != synthesize_trace(cfg)


def test_poisson_rate_matches_config():
    cfg = TraceConfig(process="poisson", rate_rps=50, horizon_s=40.0, seed=0)
    n = len(synthesize_trace(cfg))
    # 2000 expected arrivals; 5 sigma ~ +-224
    assert 1700 <= n <= 2300


def test_bursty_rate_is_modulated():
    cfg = TraceConfig(process="bursty", rate_rps=30, horizon_s=40.0, seed=1,
                      burst_factor=6.0, burst_period_s=4.0, burst_duty=0.25)
    events = synthesize_trace(cfg)
    on = [e for e in events if (e.t % 4.0) < 1.0]
    off = [e for e in events if (e.t % 4.0) >= 1.0]
    on_rate = len(on) / (0.25 * 40.0)
    off_rate = len(off) / (0.75 * 40.0)
    assert on_rate > 3.0 * off_rate          # true ratio is 6x
    # the long-run mean still honours rate_rps
    assert 0.75 * 30 <= len(events) / 40.0 <= 1.25 * 30


def test_diurnal_rate_follows_the_sinusoid():
    cfg = TraceConfig(process="diurnal", rate_rps=40, horizon_s=40.0, seed=2,
                      diurnal_period_s=10.0, diurnal_amplitude=0.9)
    events = synthesize_trace(cfg)
    # first half of each period is the high phase (sin > 0)
    high = sum(1 for e in events if (e.t % 10.0) < 5.0)
    low = len(events) - high
    assert high > 1.5 * low


def test_unknown_process_rejected():
    with pytest.raises(ValueError, match="unknown arrival process"):
        synthesize_trace(TraceConfig(process="fractal"))


def test_lengths_are_heavy_tailed_and_bounded():
    cfg = TraceConfig(rate_rps=100, horizon_s=30.0, seed=5,
                      prompt_lo=4, prompt_hi=64, prompt_alpha=1.1)
    plens = np.asarray([e.prompt_len for e in synthesize_trace(cfg)])
    assert plens.min() >= 4 and plens.max() <= 64
    # heavy tail: the median hugs the floor, but the cap is reached
    assert np.median(plens) < 12
    assert plens.max() > 48


def test_tier_shares_and_deadlines():
    tiers = (TierSpec(0, 0.5, deadline_s=5.0), TierSpec(1, 0.5))
    cfg = TraceConfig(rate_rps=60, horizon_s=20.0, seed=6, tiers=tiers)
    events = synthesize_trace(cfg)
    n0 = sum(1 for e in events if e.tier == 0)
    assert 0.4 <= n0 / len(events) <= 0.6
    assert all(e.deadline_s == 5.0 for e in events if e.tier == 0)
    assert all(e.deadline_s is None for e in events if e.tier == 1)


def test_as_requests_materialisation():
    events = [TraceEvent(t=0.5, req_id=7, tier=2, deadline_s=9.0,
                         prompt_len=11, max_new_tokens=3)]
    (t, req), = as_requests(events, vocab=64, seed=0, id_base=100)
    assert t == 0.5 and req.req_id == 107
    assert req.prompt.dtype == np.int32 and req.prompt.shape == (11,)
    assert (req.prompt > 0).all() and (req.prompt < 64).all()
    assert req.tier == 2 and req.deadline_s == 9.0 and req.max_new_tokens == 3


def test_virtual_clock():
    vc = VirtualClock(start=2.0)
    assert vc() == 2.0
    assert vc.advance(0.5) == 2.5
    assert vc() == 2.5


def test_tick_cost_model_charges_issued_lanes():
    cost = TickCostModel(base_s=0.01, prefill_token_s=1e-3,
                         decode_token_s=1e-2)
    stats = {"prefill_tokens": 10, "prefill_issued_tokens": 16,
             "decode_tokens": 3}
    # issued (padded) lanes are charged, not just live tokens
    assert cost.cost(stats) == pytest.approx(0.01 + 16e-3 + 3e-2)
    assert cost.cost({}) == pytest.approx(0.01)


def test_concat_traces_regime_shift():
    calm = synthesize_trace(TraceConfig(
        process="poisson", rate_rps=10.0, horizon_s=2.0, seed=3))
    storm = synthesize_trace(TraceConfig(
        process="bursty", rate_rps=40.0, horizon_s=2.0, t_start=2.0,
        seed=4, burst_factor=4.0, burst_period_s=1.0, burst_duty=0.5))
    merged = concat_traces(calm, storm)
    assert len(merged) == len(calm) + len(storm)
    ts = [e.t for e in merged]
    assert ts == sorted(ts)
    # globally unique, dense ids — safe to materialise as one request list
    assert [e.req_id for e in merged] == list(range(len(merged)))
    # the shift is real: the storm half offers several times the calm rate
    n_calm = sum(1 for e in merged if e.t < 2.0)
    n_storm = len(merged) - n_calm
    assert n_storm > 2 * n_calm
