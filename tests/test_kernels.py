"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.rglru import rglru_ref, rglru_scan
from repro.kernels.rwkv6 import rwkv6_ref, rwkv6_scan

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOLS[jnp.bfloat16] if dtype == jnp.bfloat16 else TOLS[jnp.float32]


@pytest.mark.parametrize("b,h,kv,s,d", [
    (2, 4, 2, 128, 64), (1, 8, 1, 256, 64), (2, 4, 4, 192, 32),
    (1, 2, 2, 128, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(rng, b, h, kv, s, d, causal, window, dtype):
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, kv, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, kv, s, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_kv=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("b,h,kv,s,d,w", [
    (2, 8, 2, 512, 64, 0), (1, 4, 1, 1024, 128, 256), (2, 4, 4, 384, 64, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(rng, b, h, kv, s, d, w, dtype):
    q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, kv, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, kv, s, d)), dtype)
    kpos = jnp.asarray(rng.integers(-1, 600, (b, s)), jnp.int32)
    qpos = jnp.asarray([599] * b, jnp.int32)
    out = decode_attention(q, k, v, kpos, qpos, window=w, block_kv=128,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, kpos, qpos, window=w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_empty_cache(rng):
    """All slots invalid -> output must be zeros (l == 0 guard)."""
    q = jnp.asarray(rng.standard_normal((1, 4, 64)), jnp.float32)
    k = jnp.zeros((1, 2, 128, 64), jnp.float32)
    v = jnp.zeros((1, 2, 128, 64), jnp.float32)
    kpos = jnp.full((1, 128), -1, jnp.int32)
    out = decode_attention(q, k, v, kpos, jnp.asarray([5], jnp.int32),
                           interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("bh,s,n", [(4, 64, 64), (2, 128, 64), (3, 96, 32),
                                    (1, 32, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_sweep(rng, bh, s, n, dtype):
    r = jnp.asarray(rng.standard_normal((bh, s, n)) * 0.5, dtype)
    k = jnp.asarray(rng.standard_normal((bh, s, n)) * 0.5, dtype)
    v = jnp.asarray(rng.standard_normal((bh, s, n)) * 0.5, dtype)
    logw = jnp.asarray(-np.exp(rng.standard_normal((bh, s, n)) - 1.0), jnp.float32)
    u = jnp.asarray(rng.standard_normal((bh, n)) * 0.3, jnp.float32)
    out = rwkv6_scan(r, k, v, logw, u, interpret=True)
    ref = rwkv6_ref(r, k, v, logw, u)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("b,s,f", [(2, 256, 512), (1, 128, 1024), (3, 512, 256)])
def test_rglru_sweep(rng, b, s, f):
    la = jnp.asarray(-np.abs(rng.standard_normal((b, s, f))) * 0.5, jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, f)), jnp.float32)
    out = rglru_scan(la, bb, interpret=True)
    ref = rglru_ref(la, bb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_rwkv6_state_op_threads_across_boundary(rng, impl):
    """Splitting a sequence and threading (y, S) through the state-in/
    state-out variant must reproduce the unsplit run — the scan-state ABI
    chunked prefill relies on."""
    from repro.kernels.rwkv6 import rwkv6_ref, rwkv6_state_op

    bh, s, n = 4, 64, 64
    r = jnp.asarray(rng.standard_normal((bh, s, n)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, s, n)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, n)) * 0.5, jnp.float32)
    logw = jnp.asarray(-np.exp(rng.standard_normal((bh, s, n)) - 1.0),
                       jnp.float32)
    u = jnp.asarray(rng.standard_normal((bh, n)) * 0.3, jnp.float32)
    full = rwkv6_ref(r, k, v, logw, u)

    s0 = jnp.zeros((bh, n, n), jnp.float32)
    cut = 32
    y1, s1 = rwkv6_state_op(r[:, :cut], k[:, :cut], v[:, :cut],
                            logw[:, :cut], u, s0, force=impl)
    y2, s2 = rwkv6_state_op(r[:, cut:], k[:, cut:], v[:, cut:],
                            logw[:, cut:], u, s1, force=impl)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(full), atol=1e-4, rtol=1e-4)
    # the carried state is itself part of the ABI: it must equal the
    # one-shot run's final state
    _, s_full = rwkv6_state_op(r, k, v, logw, u, s0, force="xla")
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_rglru_state_op_threads_across_boundary(rng, impl):
    from repro.kernels.rglru import rglru_ref, rglru_state_op

    b, s, f = 2, 128, 256
    la = jnp.asarray(-np.abs(rng.standard_normal((b, s, f))) * 0.5,
                     jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, f)), jnp.float32)
    full = rglru_ref(la, bb)

    h0 = jnp.zeros((b, f), jnp.float32)
    cut = 64
    h1, st1 = rglru_state_op(la[:, :cut], bb[:, :cut], h0, force=impl)
    h2, st2 = rglru_state_op(la[:, cut:], bb[:, cut:], st1, force=impl)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], axis=1)),
                               np.asarray(full), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(full[:, -1]),
                               atol=1e-5, rtol=1e-5)


def test_model_chunked_rwkv6_matches_naive(rng):
    """models.rwkv6.time_mix_chunked (the XLA path) against the per-token
    oracle — the same math the Pallas kernel implements."""
    from repro.kernels.rwkv6 import rwkv6_ref as oracle
    from repro.models import rwkv6 as m

    d = 128
    h = d // m.HEAD_DIM
    b, s = 2, 64
    cfgish = type("C", (), {"d_model": d, "d_ff": 256, "dtype": "float32"})()
    params, _ = m.rwkv6_init(jax.random.key(0), cfgish)
    x = jnp.asarray(rng.standard_normal((b, s, d)) * 0.1, jnp.float32)
    state = m.init_state(cfgish, b)
    y, S, _ = m.time_mix_chunked(params, x, state["S"], state["tm_last"])

    # naive path: project then per-token recurrence
    x_prev = jnp.concatenate([state["tm_last"][:, None, :], x[:, :-1, :]], 1)
    r, k, v, g, logw = m._projections(params, x, x_prev)
    rh = m._heads(r, h).transpose(0, 2, 1, 3).reshape(b * h, s, m.HEAD_DIM)
    kh = m._heads(k, h).transpose(0, 2, 1, 3).reshape(b * h, s, m.HEAD_DIM)
    vh = m._heads(v, h).transpose(0, 2, 1, 3).reshape(b * h, s, m.HEAD_DIM)
    wh = m._heads(logw, h).transpose(0, 2, 1, 3).reshape(b * h, s, m.HEAD_DIM)
    u = jnp.broadcast_to(params["u"][None], (b, h, m.HEAD_DIM)).reshape(b * h, -1)
    y_ref = oracle(rh, kh, vh, wh, u)
    y_ref = y_ref.reshape(b, h, s, m.HEAD_DIM).transpose(0, 2, 1, 3)
    y_ref = m._groupnorm(y_ref, params["ln_scale"], h) * jax.nn.silu(g)
    y_ref = y_ref @ params["wo"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("b,h,kv,s,d,causal,window", [
    (1, 4, 2, 128, 64, True, 0),
    (1, 2, 1, 192, 32, True, 64),
    (2, 4, 4, 128, 64, False, 0),
])
def test_flash_attention_backward_kernels(rng, b, h, kv, s, d, causal, window):
    """custom_vjp over the Pallas fwd/bwd kernels vs jax.grad of the oracle."""
    from repro.kernels.flash_attention import (attention_ref,
                                               flash_attention_grad)
    q = jnp.asarray(rng.standard_normal((b, h, s, d)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, kv, s, d)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, kv, s, d)) * 0.5, jnp.float32)
    w = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)

    g1 = jax.grad(lambda *a: jnp.sum(
        flash_attention_grad(*a, causal, window, True) * w),
        argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: jnp.sum(
        attention_ref(*a, causal=causal, window=window) * w),
        argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=5e-6, rtol=5e-5)


def test_flash_fwd_lse_matches_ref(rng):
    from repro.kernels.flash_attention import (attention_ref,
                                               flash_attention_fwd_lse)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 32)), jnp.float32)
    o, lse = flash_attention_fwd_lse(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)
    # lse cross-check: scores logsumexp per row
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (32 ** -0.5)
    mask = jnp.tril(jnp.ones((128, 128), bool))
    s = jnp.where(mask, s, -1e30)
    np.testing.assert_allclose(np.asarray(lse),
                               np.asarray(jax.nn.logsumexp(s, axis=-1)),
                               atol=1e-4, rtol=1e-4)


def test_model_attention_pallas_impl_flag(rng, monkeypatch):
    """REPRO_ATTN_IMPL=pallas_interpret must match the XLA path end-to-end
    through a real train loss (reduced yi-6b)."""
    import jax
    from repro.configs import get_config, reduced
    from repro.models import zoo

    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32),
    }
    loss_xla, _ = zoo.loss_fn(cfg, params, batch)
    monkeypatch.setenv("REPRO_ATTN_IMPL", "pallas_interpret")
    loss_pallas, _ = zoo.loss_fn(cfg, params, batch)
    np.testing.assert_allclose(float(loss_xla), float(loss_pallas),
                               rtol=2e-5, atol=2e-5)
