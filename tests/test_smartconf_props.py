"""Property-based tests (hypothesis) for the paper's §5.6 guarantees."""

import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (ControllerModel, GoalSpec, SmartController,
                        compute_pole, compute_virtual_goal, fit_model)

finite = st.floats(allow_nan=False, allow_infinity=False)


@given(st.floats(min_value=1.0, max_value=1e6))
def test_pole_always_stable_range(delta):
    """Stability requires 0 <= p < 1 for any Delta (paper §5.6)."""
    p = compute_pole(delta)
    assert 0.0 <= p < 1.0


@given(st.floats(min_value=0.01, max_value=100.0),
       st.floats(min_value=1.0, max_value=1e4),
       st.floats(min_value=0.0, max_value=3.0))
@settings(max_examples=50, deadline=None)
def test_convergence_within_delta_bound(alpha_hat, goal, noise_delta):
    """The closed loop converges whenever the true/model gain ratio is below
    2/(1-p) — exactly the robustness the Delta-derived pole buys."""
    delta = 1.0 + noise_delta
    p = compute_pole(delta)
    # pick a true alpha at 90% of the guaranteed robustness bound
    ratio = 0.9 * 2.0 / (1.0 - p)
    alpha_true = alpha_hat * ratio
    model = ControllerModel(alpha=alpha_hat, delta=delta, lam=0.0,
                            conf_min=-1e12, conf_max=1e12, integer=False)
    ctl = SmartController(model, GoalSpec(goal, hard=False), 0.0)
    s = 0.0
    for _ in range(400):
        ctl.observe(s)
        s = alpha_true * ctl.actuate()
    assert abs(s - goal) <= max(1e-3 * goal, 1e-3)


@given(st.floats(min_value=0.0, max_value=0.5),
       st.floats(min_value=10.0, max_value=1e5))
def test_virtual_goal_orders(lam, goal):
    """Hard upper goals: virtual goal strictly inside the safe region and
    monotone in lambda (more instability -> more margin)."""
    g = GoalSpec(goal, hard=True)
    vg = compute_virtual_goal(g, lam)
    assert vg <= goal
    assert compute_virtual_goal(g, min(lam + 0.1, 0.95)) <= vg


@given(st.integers(min_value=2, max_value=8),
       st.floats(min_value=0.1, max_value=10.0),
       st.floats(min_value=10.0, max_value=1000.0))
@settings(max_examples=30, deadline=None)
def test_interaction_factor_never_overshoots_jointly(n, alpha, goal):
    """N interacting controllers on one metric: with the super-hard split
    the combined first-step correction never exceeds the single-controller
    correction (the §5.4 safety net)."""
    model = ControllerModel(alpha=alpha, delta=1.0, lam=0.0,
                            conf_min=-1e12, conf_max=1e12, integer=False)
    ctls = [SmartController(model, GoalSpec(goal, hard=False), 0.0,
                            n_interacting=n) for _ in range(n)]
    s = 0.0
    for c in ctls:
        c.observe(s)
    total_effect = alpha * sum(c.actuate() for c in ctls)
    assert total_effect <= goal * (1.0 + 1e-9)


@given(st.lists(st.floats(min_value=1.0, max_value=1e3), min_size=2,
                max_size=8, unique=True),
       st.floats(min_value=-5.0, max_value=5.0).filter(lambda a: abs(a) > 0.01),
       st.floats(min_value=-100.0, max_value=100.0))
@settings(max_examples=50, deadline=None)
def test_fit_model_recovers_slope(confs, true_alpha, intercept):
    """fit_model recovers the affine slope exactly on noiseless data."""
    samples = [[true_alpha * c + intercept] * 3 for c in confs]
    m = fit_model(sorted(confs), [samples[i] for i in np.argsort(confs)])
    assert math.isclose(m.alpha, true_alpha, rel_tol=1e-6, abs_tol=1e-9)


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_no_overshoot_probability_hard_goal(seed):
    """One-sided no-overshoot (paper: >=84% per decision).  We empirically
    require the *per-run* violation rate under matched noise to stay small:
    the virtual-goal margin is lambda*goal = 1 sigma-equivalent, and the
    two-pole reaction caps excursions.  Statistical, hence the fixed bound."""
    rng = np.random.default_rng(seed)
    lam = 0.1
    goal = 100.0
    model = ControllerModel(alpha=1.0, delta=1.0 + 3 * lam, lam=lam,
                            conf_min=0.0, conf_max=1e9, integer=False)
    ctl = SmartController(model, GoalSpec(goal, hard=True), 0.0)
    sigma = lam * goal / 2.0   # noise at half the margin
    s = 0.0
    viol = 0
    n = 300
    for _ in range(n):
        ctl.observe(s)
        c = ctl.actuate()
        s = c + rng.normal(0.0, sigma)
    # steady state hugs the virtual goal (90); violations of the REAL goal
    # need a +2 sigma excursion: empirically rare
    for _ in range(n):
        ctl.observe(s)
        c = ctl.actuate()
        s = c + rng.normal(0.0, sigma)
        viol += (s > goal)
    assert viol / n <= 0.16   # the paper's 84% one-sided bound
