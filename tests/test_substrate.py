"""Optimizer, data pipeline, checkpointing, fault tolerance, collectives."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, restore, save
from repro.data import PrefetchPipeline, SyntheticTokens
from repro.distributed.collectives import dequantize_int8, quantize_int8
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               PreemptionHandler,
                                               StragglerDetector)
from repro.optim import accum, adamw


# --------------------------------------------------------------- optimizer
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                            total_steps=200, clip_norm=100.0)
    def loss(p):
        return jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.update(g, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_clipping_and_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(adamw.schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(adamw.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(cfg, jnp.asarray(100))) == pytest.approx(
        cfg.min_lr_ratio, rel=1e-3)
    params = {"w": jnp.ones((4,))}
    st = adamw.init(params)
    big = {"w": jnp.full((4,), 1e9)}
    p2, _, m = adamw.update(big, st, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(2e9)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_grad_accumulation_equivalence(rng):
    w = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    params = {"w": w}
    x = jnp.asarray(rng.standard_normal((6, 8)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((6, 4)), jnp.float32)

    def loss_fn(p, b):
        pred = b["x"] @ p["w"]
        return jnp.mean((pred - b["y"]) ** 2), {"z": jnp.zeros(())}

    batch = {"x": x, "y": y}
    l1, _, g1 = accum.accumulate_grads(loss_fn, params, batch, 1)
    l3, _, g3 = accum.accumulate_grads(loss_fn, params, batch, 3)
    np.testing.assert_allclose(float(l1), float(l3), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g3["w"]),
                               rtol=1e-5, atol=1e-6)


def test_quantize_microbatches():
    assert accum.quantize_microbatches(8, 3.2) == 4
    assert accum.quantize_microbatches(8, 0.5) == 1
    assert accum.quantize_microbatches(6, 5.9) == 6


# ------------------------------------------------------------ data pipeline
def test_synthetic_tokens_deterministic_and_restartable():
    a = SyntheticTokens(1000, 4, 16, seed=7)
    b1 = [a.next_batch() for _ in range(3)]
    st = a.state()
    b2 = a.next_batch()
    a.restore(st)
    b2r = a.next_batch()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
    fresh = SyntheticTokens(1000, 4, 16, seed=7)
    np.testing.assert_array_equal(b1[0]["tokens"], fresh.next_batch()["tokens"])


def test_host_sharding_disjoint_streams():
    h0 = SyntheticTokens(1000, 8, 16, host_id=0, num_hosts=2)
    h1 = SyntheticTokens(1000, 8, 16, host_id=1, num_hosts=2)
    assert h0.local_batch == 4
    assert not np.array_equal(h0.next_batch()["tokens"],
                              h1.next_batch()["tokens"])


def test_prefetch_depth_and_straggler_backup():
    src = SyntheticTokens(100, 2, 8)
    delays = iter([0.0, 0.3] + [0.0] * 50)
    pipe = PrefetchPipeline(src, depth=2, produce_deadline_s=0.1,
                            delay_fn=lambda: next(delays, 0.0))
    batches = [pipe.get(timeout=5.0) for _ in range(5)]
    assert len(batches) == 5
    assert pipe.backup_batches >= 1      # the slow batch was substituted
    pipe.set_depth(1)
    assert pipe.depth == 1
    pipe.close()


# ------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as td:
        tree = {"a": jnp.arange(5, dtype=jnp.float32),
                "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
        for step in (1, 2, 3, 4):
            save(td, step, tree, extra={"step": step}, keep_n=2)
        assert latest_step(td) == 4
        assert sorted(os.listdir(td)) == ["step_00000003", "step_00000004"]
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        got, extra, step = restore(td, None, like)
        assert step == 4 and extra["step"] == 4
        np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(5))
        assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomic_no_partial_dirs():
    with tempfile.TemporaryDirectory() as td:
        save(td, 7, {"x": jnp.zeros(4)})
        assert not any(n.endswith(".tmp") for n in os.listdir(td))


def test_checkpointer_interval_control():
    with tempfile.TemporaryDirectory() as td:
        ck = Checkpointer(td, interval_steps=5)
        tree = {"x": jnp.zeros(2)}
        assert ck.maybe_save(3, tree) is None
        assert ck.maybe_save(5, tree) is not None
        ck.set_interval(2)
        assert ck.maybe_save(6, tree) is not None


# --------------------------------------------------------- fault tolerance
def test_heartbeat_detects_and_recovers():
    t = [0.0]
    failures = []
    mon = HeartbeatMonitor(["w0", "w1"], timeout_s=5.0,
                           on_failure=failures.append, clock=lambda: t[0])
    t[0] = 4.0
    mon.beat("w0")
    t[0] = 6.0
    assert mon.check() == ["w1"]
    assert failures == ["w1"]
    assert mon.alive == ["w0"]
    mon.beat("w1")   # elastic rejoin
    assert "w1" in mon.alive


def test_straggler_detector():
    det = StragglerDetector(factor=2.0)
    for i in range(8):
        det.record("fast1", 1.0)
        det.record("fast2", 1.1)
        det.record("slow", 3.5)
    assert det.stragglers() == ["slow"]


def test_preemption_flag():
    h = PreemptionHandler()
    assert not h.triggered
    h.trigger()
    assert h.triggered


# -------------------------------------------------------------- compression
def test_int8_quantization_roundtrip_error(rng):
    x = jnp.asarray(rng.standard_normal((1000,)) * 3.0, jnp.float32)
    q, scale, shape = quantize_int8(x)
    back = dequantize_int8(q, scale, shape)
    # per-block max error <= scale/2
    err = np.abs(np.asarray(back - x))
    max_scale = float(scale.max())
    assert err.max() <= max_scale / 2 + 1e-7


def test_hlo_cost_analyzer_known_flops():
    from repro.roofline.hlo_cost import analyze_module
    s = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    txt = jax.jit(lambda a, b: a @ b).lower(s, s).compile().as_text()
    r = analyze_module(txt)
    assert r["flops"] == pytest.approx(2 * 256 ** 3, rel=1e-6)

    def g(a):
        out, _ = jax.lax.scan(lambda x, _: (x @ a, None), a, None, length=7)
        return out
    txt = jax.jit(g).lower(s).compile().as_text()
    r = analyze_module(txt)
    assert r["flops"] == pytest.approx(7 * 2 * 256 ** 3, rel=1e-6)
