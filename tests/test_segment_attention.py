"""Fused segment-attention kernel family vs. the ref oracle, and the
dead-pad-lane contract.

The correctness bar for the unified-tick path: the Pallas kernels (run in
interpreter mode on CPU) must match ``ref.py`` on EVERY lane — live and
dead — over ragged segment mixes, GQA/MQA head layouts, sliding windows
(the gemma3 swa kind), bf16 streams, and out-of-order / holey paged block
tables.  Exact all-lane parity is only possible because fully-masked
queries emit exact zeros instead of a garbage uniform softmax (the
sensor-honesty satellite on ``layers.segment_attention``)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.segment_attention import (
    paged_segment_attention, paged_segment_attention_ref,
    segment_attention, segment_attention_ref, segment_attention_op)
from repro.models import layers


def _ragged_stream(rng, p, n, n_seg, max_pos=64):
    """Packed-ABI tags: contiguous query segments (with a dead tail) and
    shuffled keys carrying (pos, seg) pairs, some unwritten (-1)."""
    q_seg = np.full((p,), -1, np.int32)
    q_pos = np.zeros((p,), np.int32)
    cursor = 0
    for s in range(n_seg):
        ln = int(rng.integers(1, max(2, (p - cursor) // max(1, n_seg - s))))
        if cursor + ln > p:
            break
        start = int(rng.integers(0, max_pos - ln))
        q_seg[cursor:cursor + ln] = s
        q_pos[cursor:cursor + ln] = np.arange(start, start + ln)
        cursor += ln
    k_seg = rng.integers(-1, n_seg, n).astype(np.int32)
    k_pos = rng.integers(-1, max_pos, n).astype(np.int32)
    return (jnp.asarray(q_pos), jnp.asarray(q_seg),
            jnp.asarray(k_pos), jnp.asarray(k_seg))


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (4, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("window", [0, 9])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_kernel_matches_ref(rng, h, kv, window, dtype):
    p, n, d = 37, 101, 16
    q = jnp.asarray(rng.standard_normal((p, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((n, kv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((n, kv, d)), dtype)
    q_pos, q_seg, k_pos, k_seg = _ragged_stream(rng, p, n, 3)
    ref = segment_attention_ref(q, k, v, q_pos, k_pos, q_seg, k_seg,
                                window=window)
    got = segment_attention(q, k, v, q_pos, k_pos, q_seg, k_seg,
                            window=window, block_q=16, block_k=32,
                            interpret=True)
    # all-lane comparison: dead lanes are exact zeros on both sides
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < tol, f"h={h} kv={kv} w={window}: {err:.2e}"
    dead = np.asarray(q_seg) < 0
    assert dead.any()
    assert (np.asarray(ref, np.float32)[dead] == 0.0).all()
    assert (np.asarray(got, np.float32)[dead] == 0.0).all()


def test_segment_kernel_fully_masked_live_lane(rng):
    """A live lane whose predicate admits no key (nothing written yet) must
    also emit exact zeros — kernel and oracle alike."""
    p, n, h, d = 8, 16, 2, 8
    q = jnp.asarray(rng.standard_normal((p, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((n, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((n, h, d)), jnp.float32)
    q_pos = jnp.arange(p, dtype=jnp.int32)
    q_seg = jnp.zeros((p,), jnp.int32)
    k_pos = jnp.full((n,), -1, jnp.int32)        # nothing written
    k_seg = jnp.zeros((n,), jnp.int32)
    ref = segment_attention_ref(q, k, v, q_pos, k_pos, q_seg, k_seg)
    got = segment_attention(q, k, v, q_pos, k_pos, q_seg, k_seg,
                            interpret=True)
    assert (np.asarray(ref) == 0.0).all()
    assert (np.asarray(got) == 0.0).all()


@pytest.mark.parametrize("window", [0, 11])
def test_paged_segment_kernel_out_of_order_tables(rng, window):
    """Out-of-order physical blocks and -1 holes: only the table gives the
    store meaning; the scalar-prefetch gather must agree with the
    materialized-view oracle."""
    p, h, kv, d = 29, 4, 2, 16
    b, m, t = 3, 4, 8
    nb = b * m + 2                               # spare blocks stay unused
    q = jnp.asarray(rng.standard_normal((p, h, d)), jnp.float32)
    ks = jnp.asarray(rng.standard_normal((nb, kv, t, d)), jnp.float32)
    vs = jnp.asarray(rng.standard_normal((nb, kv, t, d)), jnp.float32)
    perm = rng.permutation(nb)[:b * m].astype(np.int32).reshape(b, m)
    perm[1, 3] = -1                              # unallocated hole
    perm[2, 2] = -1
    q_seg = jnp.asarray(rng.integers(-1, b, p), jnp.int32)
    q_pos = jnp.asarray(rng.integers(0, m * t, p), jnp.int32)
    tables = jnp.asarray(perm)
    ref = paged_segment_attention_ref(q, ks, vs, tables, q_pos, q_seg,
                                      window=window)
    got = paged_segment_attention(q, ks, vs, tables, q_pos, q_seg,
                                  window=window, block_q=8, interpret=True)
    err = float(jnp.max(jnp.abs(got - ref)))
    assert err < 2e-5, f"w={window}: {err:.2e}"


def test_layers_segment_attention_zeroes_dead_lanes(rng):
    """The XLA twin in models.layers must zero dead pad lanes too (the
    bugfix satellite): uniform softmax over -1e30 scores previously emitted
    garbage on lanes no caller may read — which made exact XLA-vs-Pallas
    parity impossible."""
    p, n, h, d = 12, 24, 2, 8
    for dtype in (jnp.float32, jnp.bfloat16):
        q = jnp.asarray(rng.standard_normal((1, p, h, d)), dtype)
        k = jnp.asarray(rng.standard_normal((1, n, h, d)), dtype)
        v = jnp.asarray(rng.standard_normal((1, n, h, d)), dtype)
        q_seg = np.zeros((p,), np.int32)
        q_seg[7:] = -1                           # dead tail
        q_pos = np.arange(p, dtype=np.int32)
        k_seg = np.zeros((n,), np.int32)
        k_pos = np.arange(n, dtype=np.int32)
        out = layers.segment_attention(
            q, k, v, q_pos=jnp.asarray(q_pos)[None],
            k_pos=jnp.asarray(k_pos)[None], q_seg=jnp.asarray(q_seg)[None],
            k_seg=jnp.asarray(k_seg)[None])
        assert (np.asarray(out, np.float32)[0, 7:] == 0.0).all(), dtype


def test_segment_op_env_dispatch(rng, monkeypatch):
    """REPRO_SEGMENT_IMPL routes the op between the oracle and the
    interpreted kernel; both agree on live lanes."""
    p, n, h, d = 16, 32, 2, 8
    q = jnp.asarray(rng.standard_normal((p, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((n, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((n, h, d)), jnp.float32)
    q_pos, q_seg, k_pos, k_seg = _ragged_stream(rng, p, n, 2)
    outs = {}
    for impl in ("xla", "pallas_interpret"):
        monkeypatch.setenv("REPRO_SEGMENT_IMPL", impl)
        outs[impl] = segment_attention_op(q, k, v, q_pos, k_pos, q_seg,
                                          k_seg)
    err = float(jnp.max(jnp.abs(outs["xla"] - outs["pallas_interpret"])))
    assert err < 2e-5
    monkeypatch.setenv("REPRO_SEGMENT_IMPL", "bogus")
    with pytest.raises(ValueError, match="kernel impl"):
        segment_attention_op(q, k, v, q_pos, k_pos, q_seg, k_seg)
