"""Validates the committed dry-run artifacts: every assigned (arch x shape x
mesh) cell must have compiled (deliverable e/f), with coherent analysis."""

import glob
import json
import os

import pytest

from repro.configs import ARCH_IDS, cells

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def _expected_cells():
    out = []
    for aid in ARCH_IDS:
        for shape_name, _ in cells(aid):
            for mesh in ("pod16x16", "pod2x16x16"):
                out.append((mesh, aid, shape_name))
    return out


@pytest.mark.skipif(not glob.glob(os.path.join(ART_DIR, "*.json")),
                    reason="dry-run artifacts not generated yet")
def test_all_cells_compiled():
    missing, failed = [], []
    for mesh, aid, shape in _expected_cells():
        path = os.path.join(ART_DIR, f"{mesh}__{aid}__{shape}.json")
        if not os.path.exists(path):
            missing.append((mesh, aid, shape))
            continue
        rec = json.load(open(path))
        if not rec.get("ok"):
            failed.append((mesh, aid, shape, rec.get("error")))
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"


@pytest.mark.skipif(not glob.glob(os.path.join(ART_DIR, "*.json")),
                    reason="dry-run artifacts not generated yet")
def test_roofline_terms_sane():
    for path in glob.glob(os.path.join(ART_DIR, "*.json")):
        rec = json.load(open(path))
        if not rec.get("ok"):
            continue
        t = rec["roofline"]
        assert t["compute_s"] >= 0 and t["memory_s"] >= 0
        assert t["dominant"] in ("compute_s", "memory_s", "collective_s")
        assert rec["flops_per_chip"] > 0
        # multi-pod mesh has 512 chips, single 256
        assert rec["n_chips"] == (512 if rec["mesh"] == "pod2x16x16" else 256)
