"""Paged KV subsystem: allocator invariants, engine integration (token
parity vs dense, preemption under budget cuts, physical HBM actuation), and
the bench_serving smoke gate."""

import os
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.sensors import HBMAccountant
from repro.models import zoo
from repro.serve import PagedKVAllocator, Request, ServeEngine
from repro.serve.kv_cache import KVBlockPool


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    return cfg, params


def _alloc(cfg, *, capacity=8, bps=4, bt=16, accountant=None, budget=None):
    return PagedKVAllocator(cfg, block_tokens=bt, max_blocks_per_seq=bps,
                            capacity_blocks=capacity, budget_blocks=budget,
                            accountant=accountant)


# --------------------------------------------------------------- allocator
def test_allocator_block_reuse_after_free(small_model):
    cfg, _ = small_model
    pool = _alloc(cfg)
    ls1 = pool.lease(40)                         # 3 blocks
    assert ls1 is not None
    ids1 = [b for b in ls1.table_row() if b >= 0]
    assert len(ids1) == 3 and pool.used_blocks == 3
    ls1.release()
    assert pool.used_blocks == 0 and pool.free_blocks == 8
    ls2 = pool.lease(40)
    ids2 = [b for b in ls2.table_row() if b >= 0]
    assert ids2 == ids1                          # LIFO: freed ids come back

    ls2.release()
    ls2.release()                                # double release: no-op
    assert pool.used_blocks == 0 and pool.live_seqs == 0


def test_allocator_copy_free_admission(small_model):
    """Admitting a new sequence must not move any existing lease's
    blocks — tables are append-only until release/compact."""
    cfg, _ = small_model
    pool = _alloc(cfg)
    ls1 = pool.lease(30)
    before = ls1.table_row().copy()
    ls2 = pool.lease(50)
    assert ls2 is not None
    assert ls1.extend(60)                        # grow lease 1 itself
    after = ls1.table_row()
    np.testing.assert_array_equal(before[before >= 0],
                                  after[:len(before[before >= 0])])
    # distinct (unshared) leases never share physical blocks
    all_ids = [b for ls in (ls1, ls2) for b in ls.table_row() if b >= 0]
    assert len(all_ids) == len(set(all_ids))


def test_allocator_failure_keeps_accountant_consistent(small_model):
    """A failed lease/extend must change neither tables nor the HBM
    ledger; the ledger always equals capacity * block_bytes (physical
    store truth)."""
    cfg, _ = small_model
    acc = HBMAccountant()
    pool = _alloc(cfg, capacity=4, bps=4, accountant=acc)
    def store_bytes():
        return acc.breakdown().get("kv_cache", 0)
    assert store_bytes() == 4 * pool.block_bytes
    ls1 = pool.lease(48)                         # 3 of 4 blocks
    assert ls1 is not None
    assert store_bytes() == 4 * pool.block_bytes
    used0, frag0 = pool.used_blocks, pool.frag_tokens
    assert pool.lease(32) is None                # free list exhausted
    assert pool.alloc_failures == 1
    assert pool.used_blocks == used0 and pool.frag_tokens == frag0
    assert store_bytes() == 4 * pool.block_bytes  # ledger untouched
    # budget-blocked failure, same invariants
    pool.set_budget(3)
    assert pool.lease(16) is None
    assert pool.alloc_failures == 2
    assert store_bytes() == 4 * pool.block_bytes
    # a failed extend is atomic too: the lease keeps its original blocks
    ids = list(ls1.blocks)
    assert not ls1.extend(64)                    # +1 block > budget 3
    assert pool.alloc_failures == 3
    assert list(ls1.blocks) == ids
    assert store_bytes() == 4 * pool.block_bytes


def test_allocator_budget_shrink_and_compact(small_model):
    cfg, _ = small_model
    acc = HBMAccountant()
    pool = _alloc(cfg, capacity=8, bps=4, accountant=acc)
    ls1 = pool.lease(40)                         # 3 blocks
    ls2 = pool.lease(20)                         # 2 blocks
    pool.set_budget(3)
    assert pool.over_budget                      # 5 used > 3 budget
    ls2.release()
    assert not pool.over_budget
    old_ids = [b for b in ls1.table_row() if b >= 0]
    keep = pool.compact(4)
    assert pool.capacity == 4
    assert acc.breakdown()["kv_cache"] == 4 * pool.block_bytes  # HBM freed
    # remap correctness: new table slot j must point at old physical id
    new_ids = [b for b in ls1.table_row() if b >= 0]
    assert [keep[j] for j in new_ids] == old_ids
    assert pool.free_blocks == 4 - pool.used_blocks
    grown = pool.grow(8)
    assert grown == 4 and pool.capacity == 8
    assert acc.breakdown()["kv_cache"] == 8 * pool.block_bytes


def test_allocator_fragmentation_sensor(small_model):
    cfg, _ = small_model
    pool = _alloc(cfg, bt=16)
    ls = pool.lease(20)                          # 2 blocks = 32 tokens
    assert pool.frag_tokens == 12
    assert ls.extend(30)                         # same blocks, less waste
    assert pool.frag_tokens == 2
    ls.release()
    assert pool.frag_tokens == 0


def test_allocator_lease_surface_is_complete(small_model):
    """The KVLease handle API is the allocator's ONLY surface (the seed's
    seq_id-keyed ensure/free/table_row shim is gone).  Accounting parity:
    two independent leases are indistinguishable to the pool's sensors,
    growth is append-only, and release is idempotent."""
    cfg, _ = small_model
    pool = _alloc(cfg)
    for name in ("ensure", "free"):              # the shim did not survive
        assert not hasattr(pool, name)
    ls1 = pool.lease(40)                         # 3 blocks
    ids1 = [b for b in ls1.table_row() if b >= 0]
    ls2 = pool.lease(40)
    ids2 = [b for b in ls2.table_row() if b >= 0]
    assert len(ids1) == len(ids2) == 3
    assert not set(ids1) & set(ids2)             # disjoint physical blocks
    assert pool.used_blocks == 6 and pool.live_seqs == 2
    assert ls1.extend(50)                        # grow in place
    assert [b for b in ls1.table_row()
            if b >= 0][:3] == ids1               # append-only growth
    ls1.release()
    ls1.release()                                # double release: no-op
    ls2.release()
    assert pool.used_blocks == 0 and pool.live_seqs == 0


def test_allocator_lease_truncate(small_model):
    """KVLease.truncate drops whole trailing blocks past a token extent
    (the speculative-decode finish path): freed blocks return to the pool,
    the extent clamps, and a mid-block cut keeps the boundary block."""
    cfg, _ = small_model
    acc = HBMAccountant()
    pool = _alloc(cfg, capacity=8, bt=16, accountant=acc)
    ls = pool.lease(60)                          # 4 blocks
    assert pool.used_blocks == 4
    assert ls.truncate(33) == 1                  # 33 tokens -> 3 blocks
    assert pool.used_blocks == 3 and ls.tokens == 33
    assert ls.truncate(33) == 0                  # idempotent at the extent
    assert pool.frag_tokens == 15                # 3 blocks hold 33 tokens
    assert ls.truncate(16) == 2                  # exact boundary -> 1 block
    assert pool.used_blocks == 1 and ls.tokens == 16
    # the ledger tracks capacity, not leases: truncate moves used_blocks
    # and frag only
    assert acc.breakdown()["kv_cache"] == 8 * pool.block_bytes
    assert pool.frag_tokens == 0
    ls.release()
    assert pool.used_blocks == 0
    with pytest.raises(ValueError, match="released"):
        ls.truncate(0)


def test_dense_pool_pressure_sensors(small_model):
    """Satellite parity: the dense KVBlockPool exports the same
    over_budget / frag_tokens surface."""
    cfg, _ = small_model
    pool = KVBlockPool(cfg, block_tokens=16, max_blocks=4)
    assert pool.ensure(1, 20)
    assert pool.frag_tokens == 12
    assert not pool.over_budget
    pool.set_budget(1)
    assert pool.over_budget
    pool.free(1)
    assert pool.frag_tokens == 0 and not pool.over_budget


# ------------------------------------------------------------------ engine
def test_engine_paged_token_identical_to_dense(small_model, rng):
    """Acceptance: paged decode is token-identical to the dense path on an
    end-to-end serve run (mixed lengths, multi-chunk prefills, slot reuse)."""
    cfg, params = small_model
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 23, 37, 60)]
    outs = {}
    for mode in ("paged", "dense"):
        eng = ServeEngine(cfg, params, max_batch=3, cache_len=96,
                          enable_smartconf=False, kv_mode=mode)
        assert eng.paged == (mode == "paged")
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, 6))
        ticks = 0
        while len(eng.finished) < len(prompts) and ticks < 300:
            stats = eng.tick()
            ticks += 1
        assert len(eng.finished) == len(prompts), mode
        outs[mode] = {r.req_id: r.generated for r in eng.finished}
        for key in ("kv_used_blocks", "kv_over_budget", "kv_frag_tokens",
                    "kv_capacity_blocks", "preemptions"):
            assert key in stats                  # pool-pressure sensors
        eng.close()
    assert outs["paged"] == outs["dense"]


def test_engine_budget_cut_frees_hbm_and_preempts(small_model, rng):
    """Acceptance: a kv_block_budget cut on a paged engine preempts the
    lowest-priority (latest-scheduled) sequence back to the queue and
    physically shrinks the block store (hbm_bytes drops); the preempted
    request later finishes with its full, recomputed output."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=3, cache_len=96,
                      enable_smartconf=False, kv_mode="paged")
    for i in range(3):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 20)
                           .astype(np.int32), 40))
    for _ in range(5):
        eng.tick()
    assert len(eng.running) == 3
    order = sorted(eng.running.values(), key=lambda r: r.admit_seq)
    hbm0 = eng.hbm_bytes()
    eng.set_kv_budget(eng.blocks_per_seq)        # one sequence's worth
    eng.tick()
    assert eng.hbm_bytes() < hbm0, "cut must reduce physical hbm"
    assert eng.preemptions >= 1
    # LIFO preemption: the earliest-admitted request is still resident
    assert order[0].slot is not None and order[0].preempted == 0
    assert order[-1].preempted == 1 and order[-1].slot is None
    fails_while_cut = eng.pool.alloc_failures    # real rejections only
    eng.set_kv_budget(3 * eng.blocks_per_seq)    # restore
    ticks = 0
    while len(eng.finished) < 3 and ticks < 400:
        eng.tick()
        ticks += 1
    assert len(eng.finished) == 3
    assert all(len(r.generated) == 40 for r in eng.finished)
    # sensor hygiene across the preempt/readmit cycle: once the budget is
    # restored, regrowing the store for readmission is not an allocation
    # failure, and a preempted request contributes exactly one TTFT sample
    assert eng.pool.alloc_failures == fails_while_cut
    assert len(eng.ttft._buf) == 3
    eng.close()


def test_engine_paged_admission_is_copy_free(small_model, rng):
    """Scheduling a request into a paged engine touches block tables only:
    the store arrays (cache tree leaves) are not reallocated or copied."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=96,
                      enable_smartconf=False, kv_mode="paged")
    eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 8)
                       .astype(np.int32), 4))
    leaves_before = [id(x) for x in jax.tree.leaves(eng.caches)]
    eng._admit()
    eng._schedule()
    assert 0 in {r.req_id for r in eng.prefilling.values()}
    assert [id(x) for x in jax.tree.leaves(eng.caches)] == leaves_before
    eng.close()


def test_engine_paged_pallas_interpret_matches_xla(small_model, rng):
    """The real Pallas paged kernel (interpret mode), driven end-to-end
    through the engine, must reproduce the XLA oracle path token-for-token."""
    cfg, params = small_model
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (7, 30)]
    outs = {}
    for impl in ("xla", "pallas_interpret"):
        os.environ["REPRO_PAGED_IMPL"] = impl
        try:
            eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                              enable_smartconf=False, kv_mode="paged")
            for i, p in enumerate(prompts):
                eng.submit(Request(i, p, 4))
            ticks = 0
            while len(eng.finished) < len(prompts) and ticks < 100:
                eng.tick()
                ticks += 1
            assert len(eng.finished) == len(prompts), impl
            outs[impl] = {r.req_id: r.generated for r in eng.finished}
            eng.close()
        finally:
            os.environ.pop("REPRO_PAGED_IMPL", None)
    assert outs["xla"] == outs["pallas_interpret"]


# ------------------------------------------------------- bench smoke gate
def test_bench_serving_smoke():
    """Tier-1 gate on benchmarks/bench_serving.py: the smoke run exercises
    legacy+bucketed+packed prefill, paged+dense decode, and both budget-cut
    paths (with its own internal token-parity, packed-compile-count, and
    pad-fraction-drop assertions)."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import bench_serving

    rows = bench_serving.run(smoke=True)
    names = {r.split(",")[0] for r in rows}
    assert {"serving_prefill_legacy", "serving_prefill_bucketed",
            "serving_prefill_packed", "serving_packed_vs_bucketed",
            # unified prefill+decode ticks: dispatches/tick and the
            # fused-vs-split decode throughput comparison
            "serving_unified_ticks", "serving_decode_unified_vs_split",
            "serving_e2e_unified_vs_split",
            "serving_decode_paged", "serving_decode_dense",
            "serving_kv_budget_cut_paged",
            "serving_kv_budget_cut_dense",
            # universal chunked prefill: one recurrent + one MoE arch run
            # the full mode sweep (token-identity asserted inside the bench)
            "serving_arch_rwkv6_packed",
            "serving_arch_rwkv6_compile_reduction",
            "serving_arch_deepseek_packed",
            "serving_arch_deepseek_compile_reduction",
            # radix prefix cache: warm run token-identical to cold with
            # real hits, COW copies, and reclaimed prefill
            "serving_prefix_cache",
            # self-speculative decode: token-identical to k=0 with >1.3
            # emitted tokens per slot per dispatch on the repetitive regime
            "serving_speculative"} <= names
    cut = {r.split(",")[0]: r for r in rows}
    paged_freed = int(cut["serving_kv_budget_cut_paged"]
                      .split("freed=")[1].split()[0])
    dense_freed = int(cut["serving_kv_budget_cut_dense"]
                      .split("freed=")[1].split()[0])
    assert paged_freed > 0, "paged budget cut must free physical hbm"
    assert dense_freed == 0, "dense budget cut only moves the ledger"
    pc = cut["serving_prefix_cache"]
    assert "identical=True" in pc
    assert float(pc.split("hit_rate=")[1].split()[0]) > 0
    assert int(pc.split("reclaimed_tokens=")[1].split()[0]) > 0
    assert float(pc.split("prefill_reduction=")[1].split()[0]) >= 0.30
    sp = cut["serving_speculative"]
    assert "identical=True" in sp
    assert float(sp.split("tokens_per_slot_dispatch=")[1].split()[0]) > 1.3
    assert int(sp.split("max_dispatches=")[1].split()[0]) == 1