"""Mesh serving: tensor-parallel packed ticks on a fake 8-device host.

The heavy acceptance suite runs in a subprocess (``XLA_FLAGS=--xla_force_
host_platform_device_count=8`` must be set before jax imports, which a
pytest worker that already imported jax cannot do):

* every text arch serves token-identically under a ``2x4`` mesh vs
  single-device — dense AND paged KV, speculation on, prefix cache +
  copy-on-write live where supported;
* a paged store's per-shard HBM gauge times the shard count equals the
  single-device total exactly;
* a mid-flight ``serve.kv_block_budget`` cut (the eager ``jnp.take``
  shrink + re-place path) stays token-identical under the mesh;
* an arch the model axis cannot shard (MQA ``kv_heads=1``) degrades to
  single-device with a warning when the mesh came from ``REPRO_SERVE_MESH``
  and raises when it was requested explicitly.

The cheap validation paths (spec parsing, infeasibility messages) run
in-process below.
"""

import os
import subprocess
import sys

import pytest

from repro.serve.block_store import parse_mesh_spec

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# explicit configuration only: this suite passes meshes/modes per run, and
# interpreted-kernel or telemetry overrides would multiply the 8-arch
# matrix's runtime without adding mesh coverage
for _v in ("REPRO_SERVE_MESH", "REPRO_PREFILL_MODE", "REPRO_SPEC_DEPTH",
           "REPRO_TELEMETRY", "REPRO_ATTN_IMPL", "REPRO_PAGED_IMPL",
           "REPRO_SEGMENT_IMPL", "REPRO_RWKV6_IMPL", "REPRO_RGLRU_IMPL"):
    os.environ.pop(_v, None)
import sys
sys.path.insert(0, "SRCPATH")

import dataclasses
import warnings

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import zoo
from repro.serve import Request, ServeEngine, ServeOptions

assert len(jax.devices()) == 8

TEXT = [a for a in ARCH_IDS if a not in ("whisper-tiny", "internvl2-1b")]
MAX_NEW = 6


def smoke_cfg(arch):
    cfg = reduced(get_config(arch))
    if cfg.moe:   # ample capacity -> deterministic routing for equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


def run(cfg, params, prompts, opts, budget_cut_tick=None):
    eng = ServeEngine(cfg, params, options=opts)
    for i, p in enumerate(prompts):
        assert eng.submit(Request(i, p, MAX_NEW))
    t, shards = 0, None
    while len(eng.finished) < len(prompts) and t < 300:
        if budget_cut_tick is not None and t == budget_cut_tick:
            eng.set_kv_budget(max(2, eng.pool.max_blocks // 2))
        st = eng.tick()
        t += 1
        shards = st["tp_shards"]
    assert len(eng.finished) == len(prompts), (cfg.name, t)
    outs = {r.req_id: list(r.generated) for r in eng.finished}
    ksb, paged = eng.kv_shard_bytes(), eng.paged
    eng.close()
    return outs, shards, ksb, paged


def opt(mesh, kv="auto", prefix=False, spec=2):
    return ServeOptions(max_batch=2, cache_len=64, enable_smartconf=False,
                        prefill_mode="packed", kv_mode=kv, spec_depth=spec,
                        prefix_cache=prefix, mesh=mesh)


# ---- 1. TP packed ticks token-identical to single-device, all text archs ---
for arch in TEXT:
    cfg = smoke_cfg(arch)
    params, _ = zoo.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (9, 17, 26)]
    prompts[1][:8] = prompts[0][:8]     # shared prefix: radix hits + COW
    prefix = zoo.supports_paged_kv(cfg)
    base, s0, k0, paged = run(cfg, params, prompts, opt(None, prefix=prefix))
    assert s0 == 1
    if cfg.num_kv_heads % 4 == 0:
        tp, s1, k1, _ = run(cfg, params, prompts, opt("2x4", prefix=prefix))
        assert s1 == 4, arch
        if paged:   # paged stores are pure K/V planes: shards sum exactly
            assert k1 * 4 == k0, (arch, k0, k1)
    else:
        # kv_heads the model axis cannot divide: the env-forced request
        # (the CI leg) degrades to single-device with a loud warning
        forced = opt(None, prefix=prefix).resolve(
            env={"REPRO_SERVE_MESH": "2x4"})
        assert forced.mesh == "2x4" and forced.mesh_env_forced
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            tp, s1, _, _ = run(cfg, params, prompts, forced)
        assert s1 == 1, arch
        assert any("single-device" in str(w.message) for w in caught), arch
    assert base == tp, arch
    print("tp-identity OK", arch, "paged" if paged else "dense",
          "shards", s1)

# ---- 2. explicit dense KV under TP (rings shard on the Kv dim too) ---------
for arch in ("yi-6b", "deepseek-moe-16b"):
    cfg = smoke_cfg(arch)
    params, _ = zoo.init(cfg, jax.random.key(0))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 21)]
    base, _, _, p0 = run(cfg, params, prompts, opt(None, kv="dense"))
    tp, s1, _, p1 = run(cfg, params, prompts, opt("2x4", kv="dense"))
    assert not p0 and not p1 and s1 == 4
    assert base == tp, arch
    print("tp-dense OK", arch)

# ---- 3. kv budget actuation mid-flight stays identical + sharded -----------
cfg = smoke_cfg("yi-6b")
params, _ = zoo.init(cfg, jax.random.key(0))
rng = np.random.default_rng(13)
prompts = [rng.integers(1, cfg.vocab_size, n).astype(np.int32)
           for n in (12, 18, 25)]
base, _, _, _ = run(cfg, params, prompts, opt(None), budget_cut_tick=4)
tp, s1, _, _ = run(cfg, params, prompts, opt("2x4"), budget_cut_tick=4)
assert s1 == 4 and base == tp, (base, tp)
print("tp-budget-cut OK")

# ---- 4. infeasible explicit mesh raises actionably --------------------------
try:
    ServeEngine(cfg, params, options=opt("4x4"))
except ValueError as e:
    assert "16 devices" in str(e), e
else:
    raise AssertionError("4x4 on 8 devices should raise")
try:
    ServeEngine(smoke_cfg("recurrentgemma-9b"),
                zoo.init(smoke_cfg("recurrentgemma-9b"), jax.random.key(0))[0],
                options=opt("2x4"))
except ValueError as e:
    assert "kv_heads" in str(e), e
else:
    raise AssertionError("indivisible kv_heads should raise when explicit")
print("mesh-validation OK")
print("ALL-MESH-SERVE-OK")
"""


def test_mesh_serve_suite(tmp_path):
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    script = _SCRIPT.replace("SRCPATH", os.path.abspath(src))
    path = tmp_path / "mesh_serve.py"
    path.write_text(script)
    proc = subprocess.run([sys.executable, str(path)], capture_output=True,
                          text=True, timeout=1800)
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "ALL-MESH-SERVE-OK" in proc.stdout


# ---- cheap in-process validation (no devices needed) -----------------------

def test_parse_mesh_spec():
    assert parse_mesh_spec("2x4") == (2, 4)
    assert parse_mesh_spec("1X1") == (1, 1)
    assert parse_mesh_spec(" 8 x 1 ") == (8, 1)
    for bad in ("2x", "x4", "2x4x1", "ax b", "2"):
        with pytest.raises(ValueError, match="DxM"):
            parse_mesh_spec(bad)
    with pytest.raises(ValueError, match=">= 1"):
        parse_mesh_spec("0x4")


def test_build_serve_mesh_infeasible_reasons():
    import warnings

    from repro.serve.block_store import build_serve_mesh

    # single visible device: any real mesh is infeasible -> explicit raises
    with pytest.raises(ValueError, match="devices"):
        build_serve_mesh("2x4", heads=4, kv_heads=4,
                         prefill_impl="packed", env_forced=False)
    with pytest.raises(ValueError, match="packed"):
        build_serve_mesh("1x1", heads=4, kv_heads=4,
                         prefill_impl="bucketed", env_forced=False)
    # env-forced degrades to None with a warning naming the env var
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        mesh = build_serve_mesh("2x4", heads=4, kv_heads=1,
                                prefill_impl="packed", env_forced=True)
    assert mesh is None
    assert any("REPRO_SERVE_MESH" in str(w.message) for w in caught)
