"""Per-arch smoke tests (reduced configs) + prefill/decode equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.models import transformer, zoo

SMOKE = ShapeConfig("smoke", 64, 2, "train")


def _smoke_cfg(arch_id):
    cfg = reduced(get_config(arch_id))
    if cfg.moe:   # ample capacity -> deterministic routing for equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id, rng):
    cfg = _smoke_cfg(arch_id)
    params, axes = zoo.init(cfg, jax.random.key(0))
    batch = zoo.make_batch(cfg, SMOKE, rng)
    loss, parts = jax.jit(lambda p, b: zoo.loss_fn(cfg, p, b))(params, batch)
    assert jnp.isfinite(loss), f"{arch_id} loss not finite"
    assert 0.0 < float(loss) < 20.0
    # gradients flow and are finite
    g = jax.grad(lambda p: zoo.loss_fn(cfg, p, batch)[0])(params)
    flat = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in flat)
    assert any(float(jnp.max(jnp.abs(x))) > 0 for x in flat), "all-zero grads"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_shapes(arch_id, rng):
    cfg = _smoke_cfg(arch_id)
    params, _ = zoo.init(cfg, jax.random.key(0))
    batch = zoo.make_batch(cfg, SMOKE, rng)
    x, aux = transformer.forward(cfg, params, batch)
    assert x.shape[0] == SMOKE.global_batch
    assert x.shape[-1] == cfg.d_model
    assert bool(jnp.all(jnp.isfinite(x)))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_equals_forward(arch_id, rng):
    """The decode path (ring caches, recurrent states, cross-attn caches)
    must agree with the full-sequence forward at the last position."""
    cfg = _smoke_cfg(arch_id)
    params, _ = zoo.init(cfg, jax.random.key(1))
    B, S = 2, 33   # odd length exercises ring wrap (window 32)
    st = S - (cfg.num_patches if cfg.frontend == "vision" else 0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, st)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_patches, cfg.frontend_dim)),
            jnp.float32)
    if cfg.encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.float32)

    x, _ = transformer.forward(cfg, params, batch)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    full_logits = (x[:, -1] @ head).astype(jnp.float32)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    _, caches = transformer.prefill(cfg, params, pre, cache_len=2 * S)
    npatch = cfg.num_patches if cfg.frontend == "vision" else 0
    pos = jnp.full((B,), st - 1 + npatch, jnp.int32)
    dec_logits, _ = transformer.decode_step(cfg, params, caches,
                                            batch["tokens"][:, -1], pos)
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-9
    err = float(jnp.max(jnp.abs(full_logits - dec_logits))) / scale
    assert err < 5e-3, f"{arch_id}: prefill/decode mismatch rel={err:.2e}"


@pytest.mark.parametrize("arch_id,chunk", [
    ("yi-6b", 8),                # full attention, multi-chunk
    ("h2o-danube-3-4b", 8),      # swa ring cache, chunk < window
    ("h2o-danube-3-4b", 48),     # chunk > ring size (write-back tail)
    ("gemma3-4b", 16),           # local/global mixed pattern
])
def test_chunked_prefill_matches_one_shot(arch_id, chunk, rng):
    """Padded, bucketed, chunk-at-a-time prefill into a live fused cache must
    reproduce the one-shot prefill logits and leave an equivalent cache."""
    cfg = _smoke_cfg(arch_id)
    assert transformer.supports_chunked_prefill(cfg)
    params, _ = zoo.init(cfg, jax.random.key(1))
    L, cache_len = 50, 64
    prompt = rng.integers(0, cfg.vocab_size, L).astype(np.int32)
    ref_logits, ref_caches = transformer.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None])},
        cache_len=cache_len)

    # two fused rows: row 0 carries the prompt, row 1 stays inactive
    caches = zoo.init_cache(cfg, 2, cache_len)
    logits = None
    for s in range(0, L, chunk):
        n = min(chunk, L - s)
        tok = np.zeros((2, chunk), np.int32)
        tok[0, :n] = prompt[s:s + n]
        logits, caches = transformer.prefill_chunk(
            cfg, params, caches, jnp.asarray(tok),
            jnp.asarray([s, 0], jnp.int32), jnp.asarray([n, 0], jnp.int32))
    scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-9
    err = float(jnp.max(jnp.abs(logits[0] - ref_logits[0]))) / scale
    assert err < 5e-3, f"{arch_id} chunk={chunk}: prefill rel={err:.2e}"

    # decode one step from both caches; the inactive row must not interfere
    tok = jnp.asarray([int(jnp.argmax(ref_logits[0]))] * 2, jnp.int32)
    d_ref, _ = transformer.decode_step(cfg, params, ref_caches, tok[:1],
                                       jnp.asarray([L], jnp.int32))
    d_chk, _ = transformer.decode_step(cfg, params, caches, tok,
                                       jnp.asarray([L, 0], jnp.int32),
                                       active=jnp.asarray([True, False]))
    scale = float(jnp.max(jnp.abs(d_ref))) + 1e-9
    err = float(jnp.max(jnp.abs(d_chk[0] - d_ref[0]))) / scale
    assert err < 5e-3, f"{arch_id} chunk={chunk}: decode rel={err:.2e}"


def test_chunked_prefill_gates_unsupported():
    # universal chunked prefill: only the modality frontends stay one-shot
    for arch_id in ("whisper-tiny", "internvl2-1b"):
        assert not transformer.supports_chunked_prefill(
            reduced(get_config(arch_id))), arch_id
    # recurrent / hybrid / MoE families joined the fast path
    for arch_id in ("rwkv6-7b", "recurrentgemma-9b", "deepseek-moe-16b",
                    "llama4-maverick-400b-a17b"):
        assert transformer.supports_chunked_prefill(
            reduced(get_config(arch_id))), arch_id
    # paged KV needs attention-only blocks: MoE yes, recurrent no
    for arch_id, expect in (("deepseek-moe-16b", True),
                            ("llama4-maverick-400b-a17b", True),
                            ("rwkv6-7b", False),
                            ("recurrentgemma-9b", False),
                            ("whisper-tiny", False)):
        assert transformer.supports_paged_kv(
            reduced(get_config(arch_id))) is expect, arch_id


def test_moe_matches_reference(rng):
    from repro.models import moe as moe_lib
    cfg = _smoke_cfg("deepseek-moe-16b")
    params, _ = moe_lib.moe_init(jax.random.key(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)) * 0.5, jnp.float32)
    out = moe_lib.moe_apply(params, x, cfg)
    ref = moe_lib.moe_reference(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-4)


def test_moe_capacity_drops_are_bounded(rng):
    import dataclasses as dc
    from repro.models import moe as moe_lib
    cfg = dc.replace(_smoke_cfg("deepseek-moe-16b"), capacity_factor=1.0)
    params, _ = moe_lib.moe_init(jax.random.key(0), cfg)
    x = jnp.asarray(rng.standard_normal((1, 64, cfg.d_model)), jnp.float32)
    out, aux = moe_lib.moe_apply(params, x, cfg, return_aux=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.0   # load-balance loss reported


def test_param_count_analytic_close(rng):
    """Analytic param_count tracks the real tree within 10%."""
    for arch_id in ("yi-6b", "rwkv6-7b", "deepseek-moe-16b"):
        cfg = _smoke_cfg(arch_id)
        params, _ = zoo.init(cfg, jax.random.key(0))
        real = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        approx = cfg.param_count()
        assert abs(real - approx) / real < 0.15, (arch_id, real, approx)


def test_long_context_gate():
    from repro.configs import cells
    for aid in ARCH_IDS:
        names = [s for s, _ in cells(aid)]
        cfg = get_config(aid)
        assert ("long_500k" in names) == cfg.supports_long_context
