"""Paged decode-attention kernel (interpret mode) vs the jnp oracle and the
dense decode kernel: head dims, MQA/GQA group sizes, dtypes, ragged lengths,
out-of-order block tables."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref,
                                            padded_cache_len)
from repro.kernels.paged_attention import (paged_decode_attention,
                                           paged_decode_attention_op,
                                           paged_decode_attention_ref)

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOLS[jnp.bfloat16] if dtype == jnp.bfloat16 else TOLS[jnp.float32]


def _paged_setup(rng, b, kv, d, t, m, n_blocks=32, *, shuffle=True):
    """Random block store + ragged per-row tables (out-of-order physical ids,
    -1 holes past each row's extent)."""
    ks = rng.standard_normal((n_blocks, kv, t, d))
    vs = rng.standard_normal((n_blocks, kv, t, d))
    bt = np.full((b, m), -1, np.int32)
    lens = rng.integers(1, m * t + 1, b)
    for i in range(b):
        nb = -(-int(lens[i]) // t)
        ids = rng.choice(n_blocks, nb, replace=False)
        if not shuffle:
            ids = np.sort(ids)
        bt[i, :nb] = ids
    return ks, vs, bt, lens


@pytest.mark.parametrize("b,h,kv,d,t,m", [
    (2, 4, 2, 64, 16, 6),      # GQA
    (1, 8, 1, 64, 8, 8),       # MQA
    (2, 4, 4, 32, 16, 4),      # MHA
    (1, 2, 2, 128, 16, 6),     # wide head dim
])
@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kernel_vs_ref(rng, b, h, kv, d, t, m, window, dtype):
    ks, vs, bt, lens = _paged_setup(rng, b, kv, d, t, m)
    q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
    ks, vs = jnp.asarray(ks, dtype), jnp.asarray(vs, dtype)
    bt = jnp.asarray(bt)
    qpos = jnp.asarray(lens - 1, jnp.int32)     # ragged: row i sees lens[i]
    out = paged_decode_attention(q, ks, vs, bt, qpos, window=window,
                                 interpret=True)
    ref = paged_decode_attention_ref(q, ks, vs, bt, qpos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("b,h,kv,d,t,m,window", [
    (2, 4, 2, 64, 16, 8, 0), (1, 4, 1, 32, 16, 8, 48),
])
def test_paged_matches_dense_decode_attention(rng, b, h, kv, d, t, m, window):
    """The paged kernel over a scattered block store must agree with the
    dense kernel over the equivalent contiguous [B, Kv, S, D] cache."""
    ks, vs, bt, lens = _paged_setup(rng, b, kv, d, t, m)
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    qpos = jnp.asarray(lens - 1, jnp.int32)

    # densify: logical slot p of row i <- store[bt[i, p//t], :, p%t]
    s = m * t
    k_dense = np.zeros((b, kv, s, d), np.float32)
    v_dense = np.zeros((b, kv, s, d), np.float32)
    k_pos = np.full((b, s), -1, np.int32)
    for i in range(b):
        for p in range(int(lens[i])):
            blk = bt[i, p // t]
            k_dense[i, :, p] = ks[blk, :, p % t]
            v_dense[i, :, p] = vs[blk, :, p % t]
            k_pos[i, p] = p
    out_paged = paged_decode_attention(
        q, jnp.asarray(ks, jnp.float32), jnp.asarray(vs, jnp.float32),
        jnp.asarray(bt), qpos, window=window, interpret=True)
    out_dense = decode_attention(
        q, jnp.asarray(k_dense), jnp.asarray(v_dense), jnp.asarray(k_pos),
        qpos, window=window, block_kv=64, interpret=True)
    ref_dense = decode_attention_ref(
        q, jnp.asarray(k_dense), jnp.asarray(v_dense), jnp.asarray(k_pos),
        qpos, window=window)
    np.testing.assert_allclose(np.asarray(out_paged), np.asarray(ref_dense),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(out_paged), np.asarray(out_dense),
                               atol=2e-5, rtol=2e-5)


def test_paged_empty_table_is_finite(rng):
    """A row with an all--1 table (no blocks yet) must produce finite output
    (l == 0 guard), not NaNs."""
    q = jnp.asarray(rng.standard_normal((1, 4, 64)), jnp.float32)
    ks = jnp.zeros((8, 2, 16, 64), jnp.float32)
    vs = jnp.zeros((8, 2, 16, 64), jnp.float32)
    bt = jnp.full((1, 4), -1, jnp.int32)
    out = paged_decode_attention(q, ks, vs, bt, jnp.asarray([5], jnp.int32),
                                 interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_paged_op_dispatch(rng):
    """force='xla' and force='pallas_interpret' must agree through the op."""
    ks, vs, bt, lens = _paged_setup(rng, 2, 2, 32, 16, 4)
    q = jnp.asarray(rng.standard_normal((2, 4, 32)), jnp.float32)
    args = (q, jnp.asarray(ks, jnp.float32), jnp.asarray(vs, jnp.float32),
            jnp.asarray(bt), jnp.asarray(lens - 1, jnp.int32))
    a = paged_decode_attention_op(*args, force="xla")
    b_ = paged_decode_attention_op(*args, force="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               atol=2e-5, rtol=2e-5)


def test_padded_cache_len():
    """Sizing helper: lengths above one KV tile round up to a tile multiple
    so the dense decode kernel never re-pads K/V on the hot path."""
    assert padded_cache_len(96) == 96          # below one tile: unchanged
    assert padded_cache_len(512) == 512
    assert padded_cache_len(513) == 1024
    assert padded_cache_len(600, block_kv=128) == 640
    assert padded_cache_len(64, block_kv=128) == 64