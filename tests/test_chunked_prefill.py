"""Universal chunked prefill: token-identity against the one-shot oracle for
EVERY zoo arch (attention, recurrent, hybrid, MoE), under the adversarial
schedule the serve engine produces — ragged per-row lengths, chunk widths
that do not divide the prompt, chunk boundaries mid-row, and rows going
inactive at different ticks.  The two modality-frontend archs must refuse
loudly instead of silently falling back."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import transformer, zoo

CACHE_LEN = 64
# ragged: three rows, none a multiple of the chunk width, all with a chunk
# boundary mid-row; row 2 finishes first and must sit inactive afterwards
ROW_LENS = (50, 37, 11)
CHUNK = 13


def _smoke_cfg(arch_id):
    cfg = reduced(get_config(arch_id))
    if cfg.moe:   # ample capacity -> deterministic routing for equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


def _run_chunked(cfg, params, prompts, chunk, cache_len):
    """Engine-shaped schedule: every call advances each unfinished row by up
    to ``chunk`` tokens; finished rows ride along with length 0.  Returns
    (per-row completion logits, caches)."""
    b = len(prompts)
    caches = zoo.init_cache(cfg, b, cache_len)
    prefilled = [0] * b
    done_logits = {}
    while any(prefilled[i] < len(prompts[i]) for i in range(b)):
        tok = np.zeros((b, chunk), np.int32)
        start = np.zeros((b,), np.int32)
        lengths = np.zeros((b,), np.int32)
        for i, p in enumerate(prompts):
            n = min(chunk, len(p) - prefilled[i])
            tok[i, :n] = p[prefilled[i]:prefilled[i] + n]
            start[i] = prefilled[i]
            lengths[i] = n
        logits, caches = transformer.prefill_chunk(
            cfg, params, caches, jnp.asarray(tok), jnp.asarray(start),
            jnp.asarray(lengths))
        for i, p in enumerate(prompts):
            prefilled[i] += int(lengths[i])
            if prefilled[i] >= len(p) and i not in done_logits:
                done_logits[i] = logits[i]
    return done_logits, caches


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_chunked_prefill_matches_one_shot_every_arch(arch_id, rng):
    cfg = _smoke_cfg(arch_id)
    if cfg.encoder_decoder or cfg.frontend == "vision":
        # modality prefixes stay one-shot — and refuse loudly, not silently
        assert not transformer.supports_chunked_prefill(cfg)
        with pytest.raises(ValueError, match="chunked prefill"):
            transformer.prefill_chunk(
                cfg, None, None, jnp.zeros((1, 4), jnp.int32),
                jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32))
        return

    assert transformer.supports_chunked_prefill(cfg), arch_id
    params, _ = zoo.init(cfg, jax.random.key(1))
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in ROW_LENS]

    refs = [transformer.prefill(cfg, params, {"tokens": jnp.asarray(p[None])},
                                cache_len=CACHE_LEN) for p in prompts]
    done_logits, caches = _run_chunked(cfg, params, prompts, CHUNK, CACHE_LEN)

    for i, (ref_logits, _) in enumerate(refs):
        scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-9
        err = float(jnp.max(jnp.abs(done_logits[i] - ref_logits[0]))) / scale
        assert err < 5e-3, f"{arch_id} row {i}: prefill rel={err:.2e}"

    # one decode step from both caches: the fused cache must carry every
    # row's exact state (attention K/V, recurrent scan state, token shifts)
    tok = jnp.asarray([int(jnp.argmax(r[0][0])) for r in refs], jnp.int32)
    pos = jnp.asarray([len(p) for p in prompts], jnp.int32)
    d_chk, _ = transformer.decode_step(cfg, params, caches, tok, pos)
    for i, (_, ref_caches) in enumerate(refs):
        d_ref, _ = transformer.decode_step(cfg, params, ref_caches,
                                           tok[i:i + 1], pos[i:i + 1])
        scale = float(jnp.max(jnp.abs(d_ref))) + 1e-9
        err = float(jnp.max(jnp.abs(d_chk[i] - d_ref[0]))) / scale
        assert err < 5e-3, f"{arch_id} row {i}: decode rel={err:.2e}"


@pytest.mark.parametrize("arch_id", ["rwkv6-7b", "recurrentgemma-9b"])
def test_recurrent_chunk_state_resets_on_slot_reuse(arch_id, rng):
    """A row restarting at position 0 (slot handed to a new request, or a
    preempted request recomputing) must begin from zero scan state, not the
    previous occupant's."""
    cfg = _smoke_cfg(arch_id)
    params, _ = zoo.init(cfg, jax.random.key(1))
    p1 = rng.integers(0, cfg.vocab_size, 21).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 17).astype(np.int32)

    # occupy row 0 with p1, then reuse it for p2 without clearing the cache
    caches = zoo.init_cache(cfg, 1, CACHE_LEN)
    _, caches = _run_chunked_into(cfg, params, caches, p1)
    logits, _ = _run_chunked_into(cfg, params, caches, p2)

    ref_logits, _ = transformer.prefill(
        cfg, params, {"tokens": jnp.asarray(p2[None])}, cache_len=CACHE_LEN)
    scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-9
    err = float(jnp.max(jnp.abs(logits[0] - ref_logits[0]))) / scale
    assert err < 5e-3, f"{arch_id}: stale state leaked, rel={err:.2e}"


def _run_chunked_into(cfg, params, caches, prompt, chunk=8):
    logits = None
    for s in range(0, len(prompt), chunk):
        n = min(chunk, len(prompt) - s)
        tok = np.zeros((1, chunk), np.int32)
        tok[0, :n] = prompt[s:s + n]
        logits, caches = transformer.prefill_chunk(
            cfg, params, caches, jnp.asarray(tok),
            jnp.asarray([s], jnp.int32), jnp.asarray([n], jnp.int32))
    return logits, caches


def test_moe_chunk_pads_cannot_steal_capacity(rng):
    """With tight capacity, the routed output at valid positions must be
    independent of whatever garbage sits in the pad tail — i.e. pads consume
    no expert slots (the failure mode that kept MoE off the chunked path)."""
    from repro.models import moe as moe_lib
    cfg = dataclasses.replace(reduced(get_config("deepseek-moe-16b")),
                              capacity_factor=1.0)
    params, _ = moe_lib.moe_init(jax.random.key(0), cfg)
    b, s, nv = 2, 32, 20                     # 12 pad tokens per row
    x_real = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
    valid = np.zeros((b, s), bool)
    valid[:, :nv] = True

    outs = []
    for fill in (0.0, 7.0, -3.0):
        x = x_real.copy()
        x[:, nv:] = fill                     # adversarial pad contents
        outs.append(np.asarray(
            moe_lib.moe_apply(params, jnp.asarray(x), cfg,
                              valid=jnp.asarray(valid)))[:, :nv])
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-6)

    # ...nor can padding INFLATE capacity: the drop threshold must scale
    # with the valid-token count, so the padded chunk routes exactly like
    # the unpadded batch (same group, same token order, same capacity)
    ref = np.asarray(moe_lib.moe_apply(
        params, jnp.asarray(x_real[:, :nv]), cfg))
    np.testing.assert_allclose(outs[0], ref, atol=1e-5, rtol=1e-5)


def test_engine_bucketed_matches_legacy_recurrent_slot_reuse(rng):
    """End-to-end engine check on a hybrid recurrent arch with more requests
    than slots: bucketed chunked prefill (with slot reuse and interleaved
    decode) must generate token-identical output to the legacy engine."""
    from repro.serve import Request, ServeEngine
    cfg = _smoke_cfg("recurrentgemma-9b")
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 23, 31, 45)]
    outs = {}
    for mode in ("legacy", "bucketed"):
        eng = ServeEngine(cfg, params, max_batch=2, cache_len=96,
                          enable_smartconf=False, prefill_mode=mode)
        eng.prefill_chunk = 16          # force mid-prompt chunk boundaries
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, 6))
        ticks = 0
        while len(eng.finished) < len(prompts) and ticks < 400:
            eng.tick()
            ticks += 1
        assert len(eng.finished) == len(prompts), mode
        outs[mode] = {r.req_id: list(r.generated) for r in eng.finished}
        eng.close()
    assert outs["legacy"] == outs["bucketed"]


def test_recurrent_chunk_dispatches_pallas_kernels(rng, monkeypatch):
    """REPRO_RWKV6_IMPL / REPRO_RGLRU_IMPL = pallas_interpret must route the
    chunked-prefill scan through the state-in/state-out Pallas kernels and
    still match the one-shot oracle."""
    monkeypatch.setenv("REPRO_RWKV6_IMPL", "pallas_interpret")
    monkeypatch.setenv("REPRO_RGLRU_IMPL", "pallas_interpret")
    for arch_id in ("rwkv6-7b", "recurrentgemma-9b"):
        cfg = _smoke_cfg(arch_id)
        params, _ = zoo.init(cfg, jax.random.key(1))
        prompt = rng.integers(0, cfg.vocab_size, 37).astype(np.int32)
        ref_logits, _ = transformer.prefill(
            cfg, params, {"tokens": jnp.asarray(prompt[None])},
            cache_len=CACHE_LEN)
        caches = zoo.init_cache(cfg, 1, CACHE_LEN)
        logits, _ = _run_chunked_into(cfg, params, caches, prompt, chunk=16)
        scale = float(jnp.max(jnp.abs(ref_logits))) + 1e-9
        err = float(jnp.max(jnp.abs(logits[0] - ref_logits[0]))) / scale
        assert err < 5e-3, f"{arch_id} pallas_interpret: rel={err:.2e}"
