"""Integration: serve engine under SmartConf control; trainer restart."""

import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.smartconf import ConfRegistry
from repro.models import zoo
from repro.optim import adamw
from repro.serve import Request, ServeEngine
from repro.serve.kv_cache import KVBlockPool, kv_bytes_per_token
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    return cfg, params


def _weight_bytes(params):
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(params))


def test_engine_completes_all_requests(small_model, rng):
    cfg, params = small_model
    budget = _weight_bytes(params) + 3_000_000
    eng = ServeEngine(cfg, params, max_batch=3, cache_len=96,
                      hbm_budget_bytes=budget, block_tokens=16)
    for i in range(8):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 20)
                           .astype(np.int32), 10))
    for _ in range(80):
        eng.tick()
    assert len(eng.finished) == 8
    assert all(len(r.generated) == 10 for r in eng.finished)
    assert eng.accountant.violations == 0
    eng.close()


def test_engine_hbm_constraint_respected_under_pressure(small_model, rng):
    """Tight budget: the interacting queue/KV controllers must keep HBM under
    the hard goal while still making progress."""
    cfg, params = small_model
    budget = _weight_bytes(params) + 600_000   # very tight
    eng = ServeEngine(cfg, params, max_batch=4, cache_len=128,
                      hbm_budget_bytes=budget, block_tokens=16)
    for i in range(12):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 32)
                           .astype(np.int32), 8))
    for _ in range(200):
        eng.tick()
        assert eng.hbm_bytes() <= budget, "hard HBM goal violated"
    assert len(eng.finished) >= 4, "no progress under budget pressure"
    eng.close()


def test_engine_interacting_controllers_share_metric(small_model):
    cfg, params = small_model
    budget = _weight_bytes(params) + 2_000_000
    reg = ConfRegistry()
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      hbm_budget_bytes=budget, registry=reg)
    # both PerfConfs registered on hbm_bytes -> interaction factor N = 2
    peers = reg.peers("hbm_bytes")
    assert len(peers) == 2
    assert all(p.controller.n_interacting == 2 for p in peers)
    eng.close()


def test_chunked_prefill_interleaves_decode(small_model, rng):
    """A prompt longer than ``serve.prefill_chunk_tokens`` must prefill over
    multiple chunk calls, with decode ticks for other slots in between — the
    SmartConf soft knob actuates real scheduling behavior."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=96,
                      enable_smartconf=False, prefill_mode="bucketed")
    eng.prefill_chunk = 16          # the soft-knob actuation point
    short = Request(0, rng.integers(0, cfg.vocab_size, 8).astype(np.int32), 30)
    eng.submit(short)
    eng.tick()                      # short req prefills whole and starts decoding
    assert short.gen_count >= 1 and short.prefill_chunks == 1
    long = Request(1, rng.integers(0, cfg.vocab_size, 60).astype(np.int32), 4)
    eng.submit(long)
    decoded_during_prefill = []
    while long.prefilled < len(long.prompt):
        before = short.gen_count
        eng.tick()
        decoded_during_prefill.append(short.gen_count - before)
    assert long.prefill_chunks == 4          # ceil(60 / 16) chunk calls
    assert long.first_token_t is not None
    # every prefill chunk tick also advanced the short request's decode
    assert all(d >= 1 for d in decoded_during_prefill)
    for _ in range(60):
        eng.tick()
    assert len(eng.finished) == 2
    assert len(long.generated) == 4
    eng.close()


def test_bucketed_prefill_matches_legacy_and_reuses_compiles(small_model, rng):
    """Mixed prompt lengths: the bucketed engine must produce token-identical
    greedy output while compiling >=2x fewer prefill programs."""
    cfg, params = small_model
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 7, 9, 12, 19, 23, 26, 31, 37, 45)]
    outs, compiles = {}, {}
    for mode in ("bucketed", "legacy"):
        eng = ServeEngine(cfg, params, max_batch=3, cache_len=96,
                          enable_smartconf=False, prefill_mode=mode)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, 6))
        ticks = 0
        while len(eng.finished) < len(prompts) and ticks < 300:
            eng.tick()
            ticks += 1
        assert len(eng.finished) == len(prompts), mode
        outs[mode] = {r.req_id: r.generated for r in eng.finished}
        compiles[mode] = eng.prefill_compiles
        eng.close()
    assert outs["bucketed"] == outs["legacy"]
    assert compiles["legacy"] >= 2 * compiles["bucketed"], compiles


def test_engine_clock_injectable_deterministic_ttft(small_model, rng):
    """TTFT/latency sensing must be drivable by an injected clock — no
    sleeping, no wall-clock flake: the recorded TTFT is exactly the fake
    clock's delta between submit and the first-token tick."""
    cfg, params = small_model
    t = [100.0]
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=64,
                      enable_smartconf=False, clock=lambda: t[0])
    req = Request(0, rng.integers(0, cfg.vocab_size, 8).astype(np.int32), 2)
    eng.submit(req)
    assert req.submitted_t == 100.0
    t[0] = 107.5
    eng.tick()
    assert req.first_token_t == 107.5
    assert eng.ttft.quantile(0.5) == 7.5
    assert eng.decode_latency.max() == 0.0     # same fake instant
    eng.close()


def test_fresh_engine_reports_zero_pad_fraction(small_model):
    """An engine that has issued zero prefill tokens has no padding: the
    cumulative property must report 0.0, not the 1.0 that
    ``1 - 0/max(1, 0)`` produced (the per-tick stat always guarded this;
    the cumulative one did not)."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=32,
                      enable_smartconf=False)
    assert eng.pad_fraction == 0.0
    eng.tick()                       # idle tick: still nothing issued
    assert eng.pad_fraction == 0.0
    eng.close()


def test_tick_vs_decode_latency_split(small_model, rng):
    """The sensor named ``decode_latency`` must record only the
    model-compute span of ticks that advanced a decoder — not the whole
    tick (admit + schedule + prefill + host bookkeeping) it used to record.
    ``tick_latency`` now carries the whole-tick span.  Mandatory once
    prefill and decode share one dispatch: ``sc_chunk`` acts on
    ``decode_latency.p99()``, and a controller cannot attribute latency to
    its own knob if the sensor mixes in admission work."""
    cfg, params = small_model
    t = [0.0]

    def clock():                     # strictly increasing fake clock
        t[0] += 1.0
        return t[0]

    for mode in ("packed", "bucketed"):
        eng = ServeEngine(cfg, params, max_batch=1, cache_len=96,
                          enable_smartconf=False, prefill_mode=mode,
                          clock=clock)
        eng.prefill_chunk = 8
        eng.submit(Request(0, rng.integers(0, cfg.vocab_size, 24)
                           .astype(np.int32), 3))
        eng.tick()                   # pure prefill: no decoder advanced
        assert eng.tick_latency.count() == 1, mode
        assert eng.decode_latency.count() == 0, mode
        while len(eng.finished) < 1:
            eng.tick()
        # decode ticks record both; the whole-tick span always covers the
        # model-compute span (more clock reads inside the tick)
        assert eng.decode_latency.count() > 0, mode
        assert eng.tick_latency.count() > eng.decode_latency.count(), mode
        assert eng.tick_latency.max() >= eng.decode_latency.max(), mode
        eng.close()


def test_throughput_sensor_partial_window_rate():
    """Events/sec must divide by the elapsed span while the window is
    still filling (bench warm-up, short smoke runs under-reported before),
    clamp to the window once full, and survive the single-instant
    degenerate case without dividing by zero."""
    from repro.core.sensors import ThroughputSensor
    t = [0.0]
    s = ThroughputSensor(window_seconds=5.0, clock=lambda: t[0])
    assert s.rate() == 0.0                       # no events at all
    s.record(10)
    assert s.rate() == 10 / 5.0                  # zero span: conservative
    t[0] = 2.0
    s.record(10)
    assert s.rate() == 20 / 2.0                  # partial window: honest
    t[0] = 4.0
    assert s.rate() == 20 / 4.0
    t[0] = 7.0                                   # first event leaves window
    s.record(10)
    assert s.rate() == 20 / 5.0                  # clamped at window_seconds
    t[0] = 20.0
    assert s.rate() == 0.0                       # everything trimmed


def test_latency_sensor_measure_uses_injected_clock():
    from repro.core.sensors import LatencySensor
    t = [0.0]
    sensor = LatencySensor(clock=lambda: t[0])
    with sensor.measure():
        t[0] = 2.25
    assert sensor.mean() == 2.25
    assert sensor.max() == 2.25


def test_kv_pool_accounting(small_model):
    cfg, _ = small_model
    pool = KVBlockPool(cfg, block_tokens=16, max_blocks=4)
    assert pool.ensure(1, 20)          # 2 blocks
    assert pool.used_blocks == 2
    assert pool.ensure(2, 30)          # 2 more
    assert not pool.ensure(3, 10)      # budget exhausted
    assert pool.alloc_failures == 1
    pool.free(1)
    assert pool.used_blocks == 2
    assert pool.ensure(3, 10)
    assert kv_bytes_per_token(cfg) > 0


def test_kv_pool_budget_shrink_with_live_seqs(small_model):
    """§4.2 temporary inconsistency: shrinking the budget below current
    occupancy tolerates running sequences but blocks new growth until
    enough frees bring occupancy back under."""
    cfg, _ = small_model
    pool = KVBlockPool(cfg, block_tokens=16, max_blocks=8)
    assert pool.ensure(1, 48)            # 3 blocks
    assert pool.ensure(2, 48)            # 3 blocks
    pool.set_budget(4)                   # below the 6 in use
    assert pool.used_blocks == 6         # live seqs tolerated
    assert not pool.ensure(3, 16)        # new growth blocked...
    assert not pool.ensure(1, 64)        # ...including growth of live seqs
    assert pool.ensure(2, 40)            # no new blocks needed -> fine
    pool.free(1)
    assert pool.used_blocks == 3
    assert pool.ensure(3, 16)            # back under budget
    assert pool.used_blocks == 4


def test_kv_pool_alloc_failures_and_unknown_free(small_model):
    cfg, _ = small_model
    pool = KVBlockPool(cfg, block_tokens=16, max_blocks=2)
    assert pool.ensure(1, 32)
    for _ in range(3):
        assert not pool.ensure(2, 16)
    assert pool.alloc_failures == 3      # each rejection counted
    pool.free(99)                        # unknown seq: no-op
    assert pool.used_blocks == 2
    pool.free(1)
    pool.free(1)                         # double free: no-op, no underflow
    assert pool.used_blocks == 0
    assert pool.live_seqs == 0
    assert pool.used_bytes == 0


def test_trainer_runs_and_restarts(small_model):
    cfg, _ = small_model
    with tempfile.TemporaryDirectory() as td:
        tc = TrainerConfig(workdir=td, total_steps=5, ckpt_interval=2,
                           batch_size=4, seq_len=32)
        tr = Trainer(cfg, adamw.AdamWConfig(total_steps=5), tc)
        log = tr.run()
        assert len(log) == 5
        assert all(np.isfinite(m["loss"]) for m in log)
        saved_step = tr.ckpt.last_saved
        tr.close()

        tr2 = Trainer(cfg, adamw.AdamWConfig(total_steps=5), tc)
        assert tr2.step == saved_step          # resumed from checkpoint
        tr2.run(1)
        assert tr2.step == saved_step + 1
        tr2.close()


def test_trainer_preemption_checkpoints(small_model):
    cfg, _ = small_model
    with tempfile.TemporaryDirectory() as td:
        tc = TrainerConfig(workdir=td, total_steps=50, ckpt_interval=1000,
                           batch_size=4, seq_len=32)
        tr = Trainer(cfg, adamw.AdamWConfig(), tc)
        tr.run(2)
        tr.preemption.trigger()
        tr.run(10)   # should stop immediately and emergency-checkpoint
        assert tr.step == 2
        assert tr.ckpt.last_saved == 2
        tr.close()
