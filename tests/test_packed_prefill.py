"""Token-packed ragged prefill: one [1, P] stream per tick carries chunks
from *different* requests back-to-back (no per-slot bucket padding), with
per-token slot_id/position and segment-masked attention.  The correctness
bar is token-identity against the one-shot oracle for every text arch,
under the schedules the serve engine produces — ragged mixes, budgets that
do not divide prompts, segment boundaries mid-row, dense AND paged KV,
preemption mid-packed-chunk, and slot reuse restarting a segment at
position 0."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import transformer, zoo
from repro.serve import Request, ServeEngine

CACHE_LEN = 64
ROW_LENS = (50, 37, 11)
BUDGET = 13          # divides no row; forces mid-row segment boundaries


def _smoke_cfg(arch_id):
    cfg = reduced(get_config(arch_id))
    if cfg.moe:   # ample capacity -> deterministic routing for equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


def _run_packed(cfg, params, prompts, budget, caches, block_tables=None,
                prefilled=None):
    """Engine-shaped packed schedule: each call fills one [1, budget] stream
    with chunks from as many unfinished rows as fit, in row order — so one
    call routinely carries the tail of one request AND the head of the
    next.  Returns (per-row completion logits, caches)."""
    b = len(prompts)
    prefilled = list(prefilled) if prefilled else [0] * b
    done_logits = {}
    bt = None if block_tables is None else jnp.asarray(block_tables)
    while any(prefilled[i] < len(prompts[i]) for i in range(b)):
        tokens = np.zeros((1, budget), np.int32)
        slot_id = np.full((budget,), -1, np.int32)
        pos = np.zeros((budget,), np.int32)
        start = np.zeros((b,), np.int32)
        seg_len = np.zeros((b,), np.int32)
        cursor = 0
        packed = []
        for i, p in enumerate(prompts):
            if cursor >= budget or prefilled[i] >= len(p):
                continue
            n = min(len(p) - prefilled[i], budget - cursor)
            tokens[0, cursor:cursor + n] = p[prefilled[i]:prefilled[i] + n]
            slot_id[cursor:cursor + n] = i
            pos[cursor:cursor + n] = np.arange(prefilled[i], prefilled[i] + n)
            start[i] = prefilled[i]
            seg_len[i] = n
            packed.append((i, n))
            cursor += n
        logits, caches = transformer.prefill_packed(
            cfg, params, caches, jnp.asarray(tokens), jnp.asarray(slot_id),
            jnp.asarray(pos), jnp.asarray(start), jnp.asarray(seg_len),
            block_tables=bt)
        for i, n in packed:
            prefilled[i] += n
            if prefilled[i] >= len(prompts[i]) and i not in done_logits:
                done_logits[i] = logits[i]
    return done_logits, caches


def _rel_err(got, ref):
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    return float(jnp.max(jnp.abs(got - ref))) / scale


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_packed_prefill_matches_one_shot_every_arch(arch_id, rng):
    cfg = _smoke_cfg(arch_id)
    if cfg.encoder_decoder or cfg.frontend == "vision":
        # modality prefixes stay one-shot — and refuse loudly
        with pytest.raises(ValueError, match="packed prefill"):
            transformer.prefill_packed(
                cfg, None, None, jnp.zeros((1, 4), jnp.int32),
                jnp.zeros((4,), jnp.int32), jnp.zeros((4,), jnp.int32),
                jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32))
        return

    params, _ = zoo.init(cfg, jax.random.key(1))
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in ROW_LENS]
    refs = [transformer.prefill(cfg, params, {"tokens": jnp.asarray(p[None])},
                                cache_len=CACHE_LEN) for p in prompts]

    caches = zoo.init_cache(cfg, len(prompts), CACHE_LEN)
    done_logits, caches = _run_packed(cfg, params, prompts, BUDGET, caches)

    for i, (ref_logits, _) in enumerate(refs):
        err = _rel_err(done_logits[i], ref_logits[0])
        assert err < 5e-3, f"{arch_id} row {i}: packed prefill rel={err:.2e}"

    # one decode step from both caches: the packed stream must have carried
    # every row's exact state (K/V, recurrent scan state, token shifts)
    tok = jnp.asarray([int(jnp.argmax(r[0][0])) for r in refs], jnp.int32)
    pos = jnp.asarray([len(p) for p in prompts], jnp.int32)
    d_pk, _ = transformer.decode_step(cfg, params, caches, tok, pos)
    for i, (_, ref_caches) in enumerate(refs):
        d_ref, _ = transformer.decode_step(cfg, params, ref_caches,
                                           tok[i:i + 1], pos[i:i + 1])
        err = _rel_err(d_pk[i], d_ref[0])
        assert err < 5e-3, f"{arch_id} row {i}: decode handoff rel={err:.2e}"


@pytest.mark.parametrize("arch_id",
                         ["yi-6b", "gemma3-4b", "deepseek-moe-16b"])
def test_packed_prefill_paged_matches_one_shot(arch_id, rng):
    """The same packed schedule writing through per-token block-table
    routing (``_paged_scatter`` with ``seg=slot_id``) — including the
    windowed gemma3 local layers and MoE routing."""
    cfg = _smoke_cfg(arch_id)
    assert zoo.supports_paged_kv(cfg), arch_id
    params, _ = zoo.init(cfg, jax.random.key(1))
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in ROW_LENS]
    block_tokens = 16
    bps = CACHE_LEN // block_tokens
    b = len(prompts)
    caches = zoo.init_paged_cache(cfg, b * bps, block_tokens)
    # out-of-order physical blocks: only the table gives them meaning
    tables = np.arange(b * bps, dtype=np.int32)[::-1].reshape(b, bps)

    done_logits, _ = _run_packed(cfg, params, prompts, BUDGET, caches,
                                 block_tables=tables)
    for i, p in enumerate(prompts):
        ref_logits, _ = transformer.prefill(
            cfg, params, {"tokens": jnp.asarray(p[None])},
            cache_len=CACHE_LEN)
        err = _rel_err(done_logits[i], ref_logits[0])
        assert err < 5e-3, f"{arch_id} row {i}: paged packed rel={err:.2e}"


@pytest.mark.parametrize("arch_id", ["rwkv6-7b", "recurrentgemma-9b"])
def test_packed_segment_restart_resets_recurrent_state(arch_id, rng):
    """A segment starting at position 0 in a reused slot (new request, or a
    preempted one recomputing) must begin from zero scan state."""
    cfg = _smoke_cfg(arch_id)
    params, _ = zoo.init(cfg, jax.random.key(1))
    p1 = rng.integers(0, cfg.vocab_size, 21).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, 17).astype(np.int32)

    caches = zoo.init_cache(cfg, 1, CACHE_LEN)
    _, caches = _run_packed(cfg, params, [p1], 8, caches)
    logits, _ = _run_packed(cfg, params, [p2], 8, caches)

    ref_logits, _ = transformer.prefill(
        cfg, params, {"tokens": jnp.asarray(p2[None])}, cache_len=CACHE_LEN)
    err = _rel_err(logits[0], ref_logits[0])
    assert err < 5e-3, f"{arch_id}: stale state leaked, rel={err:.2e}"


def _engine_outputs(cfg, params, prompts, mode, *, max_new=6, chunk=16,
                    max_batch=2, cache_len=96, kv_mode="auto"):
    eng = ServeEngine(cfg, params, max_batch=max_batch, cache_len=cache_len,
                      enable_smartconf=False, prefill_mode=mode,
                      kv_mode=kv_mode)
    eng.prefill_chunk = chunk
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new))
    ticks = 0
    while len(eng.finished) < len(prompts) and ticks < 500:
        eng.tick()
        ticks += 1
    assert len(eng.finished) == len(prompts), mode
    outs = {r.req_id: list(r.generated) for r in eng.finished}
    stats = dict(compiles=eng.prefill_compiles,
                 pad_fraction=eng.pad_fraction,
                 reqs={r.req_id: r for r in eng.finished})
    eng.close()
    return outs, stats


@pytest.mark.parametrize("arch_id",
                         ["yi-6b", "recurrentgemma-9b", "deepseek-moe-16b"])
def test_engine_packed_matches_legacy(arch_id, rng):
    """End-to-end engine identity with more requests than slots: the packed
    scheduler (cross-bucket packing, slot reuse, interleaved decode) must
    generate token-identical output to the one-shot legacy engine."""
    cfg = _smoke_cfg(arch_id)
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (5, 9, 23, 31, 45)]
    legacy, _ = _engine_outputs(cfg, params, prompts, "legacy")
    packed, st = _engine_outputs(cfg, params, prompts, "packed")
    assert legacy == packed
    # one stream shape in steady state (drain ticks may bucket down)
    assert st["compiles"] <= 2


def test_engine_packed_budget_smaller_than_remaining(rng):
    """serve.prefill_chunk_tokens below one request's remaining chunk: the
    request must spread over ceil(len/budget) packed calls and still match
    the one-shot oracle."""
    cfg = _smoke_cfg("yi-6b")
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, 30).astype(np.int32)]
    legacy, _ = _engine_outputs(cfg, params, prompts, "legacy", chunk=7)
    packed, st = _engine_outputs(cfg, params, prompts, "packed", chunk=7)
    assert legacy == packed
    assert st["reqs"][0].prefill_chunks == 5     # ceil(30 / 7)


def test_engine_packed_preemption_mid_chunk(rng):
    """A paged engine preempted mid-packed-prefill (budget cut below
    occupancy) must recompute the kicked request from ``prefilled = 0`` on
    re-admission and still emit oracle-identical tokens."""
    cfg = _smoke_cfg("yi-6b")
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (40, 36)]
    legacy, _ = _engine_outputs(cfg, params, prompts, "legacy",
                                kv_mode="dense", max_new=4)

    eng = ServeEngine(cfg, params, max_batch=2, cache_len=96,
                      enable_smartconf=False, prefill_mode="packed",
                      kv_mode="paged")
    # budget 48: one packed call finishes request 0 (40 tokens) and starts
    # request 1 mid-chunk (8 of 36)
    eng.prefill_chunk = 48
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, 4))
    eng.tick()
    victim = eng.prefilling[1]
    assert 0 < victim.prefilled < len(victim.prompt)
    full_budget = eng.pool.max_blocks
    # cut below current occupancy (each request holds 3 blocks): the newest
    # request is kicked back to the queue mid-packed-chunk
    eng.set_kv_budget(eng.pool.used_blocks - 1)
    assert eng.preemptions == 1 and victim.slot is None
    assert victim.prefilled == 0                 # re-packs from scratch
    eng.set_kv_budget(full_budget)
    ticks = 0
    while len(eng.finished) < len(prompts) and ticks < 500:
        eng.tick()
        ticks += 1
    assert victim.preempted == 1
    outs = {r.req_id: list(r.generated) for r in eng.finished}
    eng.close()
    assert outs == legacy


def test_engine_packed_tick_stats(rng):
    """tick() must expose the prefill-knob deputy sensors: several requests
    share one packed call (packed_segments > 1) and the pad fraction stays
    below the bucketed path's quantization waste."""
    cfg = _smoke_cfg("yi-6b")
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (9, 13, 21, 30, 44)]
    eng = ServeEngine(cfg, params, max_batch=4, cache_len=128,
                      enable_smartconf=False, prefill_mode="packed")
    eng.prefill_chunk = 64
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, 4))
    stats = eng.tick()
    # all four slots' chunks (9 + 13 + 21 + 21-of-30, four distinct natural
    # buckets) rode in ONE saturated stream: zero padding this tick
    assert stats["packed_segments"] == 4
    assert stats["pad_fraction"] == 0.0
    ticks = 0
    while len(eng.finished) < len(prompts) and ticks < 200:
        eng.tick()
        ticks += 1
    assert len(eng.finished) == len(prompts)
    packed_pad = eng.pad_fraction
    eng.close()

    _, bucketed = _engine_outputs(cfg, params, prompts, "bucketed",
                                  max_new=4, max_batch=4, cache_len=128,
                                  chunk=64)
    assert packed_pad < bucketed["pad_fraction"]


def test_engine_prefill_mode_env_toggle(rng, monkeypatch):
    """REPRO_PREFILL_MODE re-routes what prefill_mode='auto' resolves to
    (the CI matrix leg) without touching explicit requests."""
    cfg = _smoke_cfg("yi-6b")
    params, _ = zoo.init(cfg, jax.random.key(0))

    def impl(**kw):
        eng = ServeEngine(cfg, params, max_batch=1, cache_len=32,
                          enable_smartconf=False, **kw)
        mode = eng.prefill_impl
        eng.close()
        return mode

    assert impl() == "packed"                      # the text-arch default
    monkeypatch.setenv("REPRO_PREFILL_MODE", "bucketed")
    assert impl() == "bucketed"
    assert impl(prefill_mode="packed") == "packed"  # explicit beats env
    monkeypatch.setenv("REPRO_PREFILL_MODE", "one_shot")
    assert impl() == "legacy"
    monkeypatch.setenv("REPRO_PREFILL_MODE", "bogus")
    with pytest.raises(ValueError, match="prefill_mode"):
        impl()
