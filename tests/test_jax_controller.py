"""The jittable controller twin: parity with the host controller, vmap
batching, metric-id coordination."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ControllerModel, GoalSpec, SmartController
from repro.core import jax_controller as jc


def _pair(alpha=2.0, delta=4.0, lam=0.1, goal=100.0, hard=True):
    model = ControllerModel(alpha=alpha, delta=delta, lam=lam,
                            conf_min=0.0, conf_max=1e9, integer=False)
    g = GoalSpec(goal, hard=hard)
    host = SmartController(model, g, 0.0)
    spec = jc.make_spec(model, g)
    state = jc.init_state(0.0)
    return host, spec, state


def test_parity_with_host_controller():
    host, spec, state = _pair()
    step = jax.jit(jc.controller_step)
    for s in [10.0, 40.0, 95.0, 120.0, 80.0, 89.0]:
        host.observe(s)
        want = host.actuate()
        state, got = step(spec, state, jnp.asarray(s))
        assert float(got) == pytest.approx(want, rel=1e-5), s


def test_indirect_parity():
    host, spec, state = _pair()
    step = jax.jit(jc.indirect_controller_step)
    host.observe(50.0, deputy=33.0)
    want = host.actuate()
    _, got = step(spec, state, jnp.asarray(50.0), jnp.asarray(33.0))
    assert float(got) == pytest.approx(want, rel=1e-5)


def test_vmap_batch_of_controllers():
    specs = jc.stack_specs([
        jc.make_spec(ControllerModel(alpha=1.0, delta=1.0, conf_max=1e9,
                                     integer=False), GoalSpec(50.0)),
        jc.make_spec(ControllerModel(alpha=2.0, delta=4.0, conf_max=1e9,
                                     integer=False), GoalSpec(100.0, hard=True)),
    ])
    states = jc.ControllerState(conf=jnp.zeros(2))
    step = jax.vmap(jc.controller_step)
    states, confs = step(specs, states, jnp.asarray([10.0, 10.0]))
    assert confs.shape == (2,)
    assert float(confs[0]) == pytest.approx(40.0)


def test_interaction_counts():
    ids = jnp.asarray([0, 0, 1, 2, 2, 2], jnp.int32)
    n = jc.interaction_counts(ids, 4)
    np.testing.assert_array_equal(np.asarray(n), [2, 2, 1, 3, 3, 3])


def test_coordinated_step_splits_error():
    model = ControllerModel(alpha=1.0, delta=1.0, conf_max=1e9, integer=False)
    specs = jc.stack_specs([
        jc.make_spec(model, GoalSpec(100.0, super_hard=True), metric_id=0),
        jc.make_spec(model, GoalSpec(100.0, super_hard=True), metric_id=0),
    ])
    states = jc.ControllerState(conf=jnp.zeros(2))
    # both below the virtual goal; shared metric, N = 2 -> half gain each
    vg = float(specs.virtual_goal[0])
    _, confs = jc.coordinated_step(specs, states, jnp.asarray([50.0, 50.0]))
    assert float(confs[0]) == pytest.approx((vg - 50.0) / 2.0, rel=1e-5)


def test_controller_step_inside_scan():
    """The in-graph controller must compose with lax.scan (serve loop use)."""
    model = ControllerModel(alpha=1.0, delta=1.0, conf_max=1e9, integer=False)
    spec = jc.make_spec(model, GoalSpec(10.0))
    state = jc.init_state(0.0)

    def body(carry, _):
        st, plant = carry
        st, conf = jc.controller_step(spec, st, plant)
        plant = conf  # plant: s = c
        return (st, plant), plant

    (_, final), trace = jax.lax.scan(body, (state, jnp.asarray(0.0)),
                                     None, length=20)
    assert float(final) == pytest.approx(10.0, rel=1e-4)
