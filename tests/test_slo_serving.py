"""Integration: SLO-constrained serving — typed rejections, graceful
brownout, deadline shedding, worker-preemption drain/resume, the
preemption-readmission livelock guard, and chaos-corrupted sensors."""

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import zoo
from repro.serve import (ChaosMonkey, ChaosSpec, OpenLoopDriver, Request,
                         RejectReason, SLOSpec, ServeEngine, TickCostModel,
                         TierSpec, TraceConfig, VirtualClock, as_requests,
                         synthesize_trace)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    return cfg, params


def _weight_bytes(params):
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves(params))


def _req(rng, cfg, rid, plen=16, new=6, **kw):
    return Request(rid, rng.integers(1, cfg.vocab_size, plen)
                   .astype(np.int32), new, **kw)


# -------------------------------------------------------- typed rejections

def test_submit_rejects_invalid_requests_typed(small_model, rng):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      enable_smartconf=False)
    adm = eng.submit(_req(rng, cfg, 0, plen=0))
    assert not adm and adm.reason is RejectReason.EMPTY_PROMPT
    adm = eng.submit(_req(rng, cfg, 1, plen=80, new=8))
    assert not adm and adm.reason is RejectReason.PROMPT_TOO_LONG
    adm = eng.submit(_req(rng, cfg, 2, plen=16, new=6))
    assert adm and adm.reason is None and adm.footprint_blocks > 0
    assert eng.rejected == 2
    assert eng.reject_counts["empty_prompt"] == 1
    assert eng.reject_counts["prompt_too_long"] == 1
    assert all(r.reject_reason is not None for r in eng.shed)
    for _ in range(30):
        eng.tick()                       # rejected work never crashes a tick
    assert len(eng.finished) == 1
    eng.close()


def test_submit_rejects_footprint_beyond_any_budget(small_model, rng):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      block_tokens=16, enable_smartconf=False)
    eng.set_kv_budget(1)                 # 16 tokens of KV, total
    big = _req(rng, cfg, 0, plen=40, new=8)   # needs 3 blocks
    adm = eng.submit(big)
    assert not adm and adm.reason is RejectReason.KV_FOOTPRINT
    assert adm.footprint_blocks == 3
    assert eng.submit(_req(rng, cfg, 1, plen=8, new=4))
    eng.close()


# ------------------------------------------------------- deadline shedding

def test_deadline_expired_requests_are_shed(small_model, rng):
    cfg, params = small_model
    vc = VirtualClock()
    eng = ServeEngine(cfg, params, max_batch=1, cache_len=64,
                      enable_smartconf=False, clock=vc)
    eng.submit(_req(rng, cfg, 0, plen=16, new=8))
    eng.tick()                            # request 0 occupies the only slot
    eng.submit(_req(rng, cfg, 1, plen=16, new=4, deadline_s=0.5))
    vc.advance(1.0)                       # its deadline passes while queued
    eng.tick()
    assert eng.reject_counts["deadline_expired"] == 1
    shed = [r for r in eng.shed
            if r.reject_reason is RejectReason.DEADLINE_EXPIRED]
    assert [r.req_id for r in shed] == [1]
    for _ in range(30):
        eng.tick()
    assert [r.req_id for r in eng.finished] == [0]
    eng.close()


# ------------------------------------------------------------- brownout

def test_static_tier_gate_sheds_low_tiers_without_hol_blocking(
        small_model, rng):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      enable_smartconf=False, num_tiers=3, admit_tier_max=0)
    eng.submit(_req(rng, cfg, 0, new=4, tier=2))   # browned-out, arrives first
    eng.submit(_req(rng, cfg, 1, new=4, tier=0))
    for _ in range(20):
        eng.tick()
    # tier 0 was served THROUGH the waiting tier-2 head (no HOL blocking)
    assert [r.req_id for r in eng.finished] == [1]
    assert [r.req_id for r in eng.waiting] == [0]  # parked, not rejected
    eng.admit_tier_max = 2                          # brownout lifts
    for _ in range(20):
        eng.tick()
    assert sorted(r.req_id for r in eng.finished) == [0, 1]
    eng.close()


def test_adaptive_brownout_engages_under_overload(small_model):
    """Open-loop overload: the sc_admit controller must shed low tiers
    (admit_tier_max drops) and tier-0 must keep a better SLO attainment
    than tier-2."""
    cfg, params = small_model
    budget = _weight_bytes(params) + 4_000_000
    vc = VirtualClock()
    slo = SLOSpec(ttft_s=1.0, window=32)
    eng = ServeEngine(cfg, params, max_batch=4, cache_len=64,
                      hbm_budget_bytes=budget, block_tokens=16,
                      slo=slo, num_tiers=3, clock=vc)
    # sustained overload (~1.75x capacity): tier outcomes must be set by
    # the brownout gate, not by queue luck — at milder rates the gate's
    # reaction lag leaves marginal late-burst tier-0 misses that swamp
    # the tier ordering.
    trace = synthesize_trace(TraceConfig(
        process="bursty", rate_rps=35.0, horizon_s=6.0, seed=11,
        burst_factor=8.0, burst_period_s=3.0, burst_duty=0.4,
        prompt_lo=4, prompt_hi=24, new_lo=2, new_hi=8,
        tiers=(TierSpec(0, 0.3, deadline_s=20.0), TierSpec(1, 0.3),
               TierSpec(2, 0.4))))
    arrivals = as_requests(trace, vocab=cfg.vocab_size, seed=1)
    admit_probe = []
    drv = OpenLoopDriver(
        eng, arrivals, clock=vc,
        cost=TickCostModel(base_s=0.02, prefill_token_s=1e-3,
                           decode_token_s=8e-3),
        chaos=lambda d, t: admit_probe.append(d.engine.admit_tier_max) or 0.0)
    out = drv.run()
    assert out["unhandled"] == []
    assert min(admit_probe) < 2, "brownout never engaged under overload"
    assert max(admit_probe) == 2, "brownout never lifted"
    # attainment vs *offered* work per tier: brownout_shed rejects
    # would-miss low-tier requests before they finish, so attainment
    # among finished requests alone is survivor-biased.
    offered: dict[int, int] = {}
    for _, req in arrivals:
        offered[req.tier] = offered.get(req.tier, 0) + req.max_new_tokens
    good = out["goodput_tokens_by_tier"]
    t0 = good.get(0, 0) / max(1, offered.get(0, 0))
    t2 = good.get(2, 0) / max(1, offered.get(2, 0))
    assert t0 >= t2, "premium tier did not get better SLO attainment"
    assert out["slo_good_tokens"] > 0
    eng.close()


# ------------------------------------------------ preemption drain/resume

def test_preemption_drains_requeues_and_resumes(small_model, rng):
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      enable_smartconf=False)
    for i in range(4):
        eng.submit(_req(rng, cfg, i, plen=12, new=6))
    eng.tick(); eng.tick()                # some work is mid-flight
    assert eng.running or eng.prefilling
    eng.preemption.trigger()
    stats = eng.tick()                    # drain tick: no crash, no progress
    assert stats["draining"] is True
    assert not eng.running and not eng.prefilling
    assert eng.preemptions >= 1
    assert len(eng.drained_requests()) == 4   # nothing was lost
    # admission order survives the drain
    seq = [r.req_id for r in eng.drained_requests()]
    assert seq == sorted(seq)
    adm = eng.submit(_req(rng, cfg, 99))
    assert not adm and adm.reason is RejectReason.DRAINING
    eng.tick()                            # idles while the signal is up
    eng.preemption.reset()
    for _ in range(60):
        eng.tick()
    assert sorted(r.req_id for r in eng.finished) == [0, 1, 2, 3]
    assert all(len(r.generated) == 6 for r in eng.finished)
    assert eng.recompute_tokens > 0       # drained work was recomputed...
    eng.close()
    eng.close()                           # ...and close() is idempotent


# ------------------------------------------- preemption-readmission livelock

def test_budget_cut_below_footprint_parks_not_livelocks(small_model, rng):
    """Cut the KV budget below one request's remaining footprint mid-run:
    the engine must reject it with a typed reason after at most one
    preemption, not re-preempt/readmit it forever."""
    cfg, params = small_model
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      block_tokens=16, enable_smartconf=False)
    big = _req(rng, cfg, 0, plen=40, new=12)     # needs 4 blocks of 16
    eng.submit(big)
    for _ in range(3):
        eng.tick()                        # admitted and generating
    assert big.gen_count > 0 or big.prefilled > 0
    eng.set_kv_budget(1)                  # 1 block: can NEVER hold it again
    for _ in range(20):
        eng.tick()
    assert big.reject_reason is RejectReason.KV_FOOTPRINT
    assert big.preempted == 1             # exactly one undo, then parked
    # bounded recompute: at most one admission's worth of work was redone
    assert eng.recompute_tokens <= len(big.prompt) + big.max_new_tokens
    stats = eng.tick()                    # engine is idle and healthy
    assert stats["running"] == 0 and eng.queued_tokens == 0
    eng.close()


# ----------------------------------------------------- chaos sensor faults

def test_chaos_nan_sensors_do_not_crash_guarded_controllers(small_model):
    cfg, params = small_model
    budget = _weight_bytes(params) + 4_000_000
    vc = VirtualClock()
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      hbm_budget_bytes=budget, block_tokens=16,
                      slo=SLOSpec(ttft_s=1.0, decode_s=0.5, window=32),
                      clock=vc)
    trace = synthesize_trace(TraceConfig(rate_rps=8.0, horizon_s=4.0,
                                         seed=3, prompt_hi=24, new_hi=6))
    monkey = ChaosMonkey(ChaosSpec(
        seed=0, sensor_fault_tick=5, sensor_fault_ticks=12,
        sensor_fault_mode="nan",
        sensor_names=("decode_p99_s", "ttft_p99_s", "hbm_bytes"))
    ).install(eng)
    drv = OpenLoopDriver(
        eng, as_requests(trace, vocab=cfg.vocab_size, seed=2), clock=vc,
        cost=TickCostModel(base_s=0.02, prefill_token_s=1e-3,
                           decode_token_s=8e-3),
        chaos=monkey)
    out = drv.run()
    assert out["unhandled"] == []
    faults = sum(sc.sensor_faults for sc in
                 (eng.sc_queue, eng.sc_kv, eng.sc_chunk, eng.sc_admit)
                 if sc is not None)
    assert faults > 0, "chaos window never corrupted a controller read"
    assert any("sensor_nan" in name for _, name in monkey.events)
    assert out["finished"] > 0            # service continued through faults
    eng.close()


def test_chaos_preemption_mid_trace_recovers(small_model):
    cfg, params = small_model
    vc = VirtualClock()
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      enable_smartconf=False, clock=vc,
                      slo=SLOSpec(ttft_s=5.0))
    trace = synthesize_trace(TraceConfig(rate_rps=5.0, horizon_s=3.0,
                                         seed=4, prompt_hi=16, new_hi=5))
    # tick 5 deterministically has a request in flight (the schedule up to
    # the preempt tick is unaffected by the injection itself)
    monkey = ChaosMonkey(ChaosSpec(preempt_tick=5, preempt_resume_ticks=4)
                         ).install(eng)
    drv = OpenLoopDriver(
        eng, as_requests(trace, vocab=cfg.vocab_size, seed=5), clock=vc,
        cost=TickCostModel(base_s=0.02, prefill_token_s=1e-3,
                           decode_token_s=8e-3),
        chaos=monkey)
    out = drv.run()
    assert out["unhandled"] == []
    assert ("preempt" in [n for _, n in monkey.events]
            and "resume" in [n for _, n in monkey.events])
    assert out["preemptions"] >= 1
    # every submitted request was either finished or typed-rejected
    assert out["finished"] + out["rejected"] == out["submitted"]
    assert out["finished"] > 0
    eng.close()
