"""Radix prefix cache over refcounted copy-on-write paged KV: lease
refcount/COW invariants under adversarial interleavings (out-of-order
release, preemption, mid-run budget cuts), engine token-identity between
cache-hit and cold runs, the serve.kv_cache_share control loop's audit
trail, and block-level sliding-window eviction on all-window archs."""

import collections

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.sensors import HBMAccountant
from repro.models import zoo
from repro.serve import (PagedKVAllocator, PrefixCache, Request, ServeEngine,
                         ServeOptions, TICK_STATS_KEYS)


@pytest.fixture(scope="module")
def small_model():
    cfg = reduced(get_config("yi-6b"))
    params, _ = zoo.init(cfg, jax.random.key(0))
    return cfg, params


def _alloc(cfg, *, capacity=8, bps=4, bt=16, accountant=None, budget=None):
    return PagedKVAllocator(cfg, block_tokens=bt, max_blocks_per_seq=bps,
                            capacity_blocks=capacity, budget_blocks=budget,
                            accountant=accountant)


# ------------------------------------------------------ refcounts and COW
def test_fork_shares_then_cow_rehomes(small_model):
    """A fork consumes zero blocks; the first write through ``writable``
    re-homes exactly the shared blocks in the write span, leaving blocks
    outside the span shared."""
    cfg, _ = small_model
    pool = _alloc(cfg)
    ls = pool.lease(48)                          # 3 blocks
    child = ls.fork()
    assert pool.used_blocks == 3                 # shared blocks count once
    assert [ls.refcount(i) for i in range(3)] == [2, 2, 2]
    pairs = child.writable(16, 40)               # spans blocks 1 and 2
    assert pairs is not None and len(pairs) == 2
    assert pool.used_blocks == 5
    assert child.blocks[0] == ls.blocks[0]       # block 0 still shared
    assert child.blocks[1] != ls.blocks[1]
    assert child.blocks[2] != ls.blocks[2]
    assert {p[0] for p in pairs} == {ls.blocks[1], ls.blocks[2]}
    assert child.writable(16, 40) == []          # now private: no-op
    ls.release()                                 # donor first (out of order)
    assert pool.used_blocks == 3                 # child keeps its 3 alive
    child.release()
    assert pool.used_blocks == 0 and pool.free_blocks == 8


def test_shared_adoption_survives_donor_release(small_model):
    """The prefix-cache sharing path: a lease adopting live blocks
    (``shared=``) keeps them alive after the donor releases — and a
    release never double-frees a still-referenced block."""
    cfg, _ = small_model
    pool = _alloc(cfg)
    donor = pool.lease(32)                       # 2 blocks
    borrower = pool.lease(64, shared=list(donor.blocks))
    assert pool.used_blocks == 4                 # 2 shared + 2 fresh
    donor.release()                              # out-of-order release
    assert pool.used_blocks == 4
    assert borrower.writable(0, 64) == []        # sole holder now: no COW
    borrower.release()
    borrower.release()                           # idempotent
    assert pool.used_blocks == 0 and pool.free_blocks == 8


def test_cow_failure_is_atomic(small_model):
    """``writable`` that cannot source its copies changes nothing: tables,
    refcounts, and the free list stay put (counted as an alloc failure)."""
    cfg, _ = small_model
    pool = _alloc(cfg, capacity=4)
    ls = pool.lease(64)                          # all 4 blocks
    child = ls.fork()
    before = list(child.blocks)
    assert child.writable(0, 64) is None         # free list empty
    assert pool.alloc_failures == 1
    assert list(child.blocks) == before
    assert [ls.refcount(i) for i in range(4)] == [2, 2, 2, 2]
    ls.release()
    child.release()
    assert pool.free_blocks == 4


def test_allocator_property_sweep(small_model):
    """Randomized lease/extend/fork/writable/trim/release interleaving with
    mid-run budget cuts.  After EVERY op the pool's refcounts, free list,
    occupancy, and HBM ledger must agree with a mirror recomputed from the
    live lease tables alone — no leaks, no double-frees, no drift."""
    cfg, _ = small_model
    acc = HBMAccountant()
    pool = _alloc(cfg, capacity=16, bps=4, accountant=acc)
    rng = np.random.default_rng(7)
    live: list = []

    def check():
        mirror = collections.Counter(
            b for ls in live for b in ls.blocks if b >= 0)
        for b in range(pool.capacity):
            assert pool._refs[b] == mirror.get(b, 0)
        assert pool.used_blocks == len(mirror)
        assert sorted(pool._free) == sorted(
            set(range(pool.capacity)) - set(mirror))
        assert acc.breakdown()["kv_cache"] == \
            pool.capacity * pool.block_bytes

    for step in range(300):
        op = int(rng.integers(0, 6))
        if op == 0:
            ls = pool.lease(int(rng.integers(1, 65)))
            if ls is not None:
                live.append(ls)
        elif op == 1 and live:
            ls = live[int(rng.integers(len(live)))]
            ls.extend(ls.tokens + int(rng.integers(1, 33)))
        elif op == 2 and live:
            live.append(live[int(rng.integers(len(live)))].fork())
        elif op == 3 and live:
            ls = live[int(rng.integers(len(live)))]
            lo = int(rng.integers(0, max(1, ls.tokens)))
            ls.writable(lo, min(ls.tokens, lo + int(rng.integers(1, 33))))
        elif op == 4 and live:
            ls = live[int(rng.integers(len(live)))]
            ls.trim_front(int(rng.integers(0, len(ls.blocks) + 1)))
        elif op == 5 and live:
            # out-of-order release: any live lease, not LIFO
            live.pop(int(rng.integers(len(live)))).release()
        if step % 3 == 0:                        # mid-run budget churn
            pool.set_budget(int(rng.integers(4, 17)))
        check()
    for ls in live:
        ls.release()
        ls.release()                             # double release: no-op
    live.clear()
    check()
    assert pool.used_blocks == 0 and pool.free_blocks == pool.capacity


def test_cache_survives_borrower_release_and_compact(small_model):
    """COW-safe preemption at the tree level: a borrower releasing (as a
    preemption does) must not free blocks the cache still holds; a store
    compaction renumbers tree-held ids through ``remap_hook``."""
    cfg, _ = small_model
    pool = _alloc(cfg)
    cache = PrefixCache(pool)
    pool.remap_hook = cache.remap
    prompt = np.arange(40, dtype=np.int32)
    donor = pool.lease(40)                       # 3 blocks
    assert cache.insert(prompt, list(donor.blocks), 1) == 2  # 32-tok prefix
    donor.release()
    assert pool.used_blocks == 2 and cache.blocks_held == 2
    match, blocks = cache.lookup(prompt, 2)
    assert match == 32 and len(blocks) == 2
    borrower = pool.lease(40, shared=blocks)
    assert pool.used_blocks == 3                 # shared pair counted once
    borrower.release()                           # "preempted" mid-borrow
    assert pool.used_blocks == 2 and cache.blocks_held == 2
    keep = pool.compact(2)
    m2, blocks2 = cache.lookup(prompt, 3)
    assert m2 == 32
    assert [int(keep[b]) for b in blocks2] == blocks  # followed renumbering
    assert cache.clear() == 2
    assert pool.used_blocks == 0


# ----------------------------------------------------------------- engine
# every arch the paged KV path serves: full/swa/local/global attention
# incl. MoE FFNs (only attention K/V is paged)
PAGED_ARCHS = ("yi-6b", "h2o-danube-3-4b", "starcoder2-15b", "gemma3-4b",
               "deepseek-moe-16b", "llama4-maverick-400b-a17b")
_MODELS: dict = {}


def _paged_model(arch):
    if arch not in _MODELS:
        cfg = reduced(get_config(arch))
        params, _ = zoo.init(cfg, jax.random.key(0))
        _MODELS[arch] = (cfg, params)
    return _MODELS[arch]


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_engine_cache_hit_token_identical_to_cold(arch, rng):
    """Acceptance: a request admitted over a cached (mid-block!) prefix
    generates exactly the tokens the cold engine generates, with real
    reclaimed-prefill and COW activity on the warm side — for every arch
    the paged path serves."""
    cfg, params = _paged_model(arch)
    prefix = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)
    prompts = [np.concatenate(
        [prefix, rng.integers(0, cfg.vocab_size, 24).astype(np.int32)])
        for _ in range(2)]
    outs = {}
    for cache_on in (False, True):
        eng = ServeEngine(cfg, params, options=ServeOptions(
            max_batch=2, cache_len=96, enable_smartconf=False,
            kv_mode="paged", prefix_cache=cache_on))
        for i, p in enumerate(prompts):         # serial: insert, then hit
            adm = eng.submit(Request(i, p, 6))
            assert adm and adm.footprint_blocks > 0
            if cache_on and i == 1:
                assert adm.prefix_hit_tokens == 40   # mid-block match
            ticks = 0
            while len(eng.finished) < i + 1 and ticks < 200:
                stats = eng.tick()
                ticks += 1
            assert len(eng.finished) == i + 1
            assert tuple(stats) == TICK_STATS_KEYS   # frozen sensor schema
        outs[cache_on] = {r.req_id: r.generated for r in eng.finished}
        if cache_on:
            assert eng.prefix_hit_tokens_total == 40
            assert eng.cow_copied_blocks >= 1        # boundary block copied
            assert eng._prefix_cache.hit_rate > 0
        eng.close()
    assert outs[True] == outs[False]


def test_kv_cache_share_controller_leaves_audit_trail(small_model, rng):
    """Acceptance: serve.kv_cache_share is actuated by a guarded SmartConf
    whose decisions land in the telemetry audit log with the windowed
    prefix_hit_rate sensor attached."""
    from repro.core.smartconf import ConfRegistry
    from repro.core.telemetry import Telemetry
    cfg, params = small_model
    tel = Telemetry(enabled=True)
    eng = ServeEngine(cfg, params, options=ServeOptions(
        max_batch=2, cache_len=96, enable_smartconf=True,
        kv_mode="paged", prefix_cache=True, telemetry=tel),
        registry=ConfRegistry())
    prefix = rng.integers(0, cfg.vocab_size, 48).astype(np.int32)

    def submit(i):
        tail = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
        assert eng.submit(Request(i, np.concatenate([prefix, tail]), 4))

    submit(0)                                    # cold insert
    ticks = 0
    while len(eng.finished) < 1 and ticks < 200:
        eng.tick()
        ticks += 1
    for i in (1, 2, 3):                          # warm hits
        submit(i)
    while len(eng.finished) < 4 and ticks < 600:
        eng.tick()
        ticks += 1
    assert len(eng.finished) == 4
    assert eng.prefix_hit_tokens_total > 0
    recs = tel.audit.query(conf="serve.kv_cache_share")
    assert recs, "cache-share controller left no audit Decisions"
    assert all(r.metric == "prefix_hit_rate" for r in recs)
    assert any(r.sensor > 0 for r in recs)       # real hit-rate readings
    assert 0.05 <= eng.kv_cache_share <= 0.9     # inside actuator bounds
    eng.close()


def test_window_eviction_all_swa_token_identical_and_frees(rng):
    """Block-level sliding-window eviction (the PR-2 follow-on): on an
    all-swa arch the paged engine trims blocks wholly below every live
    window mid-run — front table entries go to -1 and the pool's occupancy
    stays below the no-trim watermark — while remaining token-identical to
    the dense engine."""
    cfg = reduced(get_config("h2o-danube-3-4b"))  # every layer swa
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in (12, 25)]
    outs, trimmed = {}, 0
    for mode in ("paged", "dense"):
        eng = ServeEngine(cfg, params, options=ServeOptions(
            max_batch=2, cache_len=96, enable_smartconf=False,
            kv_mode=mode))
        if mode == "paged":
            assert eng._window_evict, "all-swa paged engine must trim"
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, 60))
        ticks = 0
        while len(eng.finished) < len(prompts) and ticks < 400:
            eng.tick()
            if mode == "paged":
                for req in eng.running.values():
                    if req.lease is not None:
                        trimmed = max(trimmed, sum(
                            1 for b in req.lease.blocks if b < 0))
            ticks += 1
        assert len(eng.finished) == len(prompts), mode
        outs[mode] = {r.req_id: r.generated for r in eng.finished}
        eng.close()
    assert trimmed > 0, "window eviction never freed a leading block"
    assert outs["paged"] == outs["dense"]
