"""Unit tests: SmartConf controller guardrails (sensor sanity, fallback to
last-known-good, actuation slew clamp + anti-windup, mid-run ceiling cuts).

These are the serve-path robustness guards: an unguarded controller fed a
NaN reading crashes the first ``int(get_conf())`` actuation; a guarded one
must keep serving from the last sane configuration.
"""

import math

import pytest

from repro.core import (ConfRegistry, ControllerModel, GoalSpec, Guardrails,
                        SmartConf, SmartConfIndirect)


def _mk(guardrails, *, alpha=2.0, goal=100.0, initial=10.0, hard=False,
        conf_min=0.0, conf_max=1000.0):
    return SmartConf(
        "test.knob", metric="lat", goal=GoalSpec(goal, hard=hard),
        initial=initial, registry=ConfRegistry(), guardrails=guardrails,
        model=ControllerModel(alpha=alpha, conf_min=conf_min,
                              conf_max=conf_max))


def test_insane_readings_never_reach_the_controller():
    sc = _mk(Guardrails(perf_lo=0.0, perf_hi=1e6))
    sc.set_perf(50.0)
    base = sc.get_conf()
    for bad in (math.nan, math.inf, -math.inf, -1.0, 1e9):
        sc.set_perf(bad)
    assert sc.sensor_faults == 5
    # one sane reading between faults keeps the knob live and finite
    assert math.isfinite(sc.get_conf())
    assert sc.get_conf() == pytest.approx(sc.get_conf())  # stable when blind
    assert base is not None


def test_nan_crashes_unguarded_but_not_guarded():
    unguarded = _mk(None)
    unguarded.set_perf(math.nan)
    with pytest.raises(ValueError):
        int(unguarded.get_conf())    # int(nan): what chaos does to naive code
    guarded = _mk(Guardrails(perf_lo=0.0, perf_hi=1e6))
    guarded.set_perf(math.nan)
    assert math.isfinite(guarded.get_conf())


def test_fallback_after_consecutive_faults_and_recovery():
    sc = _mk(Guardrails(perf_lo=0.0, perf_hi=1e6, fault_tolerance=3))
    sc.set_perf(50.0)
    good = sc.get_conf()
    assert not sc.sensor_failed
    sc.set_perf(math.nan)
    sc.set_perf(math.nan)
    assert not sc.sensor_failed          # under the tolerance: still live
    sc.set_perf(math.nan)
    assert sc.sensor_failed              # 3 consecutive: declared failed
    assert sc.get_conf() == pytest.approx(good)   # pinned to last-known-good
    sc.set_perf(50.0)                    # sensor recovers
    assert not sc.sensor_failed
    assert math.isfinite(sc.get_conf())


def test_explicit_fallback_wins_over_last_good():
    sc = _mk(Guardrails(perf_lo=0.0, perf_hi=1e6, fault_tolerance=1,
                        fallback=42.0))
    sc.set_perf(50.0)
    sc.get_conf()
    sc.set_perf(math.nan)
    assert sc.sensor_failed
    assert sc.get_conf() == pytest.approx(42.0)


def test_slew_clamp_bounds_one_actuation():
    sc = _mk(Guardrails(max_step=5.0), alpha=1.0, goal=1000.0, initial=10.0)
    first = sc.get_conf()                # establishes last-known-good
    sc.set_perf(0.0)                     # error 1000 -> wants a huge step
    second = sc.get_conf()
    assert abs(second - first) <= 5.0 + 1e-9
    assert sc.clamped_actuations >= 1


def test_slew_clamp_anti_windup_resumes_from_applied_value():
    sc = _mk(Guardrails(max_step=5.0), alpha=1.0, goal=1000.0, initial=10.0)
    sc.get_conf()
    sc.set_perf(0.0)
    clamped = sc.get_conf()
    # the controller's own state was written back to the applied value, so
    # the next step integrates from there (no hidden wound-up integral)
    assert sc.controller.conf == pytest.approx(clamped)
    sc.set_perf(0.0)
    nxt = sc.get_conf()
    assert abs(nxt - clamped) <= 5.0 + 1e-9


def test_clamp_conf_max_mid_run_cut():
    sc = _mk(Guardrails(perf_lo=0.0, perf_hi=1e6), initial=800.0)
    sc.set_perf(50.0)
    assert sc.get_conf() > 500.0
    sc.clamp_conf_max(100.0)             # chaos: capacity loss mid-run
    assert sc.get_conf() <= 100.0
    sc.set_perf(50.0)                    # keeps controlling under the cut
    assert sc.get_conf() <= 100.0
    sc.clamp_conf_max(1000.0)            # restore: the range re-opens
    sc.set_perf(50.0)
    assert sc.get_conf() <= 1000.0


def test_indirect_rejects_non_finite_deputy():
    sc = SmartConfIndirect(
        "test.indirect", metric="bytes", goal=GoalSpec(1000.0, hard=True),
        initial=10.0, registry=ConfRegistry(),
        guardrails=Guardrails(perf_lo=0.0, perf_hi=1e9, fault_tolerance=1),
        model=ControllerModel(alpha=2.0, conf_min=0.0, conf_max=1e6))
    sc.set_perf(500.0, 5.0)
    good = sc.get_conf()
    sc.set_perf(500.0, math.nan)         # deputy sensor dropped out
    assert sc.sensor_faults >= 1
    assert math.isfinite(sc.get_conf())
    assert sc.get_conf() == pytest.approx(good)
