"""Unit tests for the SmartConf control law (paper §5)."""


import numpy as np
import pytest

from repro.core import (ControllerModel, GoalSpec, SmartController,
                        compute_pole, compute_virtual_goal, fit_model)
from repro.core.ablations import NoVirtualGoalController, SinglePoleController


def test_pole_rule():
    assert compute_pole(1.0) == 0.0
    assert compute_pole(2.0) == 0.0
    assert compute_pole(4.0) == pytest.approx(0.5)     # p = 1 - 2/Delta
    assert 0.0 <= compute_pole(100.0) < 1.0


def test_virtual_goal_upper_and_lower():
    g = GoalSpec(100.0, hard=True)
    assert compute_virtual_goal(g, 0.1) == pytest.approx(90.0)
    g2 = GoalSpec(100.0, hard=True, direction="lower")
    assert compute_virtual_goal(g2, 0.1) == pytest.approx(110.0)
    soft = GoalSpec(100.0, hard=False)
    assert compute_virtual_goal(soft, 0.5) == 100.0    # soft goals untouched


def test_fit_model_affine_slope_and_noise_stats():
    # s = 2c + 10 with noise
    rng = np.random.default_rng(0)
    confs = [10, 20, 30, 40]
    samples = [[2 * c + 10 + rng.normal(0, 1) for _ in range(50)] for c in confs]
    m = fit_model(confs, samples)
    assert m.alpha == pytest.approx(2.0, rel=0.1)
    assert m.lam < 0.1
    assert m.delta == pytest.approx(1 + 3 * m.lam)


def test_fit_model_negative_slope():
    confs = [100, 200, 300]
    samples = [[1000 - 0.9 * c] * 3 for c in confs]
    m = fit_model(confs, samples)
    assert m.alpha == pytest.approx(-0.9, rel=1e-6)


def test_controller_converges_linear_plant():
    model = ControllerModel(alpha=2.0, delta=1.5, lam=0.0, conf_max=1000)
    ctl = SmartController(model, GoalSpec(100.0, hard=False), 0.0)
    s = 0.0
    for _ in range(50):
        ctl.observe(s)
        c = ctl.actuate()
        s = 2.0 * c   # true plant matches the model
    assert s == pytest.approx(100.0, abs=1e-6)


def test_controller_converges_with_model_error_within_bound():
    # true alpha / modeled alpha = 1.8 < 2: must converge with p = 0
    model = ControllerModel(alpha=1.0, delta=1.2, lam=0.0, conf_max=1e9,
                            integer=False)
    ctl = SmartController(model, GoalSpec(90.0, hard=False), 0.0)
    s = 0.0
    for _ in range(200):
        ctl.observe(s)
        s = 1.8 * ctl.actuate()
    assert s == pytest.approx(90.0, rel=1e-3)


def test_two_pole_switch_on_hard_goal():
    model = ControllerModel(alpha=1.0, delta=4.0, lam=0.1, conf_min=-1e9,
                            conf_max=1e9, integer=False)
    ctl = SmartController(model, GoalSpec(100.0, hard=True), 0.0)
    assert ctl.pole == pytest.approx(0.5)
    # in danger (above the virtual goal) the aggressive pole applies:
    ctl.observe(99.0)        # virtual goal = 90
    c_before = ctl.conf
    c = ctl.actuate()
    # full-gain correction: delta_c = (1-0)/alpha * (90-99) = -9
    assert c - c_before == pytest.approx(-9.0, abs=1e-6)
    # in the safe zone the conservative pole applies (half gain)
    ctl2 = SmartController(model, GoalSpec(100.0, hard=True), 0.0)
    ctl2.observe(50.0)
    c2 = ctl2.actuate()
    assert c2 == pytest.approx(0.5 * (90.0 - 50.0), abs=1e-6)


def test_indirect_controller_uses_deputy():
    model = ControllerModel(alpha=1.0, delta=1.0, lam=0.0, conf_max=1e9)
    ctl = SmartController(model, GoalSpec(100.0, hard=False), 0.0)
    ctl.observe(40.0, deputy=70.0)
    # next value integrates from the deputy, not from the old conf
    assert ctl.actuate() == pytest.approx(70.0 + (100.0 - 40.0))


def test_interaction_factor_splits_gain():
    model = ControllerModel(alpha=1.0, delta=1.0, lam=0.0, conf_max=1e9)
    ctl = SmartController(model, GoalSpec(100.0, hard=False), 0.0,
                          n_interacting=2)
    ctl.observe(60.0)
    assert ctl.actuate() == pytest.approx(20.0)   # (100-60)/2


def test_goal_unreachable_flag():
    model = ControllerModel(alpha=1.0, delta=1.0, lam=0.0, conf_max=10.0)
    ctl = SmartController(model, GoalSpec(1000.0, hard=False), 0.0)
    ctl.observe(0.0)
    assert ctl.actuate() == 10.0
    assert ctl.goal_unreachable


def test_runtime_goal_update():
    model = ControllerModel(alpha=1.0, delta=1.0, lam=0.1)
    ctl = SmartController(model, GoalSpec(100.0, hard=True), 0.0)
    vg1 = ctl.virtual_goal
    ctl.set_goal(GoalSpec(50.0, hard=True))
    assert ctl.virtual_goal == pytest.approx(vg1 / 2)


def test_ablation_single_pole_never_aggressive():
    model = ControllerModel(alpha=1.0, delta=1.0, lam=0.1, conf_min=-1e9,
                            conf_max=1e9, integer=False)
    ctl = SinglePoleController(model, GoalSpec(100.0, hard=True), 0.0, pole=0.9)
    ctl.observe(99.0)   # deep in danger
    c = ctl.actuate()
    assert abs(c) == pytest.approx(0.1 * 9.0, abs=1e-6)  # still 1-p = 0.1 gain


def test_ablation_no_virtual_goal_targets_real_goal():
    model = ControllerModel(alpha=1.0, delta=1.0, lam=0.2, conf_max=1e9)
    ctl = NoVirtualGoalController(model, GoalSpec(100.0, hard=True), 0.0)
    assert ctl.virtual_goal == 100.0
