"""Unified prefill+decode ticks: ONE ``step_packed`` dispatch per tick
carries prefill chunks AND every running slot's decode token as a length-1
segment.  The correctness bar is engine-level token identity against the
split prefill/decode path (bucketed = split chunked oracle, legacy =
one-shot oracle) for every text arch, dense AND paged KV — plus the
dispatch-count contract the tentpole exists for: steady-state ticks cost
exactly one compiled dispatch instead of two."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import zoo
from repro.serve import Request, ServeEngine

PROMPT_LENS = (5, 19, 33)
MAX_NEW = 4


def _smoke_cfg(arch_id):
    cfg = reduced(get_config(arch_id))
    if cfg.moe:   # ample capacity -> deterministic routing for equivalence
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


def _run(cfg, params, prompts, mode, kv_mode="auto", chunk=16, max_batch=2,
         cache_len=96):
    eng = ServeEngine(cfg, params, max_batch=max_batch, cache_len=cache_len,
                      enable_smartconf=False, prefill_mode=mode,
                      kv_mode=kv_mode)
    eng.prefill_chunk = chunk
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, MAX_NEW))
    ticks = max_dispatches = 0
    dispatch_ticks = 0
    while len(eng.finished) < len(prompts) and ticks < 400:
        st = eng.tick()
        ticks += 1
        max_dispatches = max(max_dispatches, st["dispatches"])
        dispatch_ticks += st["dispatches"]
    assert len(eng.finished) == len(prompts), (cfg.name, mode)
    outs = {r.req_id: list(r.generated) for r in eng.finished}
    stats = dict(max_dispatches=max_dispatches,
                 dispatches_per_tick=dispatch_ticks / ticks,
                 programs=eng.model_programs, paged=eng.paged)
    eng.close()
    return outs, stats


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if a not in ("whisper-tiny",
                                                  "internvl2-1b")])
def test_unified_matches_split_every_text_arch(arch_id, rng):
    """All 8 text archs: the unified packed engine (kv auto: paged where
    supported) generates token-identical output to the split bucketed
    engine, with at most ONE model dispatch per tick (vs. the split
    path's two)."""
    cfg = _smoke_cfg(arch_id)
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in PROMPT_LENS]
    split, split_st = _run(cfg, params, prompts, "bucketed")
    unified, uni_st = _run(cfg, params, prompts, "packed")
    assert split == unified, arch_id
    assert uni_st["max_dispatches"] == 1
    assert split_st["max_dispatches"] == 2       # prefill + decode ticks
    assert uni_st["dispatches_per_tick"] <= split_st["dispatches_per_tick"]


@pytest.mark.parametrize("arch_id", ["yi-6b", "gemma3-4b",
                                     "deepseek-moe-16b"])
@pytest.mark.parametrize("kv_mode", ["dense", "paged"])
def test_unified_matches_one_shot_dense_and_paged(arch_id, kv_mode, rng):
    """Explicit dense AND paged KV against the one-shot legacy oracle —
    including the windowed gemma3 local layers and MoE routing riding the
    fused paged segment kernel's write-then-attend path."""
    cfg = _smoke_cfg(arch_id)
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, int(n)).astype(np.int32)
               for n in PROMPT_LENS]
    legacy, _ = _run(cfg, params, prompts, "legacy", kv_mode="dense")
    unified, st = _run(cfg, params, prompts, "packed", kv_mode=kv_mode)
    assert legacy == unified, (arch_id, kv_mode)
    assert st["paged"] == (kv_mode == "paged")
    assert st["max_dispatches"] == 1


def test_unified_fuses_decode_program(rng):
    """Mixed ticks fuse decode into the stream dispatch, so the unified
    engine's total program count never exceeds the split engine's (both
    may compile the standalone decode program — unified only for the
    decode-only drain tail, split for every running tick)."""
    cfg = _smoke_cfg("yi-6b")
    params, _ = zoo.init(cfg, jax.random.key(0))
    prompts = [rng.integers(0, cfg.vocab_size, 21).astype(np.int32)]
    _, uni = _run(cfg, params, prompts, "packed")
    _, spl = _run(cfg, params, prompts, "bucketed")
    assert uni["programs"] <= spl["programs"]
    assert uni["max_dispatches"] == 1 and spl["max_dispatches"] == 2


def test_unified_decode_rides_budget_but_never_starves_prefill(rng):
    """Decode riders count against the literal token budget, but prefill
    keeps a one-token floor: with budget == 1 and a full decode batch the
    prefilling request still advances every tick (no livelock)."""
    cfg = _smoke_cfg("yi-6b")
    params, _ = zoo.init(cfg, jax.random.key(0))
    short = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    long = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
    eng = ServeEngine(cfg, params, max_batch=2, cache_len=64,
                      enable_smartconf=False, prefill_mode="packed")
    eng.prefill_chunk = 1
    eng.submit(Request(0, short, 30))
    for _ in range(8):
        eng.tick()                    # short req is decoding by now
    assert len(eng.running) == 1
    eng.submit(Request(1, long, 2))
    req = eng.waiting[0]
    ticks = 0
    while req.prefilled < len(long) and ticks < 40:
        eng.tick()
        ticks += 1
    assert req.prefilled == len(long), "prefill starved by decode riders"
    assert req.prefill_chunks == len(long)   # one-token floor per tick
    eng.close()
