import os
import sys

# Tests run single-device (the dry-run sets its own 512-device flag in a
# dedicated subprocess; see test_multidevice.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _clear_registry():
    from repro.core.smartconf import GLOBAL_REGISTRY
    yield
    GLOBAL_REGISTRY.clear()
