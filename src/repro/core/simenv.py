"""Deterministic workload simulator for the paper's six case studies (§6).

The paper evaluates SmartConf on live Cassandra/HBase/HDFS/MapReduce clusters.
This build reproduces those six PerfConf issues as discrete-event dynamics so
the controller behaviour (constraint satisfaction, trade-off throughput,
ablations, interacting controllers) is measurable deterministically on CPU —
the controller code under test is *identical* to the one driving the real
serve/train loops in this framework (DESIGN.md §2).

Each case study implements the paper's Table 6 recipe:
  * a *profiling* workload different from evaluation (``phase = -1``),
  * a two-phase evaluation workload (``phase = 0`` then ``1``) where the
    workload or the goal changes at ``phase_boundary``,
  * at least one phase that triggers the user-reported failure under the
    original default configuration.

Time advances in fixed control intervals (1 simulated second).  All noise is
drawn from a seeded ``numpy.random.Generator``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .controller import GoalSpec

__all__ = [
    "Trace",
    "CaseStudy",
    "CA6059",
    "HB2149",
    "HB3813",
    "HB6728",
    "HD4995",
    "MR2820",
    "ALL_CASES",
    "StaticPolicy",
    "SmartConfPolicy",
]

PROFILE_PHASE = -1


@dataclasses.dataclass
class Trace:
    """Result of one evaluation run."""

    t: np.ndarray               # interval index
    metric: np.ndarray          # constrained metric per interval
    conf: np.ndarray            # configuration value per interval
    deputy: np.ndarray          # deputy variable (== conf for direct confs)
    tradeoff: np.ndarray        # per-interval trade-off reward (e.g. ops served)
    goal: np.ndarray            # active goal per interval (may change at phase 2)
    first_violation: int | None # first interval where the goal broke
    violations: int
    hard: bool = True

    @property
    def failed(self) -> bool:
        """Hard goals: any violation is a crash (OOM/OOD).  Soft goals: the
        SLA is broken when the metric does not *track* the goal — steady-state
        mean above 1.05x goal or p95 above 1.25x goal (measured per phase,
        skipping a settling window)."""
        if self.hard:
            return self.first_violation is not None
        n = len(self.t)
        settle = max(10, n // 10)
        half = n // 2
        for lo, hi in ((settle, half), (half + settle, n)):
            m, g = self.metric[lo:hi], self.goal[lo:hi]
            if len(m) == 0:
                continue
            if m.mean() > 1.05 * g.mean() or np.quantile(m, 0.95) > 1.25 * g.mean():
                return True
        return False

    @property
    def total_tradeoff(self) -> float:
        return float(self.tradeoff.sum())


class StaticPolicy:
    """Traditional configuration: one launch-time value, never adjusted."""

    def __init__(self, value: float) -> None:
        self.value = float(value)

    def __call__(self, metric: float, deputy: float, t: int) -> float:
        return self.value


class SmartConfPolicy:
    """Adapter: drives a SmartConf object exactly the way application code
    does — setPerf(actual[, deputy]) then getConf() (paper §4.1.2)."""

    def __init__(self, smartconf, indirect: bool) -> None:
        self.smartconf = smartconf
        self.indirect = indirect

    def __call__(self, metric: float, deputy: float, t: int) -> float:
        if self.indirect:
            self.smartconf.set_perf(metric, deputy)
        else:
            self.smartconf.set_perf(metric)
        return float(self.smartconf.get_conf())


class CaseStudy:
    """Base class.  Subclasses define the dynamics via :meth:`_step`."""

    name: str = "base"
    issue: str = ""
    indirect: bool = False
    conditional: bool = False
    goal: GoalSpec = GoalSpec(1.0, hard=True)
    phase2_goal: GoalSpec | None = None   # for runs where the *goal* changes
    horizon: int = 400
    phase_boundary: int = 200
    conf_grid: Sequence[float] = ()
    buggy_default: float = 0.0
    patched_default: float = 0.0
    conf_min: float = 0.0
    conf_max: float = float("inf")
    integer: bool = True
    metric_name: str = "metric"
    tradeoff_name: str = "throughput"

    # ---- dynamics ----------------------------------------------------------
    def _reset(self, rng: np.random.Generator) -> dict:
        raise NotImplementedError

    def _step(self, state: dict, conf: float, t: int, phase: int,
              rng: np.random.Generator) -> tuple[float, float, float]:
        """Advance one interval.  Returns (metric, deputy, tradeoff_reward)."""
        raise NotImplementedError

    def active(self, t: int, state: dict) -> bool:
        """Conditional PerfConfs only engage their controller on the intervals
        where the configuration actually takes effect (paper §4.2)."""
        return True

    def profile_keep(self, state: dict, t: int) -> bool:
        """Whether this interval's sample is informative for model fitting."""
        return True

    # ---- profiling (paper §5.5) ---------------------------------------------
    def profile(self, conf_values: Sequence[float] | None = None, *,
                intervals: int = 60, seed: int = 0
                ) -> list[tuple[float, float]]:
        """Run the *profiling* workload at a sweep of pinned configuration
        values; returns (deputy-or-conf value, metric) samples."""
        if conf_values is None:
            grid = list(getattr(self, "profile_grid", None) or self.conf_grid)
            step = max(1, len(grid) // 8)
            conf_values = grid[::step]  # 8-point sweep
        rng = np.random.default_rng(seed)
        samples: list[tuple[float, float]] = []
        for cv in conf_values:
            state = self._reset(rng)
            for t in range(intervals):
                metric, deputy, _ = self._step(state, cv, t, PROFILE_PHASE, rng)
                if t >= intervals // 3 and self.profile_keep(state, t):
                    key = deputy if self.indirect else cv
                    samples.append((float(key), float(metric)))
        return samples

    # ---- evaluation -----------------------------------------------------------
    def evaluate(self, policy: Callable[[float, float, int], float], *,
                 seed: int = 1, horizon: int | None = None) -> Trace:
        horizon = horizon or self.horizon
        rng = np.random.default_rng(seed)
        state = self._reset(rng)
        metric_v = np.zeros(horizon)
        conf_v = np.zeros(horizon)
        deputy_v = np.zeros(horizon)
        reward_v = np.zeros(horizon)
        goal_v = np.zeros(horizon)
        first_violation = None
        violations = 0
        conf = getattr(policy, "value", None)
        if conf is None:
            conf = self.initial_conf()
        metric, deputy = 0.0, 0.0
        goal = self.goal
        for t in range(horizon):
            phase = 0 if t < self.phase_boundary else 1
            if phase == 1 and self.phase2_goal is not None and goal is not self.phase2_goal:
                goal = self.phase2_goal
                sc = getattr(policy, "smartconf", None)
                if sc is not None:
                    sc.set_goal(goal)  # runtime goal update (paper §4.3)
            if self.active(t, state):
                conf = policy(metric, deputy, t)
                conf = min(max(conf, self.conf_min), self.conf_max)
                if self.integer:
                    conf = float(int(round(conf)))
            metric, deputy, reward = self._step(state, conf, t, phase, rng)
            violated = (metric > goal.value) if goal.direction == "upper" else (metric < goal.value)
            if violated:
                violations += 1
                if first_violation is None:
                    first_violation = t
            metric_v[t], conf_v[t], deputy_v[t] = metric, conf, deputy
            reward_v[t], goal_v[t] = reward, goal.value
        return Trace(np.arange(horizon), metric_v, conf_v, deputy_v, reward_v,
                     goal_v, first_violation, violations, hard=self.goal.hard)

    def initial_conf(self) -> float:
        return self.conf_min

    # ---- static search (paper §6.3: exhaustive best-static) -----------------
    def best_static(self, *, seed: int = 1) -> tuple[float, Trace]:
        """Exhaustive search for the best launch-time setting that satisfies
        the constraint across BOTH phases — the paper's strongest baseline."""
        best = None
        for cv in self.conf_grid:
            tr = self.evaluate(StaticPolicy(cv), seed=seed)
            if tr.failed:
                continue
            if best is None or tr.total_tradeoff > best[1].total_tradeoff:
                best = (cv, tr)
        if best is None:  # nothing satisfies the constraint; least-bad fallback
            cv = self.conf_grid[0]
            best = (cv, self.evaluate(StaticPolicy(cv), seed=seed))
        return best


class _BurstyQueue(CaseStudy):
    """Shared dynamics for the two HBase RPC-queue issues: a bounded FIFO in
    front of a service whose effective rate grows with queue depth (more
    outstanding RPCs keep more handler threads busy), fed by a *bursty*
    source.  A larger queue absorbs bursts and feeds the handlers (the paper:
    "a larger queue makes a system more responsive to bursty requests at the
    cost of increased memory usage"); a smaller one drops requests.

    The queue tracks items AND bytes: when the workload's item size changes
    (phase 2), in-queue items drain at their enqueue-time size, so memory
    shifts gradually — as in the real HBase run of paper Fig. 6.
    Memory = base(t) + queue_bytes.  The deputy is queue *items* (the unit
    ipc.server.max.queue.size is expressed in)."""

    indirect = True
    goal = GoalSpec(495.0, hard=True)            # MB, paper Fig. 6 red line
    metric_name = "memory_mb"

    base_mem = 200.0
    base_noise_mb = 4.0
    service_rate = 60.0
    depth_knee = 250.0      # items at which handlers are fully utilized
    depth_floor = 0.4       # service fraction at zero depth
    calm_rate = 48.0
    burst_rate = 110.0
    burst_len = 10
    burst_prob = 1.0 / 18.0
    profile_rate = 75.0     # sustained load: fills the queue to each cap

    def _item_mb(self, phase):
        raise NotImplementedError

    def _base(self, t, phase, rng):
        wobble = 0.0
        if phase == PROFILE_PHASE:
            # Profiling runs co-located compactions etc. so the synthesized
            # lambda captures realistic environmental disturbance (§5.5:
            # "the larger the range of workloads, the more robust").
            wobble = 0.4 * self.base_mem * np.sin(t / 7.0)
        return self.base_mem + wobble + self.base_noise_mb * rng.standard_normal()

    def _reset(self, rng):
        return {"items": 0.0, "bytes": 0.0, "burst_left": 0}

    def _step(self, state, conf, t, phase, rng):
        if phase == PROFILE_PHASE:
            rate = self.profile_rate
        else:
            if state["burst_left"] > 0:
                state["burst_left"] -= 1
                rate = self.burst_rate
            else:
                if rng.random() < self.burst_prob:
                    state["burst_left"] = self.burst_len
                rate = self.calm_rate
        arrivals = float(rng.poisson(rate))
        room = max(0.0, conf - state["items"])
        admitted = min(arrivals, room)
        state["items"] += admitted
        state["bytes"] += admitted * self._item_mb(phase, t)
        # OOM strikes at the intra-interval PEAK: measure memory (and the
        # deputy the threshold caps) right after admission, before the
        # handlers drain the queue.
        peak_items = state["items"]
        mem = self._base(t, phase, rng) + state["bytes"]
        depth_util = self.depth_floor + (1.0 - self.depth_floor) * min(
            1.0, state["items"] / self.depth_knee)
        served = min(state["items"],
                     self.service_rate * depth_util
                     * (1.0 + 0.06 * rng.standard_normal()))
        served = max(served, 0.0)
        if state["items"] > 0:
            state["bytes"] = max(0.0, state["bytes"] * (1.0 - served / state["items"]))
        state["items"] -= served
        return mem, peak_items, served


# ---------------------------------------------------------------------------
# HB3813 — ipc.server.max.queue.size (indirect, hard memory).  Paper Fig. 6.
# Profiling: YCSB 0.5W 1MB sustained.  Eval: bursty 1MB -> bursty 2MB.
# ---------------------------------------------------------------------------
class HB3813(_BurstyQueue):
    name = "HB3813"
    issue = "RPC-call queue size: too big -> OOM; too small -> throughput hurts"
    conf_grid = tuple(range(10, 1001, 10))
    buggy_default = 1000.0
    patched_default = 100.0
    conf_min, conf_max = 0.0, 5000.0
    tradeoff_name = "rpcs_served"

    def _item_mb(self, phase, t=0):
        if phase == 1:
            frac = min(1.0, max(0.0, (t - self.phase_boundary) / 20.0))
            return 1.0 + 0.8 * frac
        return 1.0


# ---------------------------------------------------------------------------
# HB6728 — ipc.server.response.queue.maxsize (indirect, hard memory).
# Responses are 2MB at evaluation time (reads of large cells), 1.5MB during
# profiling; phase 2 diverts 30% of capacity to writes (slower drain).
# ---------------------------------------------------------------------------
class HB6728(_BurstyQueue):
    name = "HB6728"
    issue = "RPC-response queue size: too big -> OOM; too small -> throughput hurts"
    conf_grid = tuple(range(10, 801, 10))
    buggy_default = 100000.0      # originally unbounded
    patched_default = 500.0       # patch: 1G bytes ~= 500 x 2MB responses
    conf_min, conf_max = 0.0, 5000.0
    tradeoff_name = "responses_sent"

    base_mem = 180.0
    calm_rate = 40.0
    burst_rate = 110.0
    service_rate = 50.0
    profile_rate = 65.0

    def _item_mb(self, phase, t=0):
        return 1.2 if phase == PROFILE_PHASE else 1.8

    def _step(self, state, conf, t, phase, rng):
        if phase == 1:
            # writes steal service capacity from the response path
            old = self.service_rate
            self.service_rate = old * 0.8
            out = super()._step(state, conf, t, phase, rng)
            self.service_rate = old
            return out
        return super()._step(state, conf, t, phase, rng)


# ---------------------------------------------------------------------------
# CA6059 — memtable_total_space_in_mb (indirect, hard memory).
# Bigger memtables flush less often (each flush start costs a compaction
# stall); phase 2 grows the off-memtable heap (C0.5 read cache warming up).
# ---------------------------------------------------------------------------
class CA6059(CaseStudy):
    name = "CA6059"
    issue = "memtable size cap: too big -> OOM; too small -> write latency hurts"
    indirect = True
    goal = GoalSpec(1024.0, hard=True)   # JVM heap MB
    conf_grid = tuple(range(32, 801, 16))
    buggy_default = 768.0
    patched_default = 256.0   # developers' "conservative setting"
    conf_min, conf_max = 16.0, 2048.0
    metric_name = "heap_mb"
    tradeoff_name = "writes_absorbed"

    flush_rate = 300.0            # MB/interval drained by a running flush
    flush_trigger = 1.0           # flush starts when memtable hits the cap
    flush_penalty = 0.8           # throughput hit on a flush-start interval
    cache_ramp = 30               # intervals for phase-2 heap growth

    def _other_heap(self, phase, t, boundary):
        if phase == PROFILE_PHASE:
            # co-located compaction during profiling: lambda learns disturbance
            return 280.0 * (1.0 + 0.12 * np.sin(t / 9.0))
        if phase == 0:
            return 300.0
        ramp = min(1.0, (t - boundary) / self.cache_ramp)
        return 300.0 + 260.0 * ramp

    def _write_rate(self, phase):
        return {PROFILE_PHASE: 70.0, 0: 140.0, 1: 105.0}[phase]

    def _reset(self, rng):
        return {"memtable": 0.0, "flushing": False}

    def _step(self, state, conf, t, phase, rng):
        writes = max(0.0, self._write_rate(phase) * (1.0 + 0.12 * rng.standard_normal()))
        absorbed = writes
        started_flush = False
        if not state["flushing"] and state["memtable"] >= self.flush_trigger * conf:
            state["flushing"] = True
            started_flush = True
        if state["flushing"]:
            state["memtable"] = max(0.0, state["memtable"] - self.flush_rate)
            if state["memtable"] <= 0.25 * max(conf, 1.0):
                state["flushing"] = False
        if started_flush:
            absorbed = writes * (1.0 - self.flush_penalty)  # compaction stall
        if state["memtable"] >= conf:                       # memtable full
            absorbed = min(absorbed, writes * 0.3)
        state["memtable"] = min(state["memtable"] + absorbed, max(conf, 0.0))
        heap = (self._other_heap(phase, t, self.phase_boundary)
                + 5.0 * rng.standard_normal() + state["memtable"])
        return heap, state["memtable"], absorbed


# ---------------------------------------------------------------------------
# HB2149 — global.memstore.lowerLimit (direct, conditional, soft latency).
# Eval phases share the workload; the latency GOAL tightens 10s -> 5s.
# Each flush blocks writes for conf/flush-rate seconds AND costs a fixed
# stall, so flushing too often (small conf) also destroys throughput.
# ---------------------------------------------------------------------------
class HB2149(CaseStudy):
    name = "HB2149"
    issue = "flush amount: too big -> writes blocked too long; too small -> too often"
    indirect = False
    conditional = True
    goal = GoalSpec(10.0, hard=False)            # worst write-block seconds
    phase2_goal = GoalSpec(5.0, hard=False)      # paper: constraint tightens
    conf_grid = tuple(range(8, 257, 8))          # MB flushed per blocking flush
    buggy_default = 248.0
    patched_default = 144.0
    conf_min, conf_max = 4.0, 512.0
    metric_name = "block_seconds"
    tradeoff_name = "writes_committed"

    flush_mb_per_s = 24.0       # flushing drains this fast while blocking
    fixed_stall = 0.55          # fixed fraction of an interval lost per flush

    def _write_rate(self, phase):
        return 50.0 if phase == PROFILE_PHASE else 100.0

    def _reset(self, rng):
        return {"pending": 0.0, "since_flush": 0, "worst": 0.0, "flushed_now": False}

    def active(self, t, state):
        return state["since_flush"] == 0  # controller consulted at flush points

    def profile_keep(self, state, t):
        return state["flushed_now"]

    def _step(self, state, conf, t, phase, rng):
        writes = max(0.0, self._write_rate(phase) * (1.0 + 0.08 * rng.standard_normal()))
        state["pending"] += writes
        block_s = 0.0
        committed = writes
        state["flushed_now"] = False
        if state["pending"] >= conf * 2.0:  # memstore reached the upper limit
            block_s = (conf / self.flush_mb_per_s) * (1.0 + 0.08 * rng.standard_normal())
            block_s = max(block_s, 0.05)
            state["pending"] = max(0.0, state["pending"] - conf)
            loss = min(1.0, self.fixed_stall + block_s / 30.0)
            committed = writes * (1.0 - loss)
            state["since_flush"] = 0
            state["flushed_now"] = True
        else:
            state["since_flush"] += 1
        # metric: worst-case block latency observed recently (decays slowly)
        state["worst"] = max(block_s, state["worst"] * 0.7)
        return state["worst"], conf, committed


# ---------------------------------------------------------------------------
# HD4995 — content-summary.limit (indirect, conditional, soft latency).
# Profiling: single-thread TestDFSIO (contention 2).  Eval: multi-thread
# (contention 3) in both phases; the latency GOAL tightens 20s -> 10s.
# Small chunks churn the namenode lock (5s amortized re-walk per release).
# ---------------------------------------------------------------------------
class HD4995(CaseStudy):
    name = "HD4995"
    issue = "files traversed per namenode lock: too big -> writes blocked; too small -> du slow"
    indirect = True
    conditional = True
    goal = GoalSpec(20.0, hard=False)         # write-block seconds
    phase2_goal = GoalSpec(10.0, hard=False)
    conf_grid = tuple(range(500, 20001, 250))
    buggy_default = 2_000_000.0   # original hard-coded: traverse everything
    patched_default = 500.0
    conf_min, conf_max = 100.0, 2_000_000.0
    metric_name = "write_block_seconds"
    tradeoff_name = "du_progress_kfiles"

    per_file_ms = 1.0
    lock_reacquire_s = 5.0

    def _contention(self, phase):
        return 2.0 if phase == PROFILE_PHASE else 3.0

    def _reset(self, rng):
        return {"remaining": 2_000_000.0}

    def _step(self, state, conf, t, phase, rng):
        traversed = min(conf, state["remaining"])
        block_s = traversed * self.per_file_ms / 1000.0 * self._contention(phase)
        block_s *= (1.0 + 0.06 * rng.standard_normal())
        block_s = max(block_s, 0.0)
        state["remaining"] -= traversed
        if state["remaining"] <= 0:
            state["remaining"] = 2_000_000.0   # next du command begins
        # du progress per wall-second: traversal amortized over lock churn
        seconds = block_s + self.lock_reacquire_s
        progress = traversed / max(seconds, 1e-6)
        return block_s, traversed, progress / 1000.0


# ---------------------------------------------------------------------------
# MR2820 — local.dir.minspacestart (direct, conditional, hard disk).
# A task spills most of its intermediate data right after starting (sort
# buffers), then trickles the rest; the config is the free-space guard the
# scheduler checks before starting a task.  Profiling: 64MB splits.  Eval:
# 64MB -> 128MB splits (phase 2 needs much more headroom).
# ---------------------------------------------------------------------------
class MR2820(CaseStudy):
    name = "MR2820"
    issue = "min free disk to start task: too small -> OOD; too big -> low utilization"
    indirect = False
    conditional = True
    goal = GoalSpec(1000.0, hard=True)        # disk capacity MB (stay below)
    conf_grid = tuple(range(10, 801, 10))
    profile_grid = tuple(range(120, 751, 70))  # sweep the binding region
    buggy_default = 0.0       # original default: no space check at all
    patched_default = 1.0     # patch: 1MB - still fails
    conf_min, conf_max = 0.0, 1000.0
    metric_name = "disk_used_mb"
    tradeoff_name = "tasks_completed"

    capacity = 1000.0
    tau = 8.0                 # task turnover: spool drains as tasks complete

    def _rate(self, phase):
        # aggregate spill inflow of starting tasks (MB/interval)
        return {PROFILE_PHASE: 70.0, 0: 60.0, 1: 75.0}[phase]

    def _need(self, phase):
        # intermediate bytes per task: phase 2 runs much bigger splits
        return {PROFILE_PHASE: 30.0, 0: 20.0, 1: 44.0}[phase]

    def _base(self, t, phase, rng):
        if phase == PROFILE_PHASE:
            # profiling co-locates HDFS block/log churn: teaches lambda
            base = 500.0 * (1.0 + 0.18 * np.sin(t / 6.0))
        elif phase == 0:
            base = 550.0    # phase 1: disk crowded by input/shuffle data
        else:
            frac = min(1.0, (t - self.phase_boundary) / 15.0)
            base = 550.0 - 100.0 * frac   # phase 2: less input, bigger spills
        return base + 8.0 * rng.standard_normal()

    def _reset(self, rng):
        return {"spool": 0.0, "gate": True}

    def active(self, t, state):
        return state["gate"]  # consulted at scheduling points only

    def _step(self, state, conf, t, phase, rng):
        base = self._base(t, phase, rng)
        used = base + state["spool"]
        free = self.capacity - used
        # Scheduler: start tasks this interval iff free space clears the guard.
        state["gate"] = free >= conf
        inflow = self._rate(phase) * (1.0 + 0.06 * rng.standard_normal()) if state["gate"] else 0.0
        drained = state["spool"] / self.tau
        state["spool"] = max(0.0, state["spool"] + inflow - drained)
        used = base + state["spool"]
        completions = drained / self._need(phase)
        return used, conf, float(completions)


ALL_CASES: dict[str, type[CaseStudy]] = {
    c.name: c for c in (CA6059, HB2149, HB3813, HB6728, HD4995, MR2820)
}
