"""SmartConf core — the paper's contribution (Wang et al. 2017).

Public API:

  * :class:`SmartConf` / :class:`SmartConfIndirect` / :class:`Transducer` —
    the developer-facing configuration objects (paper §4).
  * :class:`GoalSpec` — user-facing performance goal (value, hard?).
  * :class:`SmartController` + synthesis helpers — the control law (paper §5).
  * ``jax_controller`` — jittable pytree twin for in-graph knobs.
  * ``sensors`` — performance sensors for the framework's own PerfConfs.
  * ``simenv`` — deterministic replicas of the paper's six case studies.
"""

from .controller import (
    ControllerModel,
    GoalSpec,
    SmartController,
    compute_pole,
    compute_virtual_goal,
    fit_model,
)
from .smartconf import (
    ConfRegistry,
    GLOBAL_REGISTRY,
    Guardrails,
    SmartConf,
    SmartConfIndirect,
    Transducer,
    parse_goals_file,
    parse_sys_file,
)
from .profiler import ProfileBuffer, read_sysfile, synthesize, write_sysfile
from .telemetry import (
    Decision,
    DecisionLog,
    FlightRecorder,
    MetricsRegistry,
    Telemetry,
    Tracer,
)
from .sensors import (
    HBMAccountant,
    LatencySensor,
    QueueGauge,
    StepTimer,
    ThroughputSensor,
    device_live_bytes,
)
from . import ablations, jax_controller, simenv

__all__ = [
    "ControllerModel", "GoalSpec", "SmartController",
    "compute_pole", "compute_virtual_goal", "fit_model",
    "ConfRegistry", "GLOBAL_REGISTRY", "Guardrails", "SmartConf",
    "SmartConfIndirect", "Transducer", "parse_goals_file", "parse_sys_file",
    "ProfileBuffer", "read_sysfile", "synthesize", "write_sysfile",
    "Decision", "DecisionLog", "FlightRecorder", "MetricsRegistry",
    "Telemetry", "Tracer",
    "HBMAccountant", "LatencySensor", "QueueGauge", "StepTimer",
    "ThroughputSensor", "device_live_bytes",
    "ablations", "jax_controller", "simenv",
]
