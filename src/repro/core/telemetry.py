"""Flight recorder for the control loop: metrics, traces, decision audit.

Zero-dependency observability for the SmartConf serving stack.  Four
cooperating pieces, bundled behind one :class:`Telemetry` hub:

- :class:`MetricsRegistry` — counters, gauges, and fixed-bucket
  histograms with p50/p90/p99 readout.  Histograms bucket at record
  time, so readout is O(buckets) and the registry never holds raw
  samples.
- :class:`Tracer` — span tracer emitting Chrome trace-event JSON
  (``trace.json``), loadable in Perfetto / ``chrome://tracing``.  The
  serve engine stamps one span per tick with nested phase spans
  (control → admit → schedule → pack → dispatch → sample → finish),
  chaos events and preemptions as instant markers, and request
  lifetimes as async begin/end pairs.
- :class:`FlightRecorder` — bounded ring of the last N ticks of raw
  sensor readings (pre- and post-``sensor_tap``), dumped automatically
  on guardrail faults, rejection storms, or chaos triggers.
- :class:`DecisionLog` — structured :class:`Decision` record per
  controller actuation: sensor value in, guardrail verdict, error
  term, raw vs. slew-clamped output, fallback-engaged flag.  Queryable,
  so tests assert "the NaN window engaged last-known-good on tick 41"
  instead of grepping stdout.

Design constraints, both load-bearing:

- **Off by default, free when off.**  Consumers hold ``None`` instead
  of a disabled hub (see ``ServeEngine.__init__``), so the disabled
  path is the pre-telemetry code path: no allocation, no virtual
  dispatch, measured <1% tick-latency overhead (``bench_overhead``
  gates this in CI).
- **Deterministic under ``VirtualClock``.**  Timestamps come from the
  injected clock, dict key order is insertion order, and JSON encoding
  sanitizes non-finite floats — same seed + same trace means
  byte-identical ``audit.jsonl`` and span ordering.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Any, Callable, Iterable

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Tracer", "FlightRecorder", "Decision", "DecisionLog", "Telemetry",
    "DEFAULT_LATENCY_BUCKETS",
]

# Latency buckets in seconds: 100us .. ~100s, roughly x2 per step.  Wide
# enough for virtual-time tick costs (0.02-0.2s) and real wall ticks.
DEFAULT_LATENCY_BUCKETS: tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
)


def _finite(v: Any) -> Any:
    """JSON-safe scalar: strict JSON has no NaN/Infinity literals."""
    if isinstance(v, float) and not math.isfinite(v):
        return repr(v)  # "nan", "inf", "-inf"
    return v


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Fixed-bucket histogram with quantile readout.

    Values are bucketed at record time against sorted upper-bound
    ``buckets`` (plus an implicit +inf overflow bucket).  Quantiles are
    read back as the upper bound of the bucket holding that rank —
    coarse but allocation-free and deterministic.
    """

    __slots__ = ("name", "buckets", "counts", "count", "total", "_min", "_max")

    def __init__(self, name: str,
                 buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS):
        self.name = name
        self.buckets = tuple(sorted(buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        self.counts = [0] * (len(self.buckets) + 1)  # +1: overflow
        self.count = 0
        self.total = 0.0
        self._min = math.inf
        self._max = -math.inf

    def record(self, v: float) -> None:
        if not math.isfinite(v):
            return  # chaos can corrupt sensor values; never poison stats
        # linear scan: bucket lists are ~20 long and most latencies land
        # in the first third, beating bisect's constant factor here
        i = 0
        bs = self.buckets
        n = len(bs)
        while i < n and v > bs[i]:
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.total += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket containing rank q*count (0 if empty)."""
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return self.buckets[i] if i < len(self.buckets) else self._max
        return self._max

    def p50(self) -> float:
        return self.quantile(0.50)

    def p90(self) -> float:
        return self.quantile(0.90)

    def p99(self) -> float:
        return self.quantile(0.99)

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict[str, Any]:
        return {
            "count": self.count,
            "mean": self.mean(),
            "min": self._min if self.count else 0.0,
            "max": self._max if self.count else 0.0,
            "p50": self.p50(),
            "p90": self.p90(),
            "p99": self.p99(),
            "buckets": list(self.buckets),
            "counts": list(self.counts),
        }


class MetricsRegistry:
    """Named counters/gauges/histograms; get-or-create by name."""

    def __init__(self) -> None:
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  buckets: Iterable[float] = DEFAULT_LATENCY_BUCKETS,
                  ) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, buckets)
        return h

    def snapshot(self) -> dict[str, Any]:
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.snapshot()
                           for n, h in sorted(self._histograms.items())},
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=False)
            f.write("\n")


class Tracer:
    """Chrome trace-event tracer; timestamps from an injected clock.

    Events follow the trace-event format's required fields
    (``name/ph/ts/pid/tid``, ``dur`` for complete events): ``ph="X"``
    complete spans, ``ph="i"`` instants, ``ph="b"/"e"`` async pairs for
    request lifetimes.  ``ts`` is microseconds; with a ``VirtualClock``
    the timeline is virtual time and fully deterministic.

    Track (tid) convention: 0 = engine ticks, 1 = driver/arrivals,
    2 = chaos.  The ring is bounded by ``max_events``; overflow is
    counted, never silently resized.
    """

    PID = 1
    TID_ENGINE = 0
    TID_DRIVER = 1
    TID_CHAOS = 2

    def __init__(self, clock: Callable[[], float] | None = None,
                 max_events: int = 200_000):
        self._now = clock if clock is not None else time.monotonic
        self.max_events = max_events
        self.events: list[dict[str, Any]] = []
        self.dropped = 0
        for tid, label in ((self.TID_ENGINE, "engine"),
                           (self.TID_DRIVER, "driver"),
                           (self.TID_CHAOS, "chaos")):
            self.events.append({
                "name": "thread_name", "ph": "M", "pid": self.PID,
                "tid": tid, "args": {"name": label}})

    def now_us(self) -> int:
        return int(self._now() * 1e6)

    def _emit(self, ev: dict[str, Any]) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def complete(self, name: str, ts_us: int, dur_us: int, *,
                 tid: int = TID_ENGINE,
                 args: dict[str, Any] | None = None) -> None:
        ev: dict[str, Any] = {"name": name, "ph": "X", "ts": ts_us,
                              "dur": dur_us, "pid": self.PID, "tid": tid}
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, name: str, *, tid: int = TID_ENGINE,
                ts_us: int | None = None,
                args: dict[str, Any] | None = None) -> None:
        ev: dict[str, Any] = {
            "name": name, "ph": "i",
            "ts": self.now_us() if ts_us is None else ts_us,
            "pid": self.PID, "tid": tid, "s": "t"}
        if args:
            ev["args"] = args
        self._emit(ev)

    def async_begin(self, name: str, event_id: int, *,
                    cat: str = "request", tid: int = TID_DRIVER,
                    args: dict[str, Any] | None = None) -> None:
        ev: dict[str, Any] = {"name": name, "ph": "b", "cat": cat,
                              "id": event_id, "ts": self.now_us(),
                              "pid": self.PID, "tid": tid}
        if args:
            ev["args"] = args
        self._emit(ev)

    def async_end(self, name: str, event_id: int, *,
                  cat: str = "request", tid: int = TID_DRIVER,
                  args: dict[str, Any] | None = None) -> None:
        ev: dict[str, Any] = {"name": name, "ph": "e", "cat": cat,
                              "id": event_id, "ts": self.now_us(),
                              "pid": self.PID, "tid": tid}
        if args:
            ev["args"] = args
        self._emit(ev)

    # --- tick-structured spans -------------------------------------------
    # The engine brackets each tick with begin_tick()/end_tick() and calls
    # phase() at each internal stage boundary.  Under a frozen-per-tick
    # VirtualClock every phase would collapse to zero duration, so phases
    # are synthesized as equal slices of the tick span: the *ordering*
    # admit -> pack -> dispatch -> ... is the ground truth being traced,
    # not wall sub-timings.

    def begin_tick(self, tick: int) -> None:
        self._tick_no = tick
        self._tick_ts = self.now_us()
        self._phases: list[str] = []

    def phase(self, name: str) -> None:
        self._phases.append(name)

    def end_tick(self, args: dict[str, Any] | None = None) -> None:
        ts0 = self._tick_ts
        end = self.now_us()
        dur = max(end - ts0, len(self._phases) or 1)
        self.complete(f"tick {self._tick_no}", ts0, dur,
                      tid=self.TID_ENGINE, args=args)
        if self._phases:
            slice_us = dur // len(self._phases)
            rem = dur - slice_us * len(self._phases)
            t = ts0
            for i, name in enumerate(self._phases):
                d = slice_us + (rem if i == len(self._phases) - 1 else 0)
                self.complete(name, t, d, tid=self.TID_ENGINE,
                              args={"tick": self._tick_no})
                t += d

    def to_json(self) -> dict[str, Any]:
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=None, separators=(",", ":"))
            f.write("\n")


class FlightRecorder:
    """Ring buffer of the last ``window`` ticks of raw sensor readings.

    ``record()`` every tick with the tick's ``{sensor: (raw, tapped)}``
    map; ``dump(reason)`` snapshots the ring.  Dumps are deduplicated:
    a reason already dumped within the last ``window`` ticks is dropped
    (a 10-tick NaN window should produce one dump, not ten), and the
    dump list itself is bounded by ``max_dumps``.
    """

    def __init__(self, window: int = 64, max_dumps: int = 32):
        self.window = window
        self.max_dumps = max_dumps
        self._ring: list[dict[str, Any]] = []
        self.dumps: list[dict[str, Any]] = []
        self.dropped_dumps = 0
        self._last_dump_tick: dict[str, int] = {}

    def record(self, tick: int, readings: dict[str, Any]) -> None:
        self._ring.append({"tick": tick, **readings})
        if len(self._ring) > self.window:
            del self._ring[0]

    def dump(self, reason: str, tick: int) -> bool:
        """Snapshot the ring; returns True if a dump was taken."""
        last = self._last_dump_tick.get(reason)
        if last is not None and tick - last < self.window:
            return False
        if len(self.dumps) >= self.max_dumps:
            self.dropped_dumps += 1
            return False
        self._last_dump_tick[reason] = tick
        self.dumps.append({"reason": reason, "tick": tick,
                           "ring": [dict(r) for r in self._ring]})
        return True

    def snapshot(self) -> dict[str, Any]:
        def san(d: dict[str, Any]) -> dict[str, Any]:
            return {k: ([_finite(x) for x in v]
                        if isinstance(v, (list, tuple)) else _finite(v))
                    for k, v in d.items()}
        return {
            "window": self.window,
            "dropped_dumps": self.dropped_dumps,
            "dumps": [{"reason": d["reason"], "tick": d["tick"],
                       "ring": [san(r) for r in d["ring"]]}
                      for d in self.dumps],
        }

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
            f.write("\n")


@dataclasses.dataclass(frozen=True)
class Decision:
    """One controller actuation, end to end.

    Captured across a ``set_perf`` (sensor in, guardrail verdict) and
    the ``get_conf`` that actuates on it (error term, raw controller
    output vs. the slew-clamped value actually applied, whether the
    last-known-good fallback is pinned).
    """

    tick: int               # engine tick (DecisionLog.tick at append time)
    conf: str               # PerfConf name, e.g. "serve.admit_tier_max"
    metric: str             # sensor metric name, e.g. "ttft_p99_s"
    goal: float             # controller virtual goal
    sensor: float           # reading offered to set_perf (post-tap)
    deputy: float | None    # deputy metric value (indirect confs), else None
    sane: bool              # guardrail verdict on the reading
    error: float            # goal - last admitted perf
    raw: float              # controller/transducer output before guards
    applied: float          # value actually returned by get_conf
    clamped: bool           # slew clamp engaged this actuation
    fallback: bool          # pinned to last-known-good (sensor failed)

    def to_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        return {k: _finite(v) for k, v in d.items()}


class DecisionLog:
    """Append-only, queryable audit log of controller Decisions.

    ``tick`` is stamped by the engine at the top of each tick so
    controllers don't need to know engine internals.  Bounded: beyond
    ``max_records`` the oldest records are discarded (counted).
    """

    def __init__(self, max_records: int = 100_000):
        self.max_records = max_records
        self.records: list[Decision] = []
        self.dropped = 0
        self.tick = 0

    def append(self, d: Decision) -> None:
        if len(self.records) >= self.max_records:
            del self.records[0]
            self.dropped += 1
        self.records.append(d)

    def query(self, **eq: Any) -> list[Decision]:
        """Records where every given field equals the given value."""
        out = self.records
        for k, v in eq.items():
            out = [d for d in out if getattr(d, k) == v]
        return list(out)

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for d in self.records:
                json.dump(d.to_dict(), f, separators=(",", ":"))
                f.write("\n")


class Telemetry:
    """The hub every instrumented component holds (or ``None``).

    ``enabled=False`` builds a stub whose consumers are expected to
    drop it (the serve engine stores ``None`` in that case) — the
    disabled fast path is the *absence* of telemetry, not a null
    object absorbing calls.
    """

    def __init__(self, enabled: bool = True, *,
                 clock: Callable[[], float] | None = None,
                 flight_window: int = 64,
                 max_trace_events: int = 200_000,
                 max_audit_records: int = 100_000):
        self.enabled = enabled
        self.clock = clock
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(clock=clock, max_events=max_trace_events)
        self.flight = FlightRecorder(window=flight_window)
        self.audit = DecisionLog(max_records=max_audit_records)

    @classmethod
    def disabled(cls) -> "Telemetry":
        return cls(enabled=False)

    def write(self, out_dir: str) -> dict[str, str]:
        """Write trace.json + metrics.json + audit.jsonl + flight.json."""
        os.makedirs(out_dir, exist_ok=True)
        paths = {
            "trace": os.path.join(out_dir, "trace.json"),
            "metrics": os.path.join(out_dir, "metrics.json"),
            "audit": os.path.join(out_dir, "audit.jsonl"),
            "flight": os.path.join(out_dir, "flight.json"),
        }
        self.tracer.write(paths["trace"])
        self.metrics.write(paths["metrics"])
        self.audit.write_jsonl(paths["audit"])
        self.flight.write(paths["flight"])
        return paths
