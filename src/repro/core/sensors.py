"""Performance sensors (paper §4.1.1: "developers must provide a sensor").

The framework ships the sensors its own PerfConfs need; applications may add
their own.  All sensors are cheap, thread-safe, and side-effect free so they
can be polled at every control interval.
"""

from __future__ import annotations

import collections
import contextlib
import threading
import time
from typing import Deque

import jax

__all__ = [
    "HBMAccountant",
    "LatencySensor",
    "ThroughputSensor",
    "QueueGauge",
    "StepTimer",
    "device_live_bytes",
]


def device_live_bytes() -> int:
    """Live bytes across addressable devices, from the JAX runtime when the
    backend exposes memory stats (TPU/GPU), else from live array introspection
    (CPU).  This is the deployment-grade sensor behind ``hbm_bytes``."""
    total = 0
    got_stats = False
    for dev in jax.local_devices():
        stats = getattr(dev, "memory_stats", lambda: None)()
        if stats and "bytes_in_use" in stats:
            total += stats["bytes_in_use"]
            got_stats = True
    if got_stats:
        return total
    return sum(x.nbytes for x in jax.live_arrays())


class HBMAccountant:
    """Named byte-ledger for device memory (weights, optimizer, KV blocks,
    activations, queued requests).  The serve engine charges/credits it as it
    admits requests and allocates KV blocks; the SmartConf ``hbm_bytes``
    controllers read :meth:`total`.

    On real hardware :func:`device_live_bytes` cross-checks the ledger; on the
    CPU host the ledger *is* the measurement (DESIGN.md §2)."""

    def __init__(self, budget_bytes: int | None = None) -> None:
        self._ledger: dict[str, int] = {}
        self._lock = threading.Lock()
        self.budget_bytes = budget_bytes
        self.peak_bytes = 0
        self.violations = 0

    def charge(self, name: str, nbytes: int) -> None:
        with self._lock:
            self._ledger[name] = self._ledger.get(name, 0) + int(nbytes)
            tot = sum(self._ledger.values())
            self.peak_bytes = max(self.peak_bytes, tot)
            if self.budget_bytes is not None and tot > self.budget_bytes:
                self.violations += 1

    def credit(self, name: str, nbytes: int) -> None:
        self.charge(name, -int(nbytes))

    def set(self, name: str, nbytes: int) -> None:
        with self._lock:
            self._ledger[name] = int(nbytes)
            tot = sum(self._ledger.values())
            self.peak_bytes = max(self.peak_bytes, tot)
            if self.budget_bytes is not None and tot > self.budget_bytes:
                self.violations += 1

    def total(self) -> int:
        with self._lock:
            return sum(self._ledger.values())

    def breakdown(self) -> dict[str, int]:
        with self._lock:
            return dict(self._ledger)

    def headroom(self) -> int | None:
        if self.budget_bytes is None:
            return None
        return self.budget_bytes - self.total()


class LatencySensor:
    """Sliding-window latency sensor with mean / p50 / p99.

    ``clock`` is injectable (like :class:`ThroughputSensor`) so latency
    tests drive a fake clock deterministically instead of sleeping; it is
    consulted by :meth:`measure`, the span-timing helper."""

    def __init__(self, window: int = 512, clock=time.monotonic) -> None:
        self._buf: Deque[float] = collections.deque(maxlen=window)
        self._clock = clock
        self._lock = threading.Lock()

    def record(self, seconds: float) -> None:
        with self._lock:
            self._buf.append(float(seconds))

    @contextlib.contextmanager
    def measure(self):
        """Context manager recording the span's duration via the clock."""
        t0 = self._clock()
        try:
            yield
        finally:
            self.record(self._clock() - t0)

    def _snapshot(self) -> list[float]:
        with self._lock:
            return sorted(self._buf)

    def count(self) -> int:
        """Samples currently retained in the window."""
        with self._lock:
            return len(self._buf)

    def mean(self) -> float:
        xs = self._snapshot()
        return sum(xs) / len(xs) if xs else 0.0

    def quantile(self, q: float) -> float:
        xs = self._snapshot()
        if not xs:
            return 0.0
        idx = min(int(q * len(xs)), len(xs) - 1)
        return xs[idx]

    def p99(self) -> float:
        return self.quantile(0.99)

    def max(self) -> float:
        xs = self._snapshot()
        return xs[-1] if xs else 0.0


class ThroughputSensor:
    """Events/sec over a sliding time window."""

    def __init__(self, window_seconds: float = 10.0, clock=time.monotonic) -> None:
        self._events: Deque[tuple[float, int]] = collections.deque()
        self.window_seconds = window_seconds
        self._clock = clock
        self._lock = threading.Lock()
        self.total = 0

    def record(self, n: int = 1) -> None:
        now = self._clock()
        with self._lock:
            self._events.append((now, n))
            self.total += n
            self._trim(now)

    def _trim(self, now: float) -> None:
        while self._events and self._events[0][0] < now - self.window_seconds:
            self._events.popleft()

    def rate(self) -> float:
        """Events/sec over the retained window.

        Dividing by the full ``window_seconds`` before the window has
        filled under-reports the rate (bench warm-up, short smoke runs):
        the honest denominator is the elapsed time since the first
        *retained* event, clamped to the window.  A window whose events
        all share one instant has no measurable span; fall back to the
        full window (the conservative old behavior) instead of dividing
        by zero."""
        now = self._clock()
        with self._lock:
            self._trim(now)
            if not self._events:
                return 0.0
            n = sum(c for _, c in self._events)
            span = now - self._events[0][0]
        span = min(self.window_seconds, span)
        if span <= 0.0:
            span = self.window_seconds
        return n / span


class QueueGauge:
    """Instantaneous occupancy gauge for a queue (items and bytes) — the
    deputy-variable sensor for indirect PerfConfs (paper §5.3)."""

    def __init__(self) -> None:
        self.items = 0
        self.nbytes = 0
        self._lock = threading.Lock()

    def add(self, nbytes: int = 0) -> None:
        with self._lock:
            self.items += 1
            self.nbytes += int(nbytes)

    def remove(self, nbytes: int = 0) -> None:
        with self._lock:
            self.items -= 1
            self.nbytes -= int(nbytes)


class StepTimer:
    """Per-step wall-clock timer for the trainer (drives the checkpoint
    overhead controller and straggler detection)."""

    def __init__(self, window: int = 128) -> None:
        self.latency = LatencySensor(window)
        self._start: float | None = None

    def __enter__(self) -> "StepTimer":
        self._start = time.monotonic()
        return self

    def __exit__(self, *exc) -> None:
        if self._start is not None:
            self.latency.record(time.monotonic() - self._start)
            self._start = None

    def mean(self) -> float:
        return self.latency.mean()
