"""Alternative controller designs from the paper's §6.4 comparison.

These exist to reproduce Figure 7 — they are deliberately *worse* designs:

  * :class:`SinglePoleController` — traditional hard-constraint handling
    (Sironi et al. ThermOS): one conservative pole (0.9 in the paper's
    experiment) plus a virtual goal, but NO context-aware second pole.
  * :class:`NoVirtualGoalController` — SmartConf's two-pole switch but
    targeting the *actual* constraint instead of the virtual goal.

Both reuse :class:`~repro.core.controller.SmartController` mechanics so the
comparison isolates exactly the design choice under study.
"""

from __future__ import annotations

from .controller import ControllerModel, GoalSpec, SmartController

__all__ = ["SinglePoleController", "NoVirtualGoalController"]


class SinglePoleController(SmartController):
    """One conservative pole, never switches to the aggressive pole."""

    def __init__(self, model: ControllerModel, goal: GoalSpec, initial_conf: float,
                 *, pole: float = 0.9, **kwargs) -> None:
        super().__init__(model, goal, initial_conf, **kwargs)
        self.pole = pole
        self.aggressive_pole = pole  # the ablation: no context-aware switch


class NoVirtualGoalController(SmartController):
    """Two-pole control, but targets the real constraint (no safety margin)."""

    def __init__(self, model: ControllerModel, goal: GoalSpec, initial_conf: float,
                 **kwargs) -> None:
        super().__init__(model, goal, initial_conf, **kwargs)
        self.virtual_goal = goal.value  # the ablation: no virtual goal

    def set_goal(self, goal: GoalSpec) -> None:
        self.goal = goal
        self.virtual_goal = goal.value
