"""The SmartConf developer API (paper §4, Figs. 2–4).

Developers declare the PerfConf -> metric mapping in a *system file* that is
invisible to users; users state only ``<metric>.goal`` / ``<metric>.goal.hard``
(paper Table 1).  The classes below mirror the paper's Java API:

    SmartConf(conf_name)             # Fig. 3 — direct configurations
        .set_perf(actual)            #   setPerf
        .get_conf()                  #   getConf
        .set_goal(goal)              #   setGoal
    SmartConfIndirect(conf_name, t)  # Fig. 4 — threshold/deputy configurations
        .set_perf(actual, deputy)

camelCase aliases (``setPerf`` etc.) are provided for paper fidelity.

File formats
------------
``SmartConf.sys`` (developer-owned, one line per mapping + initial value):

    serve.max_queue_tokens @ hbm_bytes
    serve.max_queue_tokens = 4096

``<app>.conf`` (user-owned goals):

    hbm_bytes = 15032385536
    hbm_bytes.hard = 1
    hbm_bytes.super_hard = 0

Synthesized model parameters live in ``<ConfName>.smartconf.sys`` (JSON,
written by ``core.profiler``).
"""

from __future__ import annotations

import dataclasses
import math
import os
import threading
import warnings
from typing import Callable

from .controller import ControllerModel, GoalSpec, SmartController
from . import profiler

__all__ = [
    "Transducer",
    "Guardrails",
    "SmartConf",
    "SmartConfIndirect",
    "ConfRegistry",
    "parse_sys_file",
    "parse_goals_file",
]


@dataclasses.dataclass
class Guardrails:
    """Deployment guardrails wrapped around one PerfConf's control loop.

    The paper's controller assumes honest sensors and a plant that tolerates
    any actuation inside ``[conf_min, conf_max]``.  Production serving breaks
    both assumptions: sensors drop out or return NaN under faults, and a
    controller stepping a knob by 10x in one interval can destabilize the
    system it is meant to protect.  Three guards, all off by default:

    * **Sensor sanity** (``perf_lo`` / ``perf_hi``) — a reading that is
      non-finite or outside the plausible range is *rejected*: it never
      reaches Eq. 2, so one NaN cannot poison the integrator.  Each
      rejection counts in :attr:`SmartConf.sensor_faults`.
    * **Fallback to last-known-good** (``fault_tolerance``) — after this
      many *consecutive* insane readings the sensor is declared failed and
      the configuration pins to the last value computed from a sane reading
      (or the explicit ``fallback`` static setting).  Control resumes, from
      that value, on the first sane reading.
    * **Actuation slew clamp + anti-windup** (``max_step``) — one actuation
      may move the configuration by at most ``max_step`` (absolute, in conf
      units).  The clamped value is written back into the controller state,
      so the error integral never winds up beyond what was actually applied
      (the same back-calculation the actuator bounds already get via
      ``_emit``).  Clamped actuations count in
      :attr:`SmartConf.clamped_actuations`.
    """

    max_step: float | None = None
    perf_lo: float = float("-inf")
    perf_hi: float = float("inf")
    fault_tolerance: int = 3
    fallback: float | None = None

    def sane(self, value: float) -> bool:
        return math.isfinite(value) and self.perf_lo <= value <= self.perf_hi


class Transducer:
    """Maps the controller-desired deputy value to the configuration value
    (paper Fig. 4).  The default is the identity: if we want ``queue.size`` to
    drop to K we drop ``max.queue.size`` to K."""

    def transduce(self, value: float) -> float:
        return value


def parse_sys_file(path: str) -> dict:
    """Parse the developer-owned ``SmartConf.sys`` mapping file."""
    mapping: dict[str, dict] = {}
    with open(path, "r", encoding="utf-8") as fh:
        for raw in fh:
            line = raw.split("#", 1)[0].strip()
            if not line:
                continue
            if "@" in line:
                conf, metric = (x.strip() for x in line.split("@", 1))
                mapping.setdefault(conf, {})["metric"] = metric
            elif "=" in line:
                conf, value = (x.strip() for x in line.split("=", 1))
                mapping.setdefault(conf, {})["initial"] = float(value)
    return mapping


def parse_goals_file(path: str) -> dict[str, GoalSpec]:
    """Parse the user-owned goals file into {metric: GoalSpec}."""
    raw: dict[str, dict] = {}
    with open(path, "r", encoding="utf-8") as fh:
        for line_raw in fh:
            line = line_raw.split("#", 1)[0].strip()
            if not line or "=" not in line:
                continue
            key, value = (x.strip() for x in line.split("=", 1))
            if key.endswith(".hard"):
                raw.setdefault(key[: -len(".hard")], {})["hard"] = value not in ("0", "false", "False")
            elif key.endswith(".super_hard"):
                raw.setdefault(key[: -len(".super_hard")], {})["super_hard"] = value not in ("0", "false", "False")
            elif key.endswith(".direction"):
                raw.setdefault(key[: -len(".direction")], {})["direction"] = value
            else:
                raw.setdefault(key, {})["value"] = float(value)
    goals = {}
    for metric, fields in raw.items():
        if "value" not in fields:
            continue
        goals[metric] = GoalSpec(**fields)
    return goals


class ConfRegistry:
    """Process-wide registry: metric name -> SmartConf objects on that metric.

    Implements §5.4's coordination bookkeeping: when a goal is *super-hard*,
    every controller attached to the metric uses the interaction factor
    N = |configs on metric|, splitting the error evenly."""

    def __init__(self) -> None:
        self._by_metric: dict[str, list["SmartConf"]] = {}
        self._lock = threading.Lock()

    def register(self, conf: "SmartConf") -> None:
        with self._lock:
            peers = self._by_metric.setdefault(conf.metric, [])
            if conf not in peers:
                peers.append(conf)
            self._rebalance(conf.metric)

    def unregister(self, conf: "SmartConf") -> None:
        with self._lock:
            peers = self._by_metric.get(conf.metric, [])
            if conf in peers:
                peers.remove(conf)
            self._rebalance(conf.metric)

    def peers(self, metric: str) -> list["SmartConf"]:
        return list(self._by_metric.get(metric, []))

    def _rebalance(self, metric: str) -> None:
        peers = self._by_metric.get(metric, [])
        n = len(peers)
        for c in peers:
            c._controller.set_interacting(n if c.goal.super_hard else 1)

    def clear(self) -> None:
        with self._lock:
            self._by_metric.clear()


GLOBAL_REGISTRY = ConfRegistry()


class SmartConf:
    """A direct PerfConf under automatic control (paper Fig. 3).

    Parameters
    ----------
    conf_name : str
        The configuration's string name; keys the system file entries.
    sys_dir : str
        Directory holding ``SmartConf.sys`` + per-conf synthesized files.
    metric / goal / initial / model :
        Normally read from the system/goals files; may be passed directly for
        programmatic construction (the framework's own PerfConfs do this).
    profiling : bool
        When True, ``set_perf`` records (conf, perf) samples for synthesis
        instead of assuming a trained model exists (paper §5.5).
    """

    def __init__(
        self,
        conf_name: str,
        sys_dir: str | None = None,
        *,
        metric: str | None = None,
        goal: GoalSpec | None = None,
        initial: float | None = None,
        model: ControllerModel | None = None,
        profiling: bool = False,
        registry: ConfRegistry | None = None,
        guardrails: Guardrails | None = None,
    ) -> None:
        self.conf_name = conf_name
        self.sys_dir = sys_dir
        self.registry = registry if registry is not None else GLOBAL_REGISTRY
        self.profiling = profiling
        self.guardrails = guardrails
        self.sensor_faults = 0           # insane readings rejected, total
        self.clamped_actuations = 0      # slew-clamped get_conf calls
        self._consec_faults = 0
        self._sensor_failed = False
        # telemetry audit trail: a core.telemetry.DecisionLog (or None).
        # set_perf stages the sensor-side facts; the matching get_conf
        # completes the Decision with the actuation-side facts.
        self.audit = None
        self._audit_pending: tuple[float, float | None, bool] | None = None

        # Resolve mapping + initial value from SmartConf.sys when on disk.
        if sys_dir is not None:
            sys_path = os.path.join(sys_dir, "SmartConf.sys")
            if os.path.exists(sys_path):
                entry = parse_sys_file(sys_path).get(conf_name, {})
                metric = metric or entry.get("metric")
                if initial is None and "initial" in entry:
                    initial = entry["initial"]
            goals_path = os.path.join(sys_dir, "goals.conf")
            if goal is None and metric is not None and os.path.exists(goals_path):
                goal = parse_goals_file(goals_path).get(metric)
            if model is None:
                payload = profiler.read_sysfile(sys_dir, conf_name)
                if "model" in payload:
                    model = ControllerModel(**payload["model"])
        if metric is None:
            raise ValueError(f"{conf_name}: no metric mapping (SmartConf.sys entry missing)")
        if goal is None:
            raise ValueError(f"{conf_name}: no goal for metric {metric!r} (user goals file missing)")
        if initial is None:
            initial = 0.0  # paper: initial quality does not matter (Fig. 6c starts at 0)
        self.metric = metric
        self.goal = goal
        if model is None:
            if not profiling:
                raise ValueError(
                    f"{conf_name}: no synthesized model; run with profiling=True first"
                )
            model = ControllerModel(alpha=1.0)  # placeholder during profiling
        self._controller = SmartController(model, goal, initial)
        # last configuration value computed from a sane reading: where the
        # guardrails pin the knob when the sensor is declared failed
        self._last_good_conf = float(initial)
        self._profile_buffer = (
            profiler.ProfileBuffer(sys_dir, conf_name) if (profiling and sys_dir) else None
        )
        self._profile_mem: list[tuple[float, float]] = []
        self.registry.register(self)

    # ------------------------------------------------------------ guardrails
    def _admit_reading(self, actual: float) -> bool:
        """Sensor-sanity gate: True if the reading may reach the controller.
        Insane readings (NaN/inf/out-of-range) are dropped; after
        ``fault_tolerance`` consecutive drops the knob pins to the
        last-known-good value until a sane reading arrives."""
        g = self.guardrails
        if g is None:
            return True
        if not g.sane(float(actual)):
            self.sensor_faults += 1
            self._consec_faults += 1
            if self._consec_faults >= max(1, g.fault_tolerance):
                self._sensor_failed = True
            return False
        if self._sensor_failed:
            # resume control FROM the pinned value, not from wherever the
            # integrator drifted while blind (anti-windup across the outage)
            self._controller._conf = self._pinned_conf()
        self._consec_faults = 0
        self._sensor_failed = False
        return True

    def _pinned_conf(self) -> float:
        g = self.guardrails
        fb = g.fallback if (g is not None and g.fallback is not None) \
            else self._last_good_conf
        lo, hi = self._controller.model.conf_min, self._controller.model.conf_max
        return min(max(float(fb), lo), hi)

    def _apply_guards(self, value: float) -> float:
        g = self.guardrails
        if g is None:
            return value
        if self._sensor_failed:
            return self._pinned_conf()
        if g.max_step is not None:
            prev = self._last_good_conf
            clamped = min(max(value, prev - g.max_step), prev + g.max_step)
            if clamped != value:
                self.clamped_actuations += 1
                # anti-windup: the controller must integrate from the value
                # actually applied, not the one it asked for
                self._controller._conf = clamped
            value = clamped
        self._last_good_conf = float(value)
        return value

    @property
    def sensor_failed(self) -> bool:
        """True while the guardrails hold the knob at last-known-good
        because the sensor keeps returning insane readings."""
        return self._sensor_failed

    # ------------------------------------------------------------- telemetry
    def attach_audit(self, log) -> None:
        """Attach a ``core.telemetry.DecisionLog``; every subsequent
        set_perf/get_conf pair appends one :class:`Decision`."""
        self.audit = log

    def _record_decision(self, raw: float, applied: float, *,
                         clamped: bool) -> None:
        log = self.audit
        if log is None:
            return
        from .telemetry import Decision
        pend = self._audit_pending
        self._audit_pending = None
        sensor, deputy, sane = pend if pend is not None \
            else (float("nan"), None, not self._sensor_failed)
        c = self._controller
        lp = c.last_perf
        log.append(Decision(
            tick=log.tick, conf=self.conf_name, metric=self.metric,
            goal=float(self.goal.value), sensor=float(sensor),
            deputy=None if deputy is None else float(deputy), sane=sane,
            error=float("nan") if lp is None else float(c.virtual_goal - lp),
            raw=float(raw), applied=float(applied), clamped=clamped,
            fallback=self._sensor_failed))

    # ------------------------------------------------------------------ API
    def set_perf(self, actual: float) -> None:
        """Feed the latest performance measurement to the controller."""
        ok = self._admit_reading(actual)
        if self.audit is not None:
            self._audit_pending = (float(actual), None, ok)
        if not ok:
            return
        if self.profiling:
            self._record_sample(self._controller.conf, actual)
        self._controller.observe(actual)

    def get_conf(self) -> float:
        """Compute the adjusted configuration value (Eq. 2 machinery)."""
        clamped_before = self.clamped_actuations
        if self._sensor_failed:
            raw = value = self._pinned_conf()
            self._controller._conf = value
        else:
            raw = self._controller.actuate()
            value = self._apply_guards(raw)
        if self._controller.goal_unreachable:
            warnings.warn(
                f"SmartConf[{self.conf_name}]: goal {self.goal.value} on "
                f"{self.metric} unreachable at actuator bound; making best effort",
                RuntimeWarning,
                stacklevel=2,
            )
        out = int(value) if self._controller.model.integer else value
        if self.audit is not None:
            self._record_decision(
                float(raw), float(out),
                clamped=self.clamped_actuations > clamped_before)
        return out

    def set_goal(self, goal: float | GoalSpec) -> None:
        """Runtime goal update by users/administrators (paper §4.3)."""
        if not isinstance(goal, GoalSpec):
            goal = GoalSpec(value=float(goal), hard=self.goal.hard,
                            super_hard=self.goal.super_hard, direction=self.goal.direction)
        self.goal = goal
        self._controller.set_goal(goal)
        self.registry._rebalance(self.metric)

    # Paper-fidelity camelCase aliases (Fig. 3).
    setPerf = set_perf
    getConf = get_conf
    setGoal = set_goal

    # ------------------------------------------------------------ profiling
    def _record_sample(self, conf_value: float, perf: float) -> None:
        self._profile_mem.append((conf_value, perf))
        if self._profile_buffer is not None:
            self._profile_buffer.record(conf_value, perf)

    def force_conf(self, value: float) -> None:
        """Pin the configuration (used by the profiler to sweep values)."""
        self._controller._conf = float(value)

    def clamp_conf_max(self, value: float) -> None:
        """Shrink the actuation ceiling mid-run (capacity loss: a chaos
        budget cut, a neighbour claiming HBM).  The controller keeps
        running against the smaller range; current and last-known-good
        values are pulled inside it so the next actuation cannot bounce
        back above the new ceiling."""
        model = self._controller.model
        model.conf_max = float(value)
        if self._controller._conf > model.conf_max:
            self._controller._conf = model.conf_max
        if self._last_good_conf > model.conf_max:
            self._last_good_conf = model.conf_max

    def finish_profiling(
        self, *, conf_min: float = 0.0, conf_max: float = float("inf"),
        integer: bool = True, min_samples_per_point: int = 2,
    ) -> ControllerModel:
        """Fit Eq. 1 from recorded samples and swap in the real controller."""
        if self._profile_buffer is not None:
            self._profile_buffer.flush()
            model = profiler.synthesize(
                self.sys_dir, self.conf_name,
                conf_min=conf_min, conf_max=conf_max, integer=integer,
                min_samples_per_point=min_samples_per_point,
            )
        else:
            model = profiler.synthesize(
                self.sys_dir or ".", self.conf_name, samples=self._profile_mem,
                conf_min=conf_min, conf_max=conf_max, integer=integer,
                min_samples_per_point=min_samples_per_point,
            ) if self.sys_dir else None
            if model is None:
                from .controller import fit_model  # in-memory fit
                import collections
                grouped = collections.defaultdict(list)
                for c, p in self._profile_mem:
                    grouped[c].append(p)
                confs = sorted(grouped)
                model = fit_model(confs, [grouped[c] for c in confs],
                                  conf_min=conf_min, conf_max=conf_max, integer=integer)
        current = self._controller.conf
        self._controller = SmartController(
            model, self.goal, current,
            n_interacting=self._controller.n_interacting,
        )
        self.profiling = False
        self.registry._rebalance(self.metric)
        return model

    # -------------------------------------------------------------- helpers
    @property
    def controller(self) -> SmartController:
        return self._controller

    def describe(self) -> dict:
        d = self._controller.describe()
        d.update(conf_name=self.conf_name, metric=self.metric)
        return d

    def close(self) -> None:
        self.registry.unregister(self)


class SmartConfIndirect(SmartConf):
    """Indirect/threshold PerfConf (paper Fig. 4 ``SmartConf_I``).

    The controller runs on the deputy variable C'; ``set_perf`` therefore takes
    the deputy's current value, and ``get_conf`` maps the desired deputy value
    through the transducer to produce the threshold configuration C.
    """

    def __init__(self, conf_name: str, sys_dir: str | None = None,
                 transducer: Transducer | Callable[[float], float] | None = None,
                 **kwargs) -> None:
        super().__init__(conf_name, sys_dir, **kwargs)
        if transducer is None:
            transducer = Transducer()
        if callable(transducer) and not isinstance(transducer, Transducer):
            fn = transducer

            class _Fn(Transducer):
                def transduce(self, value: float) -> float:
                    return fn(value)

            transducer = _Fn()
        self.transducer = transducer

    def set_perf(self, actual: float, deputy: float | None = None) -> None:  # type: ignore[override]
        if deputy is None:
            raise TypeError("SmartConfIndirect.set_perf requires the deputy's current value")
        if not math.isfinite(float(deputy)):
            # a corrupted deputy is a sensor fault even when the metric
            # reading itself is sane: Eq. 2 integrates from the deputy
            self.sensor_faults += 1
            self._consec_faults += 1
            if (self.guardrails is not None and self._consec_faults
                    >= max(1, self.guardrails.fault_tolerance)):
                self._sensor_failed = True
            if self.audit is not None:
                self._audit_pending = (float(actual), float(deputy), False)
            return
        ok = self._admit_reading(actual)
        if self.audit is not None:
            self._audit_pending = (float(actual), float(deputy), ok)
        if not ok:
            return
        if self.profiling:
            # Profile against the deputy: it is what actually drives the metric.
            self._record_sample(deputy, actual)
        self._controller.observe(actual, deputy=deputy)

    def get_conf(self) -> float:  # type: ignore[override]
        desired_deputy = self._controller.actuate()
        value = self.transducer.transduce(desired_deputy)
        if self._controller.model.integer:
            value = int(round(value))
        if self.audit is not None:
            self._record_decision(float(desired_deputy), float(value),
                                  clamped=False)
        return value

    setPerf = set_perf
    getConf = get_conf
