"""SmartConf feedback controller (paper §5).

Implements the control law of Wang et al., "Understanding and Auto-Adjusting
Performance-Related Configurations":

    model   (Eq. 1):  s_k     = alpha * c_{k-1}
    control (Eq. 2):  c_{k+1} = c_k + (1 - p) / alpha * e_{k+1},   e = s_goal - s

with the paper's PerfConf-specific extensions:

  * automatic pole selection (§5.1):  Delta = 1 + mean_i(3 sigma_i / m_i),
    p = 1 - 2/Delta if Delta > 2 else 0.  (The paper writes ``m'_i`` — the mean
    of performance measured w.r.t. the minimum; we implement the coefficient-of-
    variation reading, consistent with lambda's definition and the 3-sigma /
    99.7% convergence argument.  See DESIGN.md §10.)
  * hard goals (§5.2): virtual goal s~v = (1 - lambda) * s_goal for upper-bound
    constraints (lambda = mean_i(sigma_i / m_i)), plus *context-aware* two-pole
    control — the regular pole inside the safe region and pole 0 (the most
    aggressive stable pole) once the virtual goal is crossed.
  * interaction factor (§5.4): for *super-hard* goals shared by N configs the
    gain becomes (1 - p) / (N * alpha), splitting the error across controllers.

The controller is deliberately tiny: its value is in the synthesis rules, not
in the arithmetic.  ``core/jax_controller.py`` provides the jittable pytree
twin used inside compiled training/serving loops.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Sequence

__all__ = [
    "GoalSpec",
    "ControllerModel",
    "SmartController",
    "fit_model",
    "compute_pole",
    "compute_virtual_goal",
]


@dataclasses.dataclass
class GoalSpec:
    """User-facing goal (paper §4.3): a number plus hard/super-hard flags.

    ``direction`` encodes which side of the goal is safe:
      * ``"upper"`` — performance metric must stay *below* the goal
        (memory consumption, latency).  The overwhelmingly common case.
      * ``"lower"`` — metric must stay *above* the goal (e.g. throughput floor).
    """

    value: float
    hard: bool = False
    super_hard: bool = False
    direction: str = "upper"

    def __post_init__(self) -> None:
        if self.direction not in ("upper", "lower"):
            raise ValueError(f"direction must be 'upper'|'lower', got {self.direction!r}")
        if self.super_hard:
            self.hard = True


@dataclasses.dataclass
class ControllerModel:
    """Profiling artifact (paper §5, Eq. 1): everything the synthesis needs.

    alpha   -- least-squares slope of performance vs configuration (through 0).
    delta   -- multiplicative model-error bound Delta (>= 1).
    lam     -- coefficient of variation lambda (system instability measure).
    conf_min/conf_max -- actuator saturation bounds for the configuration.
    integer -- whether the configuration is integer-typed (paper: >80% are).
    """

    alpha: float
    delta: float = 1.0
    lam: float = 0.0
    conf_min: float = 0.0
    conf_max: float = float("inf")
    integer: bool = True

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(text: str) -> "ControllerModel":
        return ControllerModel(**json.loads(text))


def fit_model(
    conf_values: Sequence[float],
    perf_samples: Sequence[Sequence[float]],
    *,
    conf_min: float = 0.0,
    conf_max: float = float("inf"),
    integer: bool = True,
) -> ControllerModel:
    """Fit Eq. 1 from profiling data (paper §5.5 "Profiling").

    ``conf_values[i]`` is the i-th sampled configuration value and
    ``perf_samples[i]`` the performance measurements observed under it.
    """
    if len(conf_values) != len(perf_samples) or not conf_values:
        raise ValueError("need one non-empty sample list per sampled configuration value")
    means, sigmas = [], []
    for samples in perf_samples:
        samples = list(samples)
        if not samples:
            raise ValueError("empty sample list")
        m = sum(samples) / len(samples)
        var = sum((x - m) ** 2 for x in samples) / max(len(samples) - 1, 1)
        means.append(m)
        sigmas.append(math.sqrt(var))
    # Eq. 1 slope.  The paper writes s = alpha * c (through the origin); Eq. 2
    # only ever uses alpha as the local derivative ds/dc, so we fit the affine
    # regression slope — identical when the data passes through the origin and
    # sign-correct for inversely-related PerfConfs (e.g. MR2820's
    # minspacestart, where *raising* the config *lowers* disk consumption).
    n = len(conf_values)
    c_bar = sum(conf_values) / n
    s_bar = sum(means) / n
    var_c = sum((c - c_bar) ** 2 for c in conf_values)
    if var_c == 0.0:
        # Single sampled configuration value: fall back to through-origin.
        den = sum(c * c for c in conf_values)
        if den == 0.0:
            raise ValueError("all sampled configuration values are zero; cannot fit alpha")
        alpha = sum(c * s for c, s in zip(conf_values, means)) / den
    else:
        alpha = sum((c - c_bar) * (s - s_bar)
                    for c, s in zip(conf_values, means)) / var_c
    if alpha == 0.0:
        raise ValueError("fitted alpha is zero: configuration does not affect the metric")
    # Relative-noise statistics over the sampled operating points.
    cvs = [sg / m for sg, m in zip(sigmas, means) if m > 0]
    lam = sum(cvs) / len(cvs) if cvs else 0.0
    delta = 1.0 + 3.0 * lam  # Delta = 1 + mean(3 sigma_i / m_i)
    return ControllerModel(
        alpha=alpha, delta=delta, lam=lam,
        conf_min=conf_min, conf_max=conf_max, integer=integer,
    )


def compute_pole(delta: float) -> float:
    """Paper §5.1: p = 1 - 2/Delta for Delta > 2, else 0 (guarantees convergence
    whenever the true multiplicative model error is within Delta)."""
    if delta > 2.0:
        return 1.0 - 2.0 / delta
    return 0.0


def compute_virtual_goal(goal: GoalSpec, lam: float) -> float:
    """Paper §5.2: s~v = (1 - lambda) * s~ for upper-bound hard goals; mirrored
    for lower-bound goals.  Soft goals are targeted directly."""
    if not goal.hard:
        return goal.value
    lam = min(max(lam, 0.0), 0.95)  # keep the virtual goal meaningful
    if goal.direction == "upper":
        return (1.0 - lam) * goal.value
    return (1.0 + lam) * goal.value


class SmartController:
    """One synthesized controller for one PerfConf (paper Fig. 1 grey boxes).

    The host-side control loop:

        ctl.observe(measured_perf)          # SmartConf.setPerf
        new_conf = ctl.actuate()            # SmartConf.getConf

    For *indirect* configurations (paper §5.3) the controller is built for the
    deputy variable C'; callers pass ``deputy=`` to :meth:`observe` so Eq. 2
    integrates from the deputy's *actual* value rather than the threshold's.
    """

    def __init__(
        self,
        model: ControllerModel,
        goal: GoalSpec,
        initial_conf: float,
        *,
        n_interacting: int = 1,
    ) -> None:
        self.model = model
        self.goal = goal
        self.pole = compute_pole(model.delta)
        self.aggressive_pole = 0.0
        self.virtual_goal = compute_virtual_goal(goal, model.lam)
        self.n_interacting = max(1, int(n_interacting))
        self._conf = float(initial_conf)
        self._last_perf: float | None = None
        self._deputy: float | None = None
        self.goal_unreachable = False  # best-effort alert (paper §4.3)

    # -- paper API verbs -----------------------------------------------------
    def observe(self, perf: float, deputy: float | None = None) -> None:
        self._last_perf = float(perf)
        self._deputy = None if deputy is None else float(deputy)

    def set_goal(self, goal: GoalSpec) -> None:
        """Runtime goal update (paper §4.3 setGoal)."""
        self.goal = goal
        self.virtual_goal = compute_virtual_goal(goal, self.model.lam)

    def set_interacting(self, n: int) -> None:
        self.n_interacting = max(1, int(n))

    def in_danger(self, perf: float) -> bool:
        """Has the metric crossed the virtual goal into the unsafe region?"""
        if self.goal.direction == "upper":
            return perf > self.virtual_goal
        return perf < self.virtual_goal

    def actuate(self) -> float:
        """Compute c_{k+1} (Eq. 2 + §5.2 two-pole + §5.4 interaction factor)."""
        if self._last_perf is None:
            return self._emit(self._conf)
        perf = self._last_perf
        # Context-aware pole (§5.2): aggressive once past the virtual goal.
        pole = self.pole
        if self.goal.hard and self.in_danger(perf):
            pole = self.aggressive_pole
        error = self.virtual_goal - perf
        gain = (1.0 - pole) / (self.model.alpha * self.n_interacting)
        base = self._deputy if self._deputy is not None else self._conf
        nxt = base + gain * error
        lo, hi = self.model.conf_min, self.model.conf_max
        clipped = min(max(nxt, lo), hi)
        # Best-effort alert: actuator saturated but error says push further.
        self.goal_unreachable = (clipped != nxt)
        return self._emit(clipped)

    def _emit(self, value: float) -> float:
        if self.model.integer:
            value = float(int(round(value)))
            value = min(max(value, self.model.conf_min), self.model.conf_max)
        self._conf = value
        return value

    # -- introspection -------------------------------------------------------
    @property
    def conf(self) -> float:
        return self._conf

    @property
    def last_perf(self) -> float | None:
        return self._last_perf

    def describe(self) -> dict:
        return {
            "alpha": self.model.alpha,
            "delta": self.model.delta,
            "lambda": self.model.lam,
            "pole": self.pole,
            "virtual_goal": self.virtual_goal,
            "goal": dataclasses.asdict(self.goal),
            "conf": self._conf,
            "n_interacting": self.n_interacting,
        }
