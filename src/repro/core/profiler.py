"""Profiling support (paper §5.5).

While profiling is enabled, every ``SmartConf.set_perf`` call records the
(configuration-or-deputy value, measured performance) pair into a buffer that
is periodically flushed to ``<ConfName>.smartconf.sys``.  When profiling is
complete, :func:`synthesize` groups the samples by configuration value, fits
the Eq.-1 model, and writes the synthesized controller parameters (alpha,
Delta, lambda) back into the same system file, from which the ``SmartConf``
constructor initializes its controller.

The larger the range of profiled workloads, the more robust the resulting
controller (paper: "enough samples are needed for the central limit theorem
to apply") — :func:`synthesize` refuses to fit from fewer than
``min_samples_per_point`` observations per sampled configuration value.
"""

from __future__ import annotations

import collections
import contextlib
import json
import os
import tempfile
from typing import Iterable, Mapping

from .controller import ControllerModel, fit_model

__all__ = ["ProfileBuffer", "synthesize", "read_sysfile", "write_sysfile"]

_SCHEMA = 1


def _sysfile_path(sys_dir: str, conf_name: str) -> str:
    return os.path.join(sys_dir, f"{conf_name}.smartconf.sys")


def read_sysfile(sys_dir: str, conf_name: str) -> dict:
    path = _sysfile_path(sys_dir, conf_name)
    if not os.path.exists(path):
        return {}
    with open(path, "r", encoding="utf-8") as fh:
        return json.load(fh)


def write_sysfile(sys_dir: str, conf_name: str, payload: Mapping) -> str:
    """Atomic write (the trainer may be checkpointing concurrently).

    Exception-safe on every path: if ``os.fdopen`` raises, the raw fd is
    closed directly (an fd wrapped by a failed fdopen is otherwise
    leaked); if serialization or ``os.replace`` fails, the tmp file is
    unlinked without a TOCTOU exists-check (``os.replace`` may have
    already consumed it — a racing second writer could re-create the
    name between ``exists`` and ``unlink``)."""
    os.makedirs(sys_dir, exist_ok=True)
    path = _sysfile_path(sys_dir, conf_name)
    payload = dict(payload)
    payload["schema"] = _SCHEMA
    fd, tmp = tempfile.mkstemp(dir=sys_dir, prefix=f".{conf_name}.")
    try:
        fh = os.fdopen(fd, "w", encoding="utf-8")
    except Exception:
        os.close(fd)
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise
    try:
        with fh:
            json.dump(payload, fh, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.unlink(tmp)
        raise
    return path


class ProfileBuffer:
    """In-memory (conf value, perf) sample buffer with periodic flush.

    When a ``core.telemetry.MetricsRegistry`` is attached (``metrics=``),
    every flush also emits into it — ``profiler.<conf>.samples`` counts
    samples persisted, ``profiler.<conf>.flushes`` counts write-outs — so
    a profiling run's progress is visible in the same metrics.json as the
    serving telemetry."""

    def __init__(self, sys_dir: str, conf_name: str, flush_every: int = 64,
                 metrics=None) -> None:
        self.sys_dir = sys_dir
        self.conf_name = conf_name
        self.flush_every = flush_every
        self.metrics = metrics
        self._samples: list[tuple[float, float]] = []
        self._flushed: list[tuple[float, float]] = []
        existing = read_sysfile(sys_dir, conf_name)
        if "profile_samples" in existing:
            self._flushed = [tuple(x) for x in existing["profile_samples"]]

    def record(self, conf_value: float, perf: float) -> None:
        self._samples.append((float(conf_value), float(perf)))
        if len(self._samples) >= self.flush_every:
            self.flush()

    def flush(self) -> None:
        if not self._samples:
            return
        n = len(self._samples)
        self._flushed.extend(self._samples)
        self._samples.clear()
        payload = read_sysfile(self.sys_dir, self.conf_name)
        payload["profile_samples"] = [list(x) for x in self._flushed]
        write_sysfile(self.sys_dir, self.conf_name, payload)
        if self.metrics is not None:
            self.metrics.counter(f"profiler.{self.conf_name}.samples").inc(n)
            self.metrics.counter(f"profiler.{self.conf_name}.flushes").inc()

    @property
    def samples(self) -> list[tuple[float, float]]:
        return self._flushed + self._samples


def synthesize(
    sys_dir: str,
    conf_name: str,
    *,
    samples: Iterable[tuple[float, float]] | None = None,
    conf_min: float = 0.0,
    conf_max: float = float("inf"),
    integer: bool = True,
    min_samples_per_point: int = 2,
) -> ControllerModel:
    """Group profiled samples by configuration value, fit Eq. 1, persist."""
    if samples is None:
        payload = read_sysfile(sys_dir, conf_name)
        samples = [tuple(x) for x in payload.get("profile_samples", [])]
    samples = list(samples)
    if not samples:
        raise ValueError(f"no profiling samples for {conf_name!r}")
    grouped: dict[float, list[float]] = collections.defaultdict(list)
    for conf_value, perf in samples:
        grouped[float(conf_value)].append(float(perf))
    # Indirect configs profile against a *continuous* deputy (queue occupancy,
    # memtable bytes ...): bin into at most 16 operating points so the
    # per-point sigma/mean statistics behind Delta and lambda are meaningful.
    if len(grouped) > 24:
        lo = min(grouped)
        hi = max(grouped)
        width = (hi - lo) / 16 or 1.0
        binned: dict[float, list[float]] = collections.defaultdict(list)
        for conf_value, values in grouped.items():
            center = lo + (int((conf_value - lo) / width) + 0.5) * width
            binned[center].extend(values)
        grouped = binned
    points = {c: v for c, v in grouped.items() if len(v) >= min_samples_per_point}
    if not points:
        # Fall back to whatever we have rather than refusing outright; the
        # pole/virtual-goal machinery absorbs the extra uncertainty.
        points = grouped
    conf_values = sorted(points)
    model = fit_model(
        conf_values,
        [points[c] for c in conf_values],
        conf_min=conf_min,
        conf_max=conf_max,
        integer=integer,
    )
    payload = read_sysfile(sys_dir, conf_name)
    payload["model"] = json.loads(model.to_json())
    payload["profile_samples"] = [list(x) for x in samples]
    write_sysfile(sys_dir, conf_name, payload)
    return model
