"""Jittable SmartConf controller — the paper's technique as a composable JAX
module (DESIGN.md §2).

The host-side ``SmartController`` cannot live inside a jitted serving or
training loop, so this module provides a functional twin:

  * :class:`ControllerSpec` / :class:`ControllerState` are array pytrees
    (vmap-/scan-/shard_map-compatible).
  * :func:`controller_step` is Eq. 2 + the two-pole hard-goal switch, built
    from ``jnp.where`` (branchless, so it vectorizes across controllers).
  * :func:`coordinated_step` implements §5.4's interaction protocol for a
    *batch* of controllers sharing metrics: N is recomputed on the fly from
    the metric ids, so adding/removing controllers needs no re-synthesis.
  * :func:`sharded_coordinated_step` runs controllers distributed over a mesh
    axis with ``jax.lax.psum`` computing the interaction counts — the paper's
    cross-module coordination mapped onto a TPU collective.

Everything here is pure; state threading is the caller's business (typically a
``lax.scan`` carry inside the serve loop, see ``serve/engine.py``).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .controller import GoalSpec, ControllerModel, compute_pole, compute_virtual_goal

__all__ = [
    "ControllerSpec",
    "ControllerState",
    "make_spec",
    "init_state",
    "controller_step",
    "indirect_controller_step",
    "interaction_counts",
    "coordinated_step",
    "sharded_coordinated_step",
]


class ControllerSpec(NamedTuple):
    """Static-per-controller parameters, stored as arrays so a batch of
    heterogeneous controllers is just a stacked spec."""

    alpha: jax.Array          # Eq. 1 slope
    pole: jax.Array           # regular pole (§5.1)
    goal: jax.Array           # user goal value
    virtual_goal: jax.Array   # (1 - lambda) * goal for hard upper goals (§5.2)
    hard: jax.Array           # bool: two-pole mode enabled
    direction: jax.Array      # +1: metric must stay below goal; -1: above
    conf_min: jax.Array
    conf_max: jax.Array
    metric_id: jax.Array      # int32 id of the controlled metric (§5.4)
    super_hard: jax.Array     # bool: split gain across interacting controllers


class ControllerState(NamedTuple):
    conf: jax.Array


def make_spec(model: ControllerModel, goal: GoalSpec, *, metric_id: int = 0) -> ControllerSpec:
    """Build a single controller spec from the host-side synthesis artifacts."""
    direction = 1.0 if goal.direction == "upper" else -1.0
    return ControllerSpec(
        alpha=jnp.asarray(model.alpha, jnp.float32),
        pole=jnp.asarray(compute_pole(model.delta), jnp.float32),
        goal=jnp.asarray(goal.value, jnp.float32),
        virtual_goal=jnp.asarray(compute_virtual_goal(goal, model.lam), jnp.float32),
        hard=jnp.asarray(goal.hard),
        direction=jnp.asarray(direction, jnp.float32),
        conf_min=jnp.asarray(model.conf_min, jnp.float32),
        conf_max=jnp.asarray(min(model.conf_max, 3.4e38), jnp.float32),
        metric_id=jnp.asarray(metric_id, jnp.int32),
        super_hard=jnp.asarray(goal.super_hard),
    )


def stack_specs(specs: list[ControllerSpec]) -> ControllerSpec:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *specs)


def init_state(initial_conf) -> ControllerState:
    return ControllerState(conf=jnp.asarray(initial_conf, jnp.float32))


def _next_conf(spec: ControllerSpec, base: jax.Array, measurement: jax.Array,
               n_interacting: jax.Array) -> jax.Array:
    """Eq. 2 with the §5.2 context-aware pole and §5.4 interaction factor."""
    measurement = measurement.astype(jnp.float32)
    # danger: metric crossed the virtual goal on the unsafe side.
    danger = jnp.where(spec.direction > 0,
                       measurement > spec.virtual_goal,
                       measurement < spec.virtual_goal)
    pole = jnp.where(spec.hard & danger, jnp.zeros_like(spec.pole), spec.pole)
    error = spec.virtual_goal - measurement
    n = jnp.where(spec.super_hard, n_interacting.astype(jnp.float32), 1.0)
    gain = (1.0 - pole) / (spec.alpha * n)
    nxt = base + gain * error
    return jnp.clip(nxt, spec.conf_min, spec.conf_max)


def controller_step(spec: ControllerSpec, state: ControllerState,
                    measurement: jax.Array) -> tuple[ControllerState, jax.Array]:
    """One control interval for a direct configuration."""
    conf = _next_conf(spec, state.conf, measurement, jnp.asarray(1.0))
    return ControllerState(conf=conf), conf


def indirect_controller_step(spec: ControllerSpec, state: ControllerState,
                             measurement: jax.Array, deputy: jax.Array
                             ) -> tuple[ControllerState, jax.Array]:
    """One control interval for an indirect configuration (§5.3): Eq. 2
    integrates from the *deputy's* actual value.  The returned value is the
    desired deputy value; the caller applies its transducer (host- or
    graph-side) to obtain the threshold configuration."""
    conf = _next_conf(spec, deputy.astype(jnp.float32), measurement, jnp.asarray(1.0))
    return ControllerState(conf=conf), conf


def interaction_counts(metric_ids: jax.Array, num_metrics: int) -> jax.Array:
    """N per controller: how many controllers share each controller's metric."""
    onehot = jax.nn.one_hot(metric_ids, num_metrics, dtype=jnp.float32)  # [C, M]
    per_metric = onehot.sum(axis=0)                                      # [M]
    return onehot @ per_metric                                           # [C]


def coordinated_step(specs: ControllerSpec, states: ControllerState,
                     measurements: jax.Array, *, num_metrics: int = 8
                     ) -> tuple[ControllerState, jax.Array]:
    """Batched controllers with §5.4 coordination (single device / vmapped).

    ``specs``/``states`` hold stacked arrays of C controllers; controllers with
    equal ``metric_id`` and ``super_hard`` split the error N ways."""
    n = interaction_counts(specs.metric_id, num_metrics)
    conf = _next_conf(specs, states.conf, measurements, n)
    return ControllerState(conf=conf), conf


def sharded_coordinated_step(mesh, axis_name: str, *, num_metrics: int = 8):
    """§5.4 coordination across a mesh axis.

    Returns a shard_mapped function ``(specs, states, measurements) ->
    (states', confs)`` where each shard owns a slice of the controller batch
    and the interaction count N is agreed globally via ``lax.psum`` — i.e. the
    paper's "controllers independently work together" protocol expressed as a
    TPU collective.  Controllers for different modules/pods never need to
    rendezvous at a single code location (the paper's §5.4 infeasibility
    argument); they only share this metric-count reduction.
    """

    def local_step(specs: ControllerSpec, states: ControllerState,
                   measurements: jax.Array):
        onehot = jax.nn.one_hot(specs.metric_id, num_metrics, dtype=jnp.float32)
        per_metric = jax.lax.psum(onehot.sum(axis=0), axis_name)  # global counts
        n = onehot @ per_metric
        conf = _next_conf(specs, states.conf, measurements, n)
        return ControllerState(conf=conf), conf

    spec_p = ControllerSpec(*(P(axis_name) for _ in ControllerSpec._fields))
    state_p = ControllerState(P(axis_name))
    from repro.distributed.sharding import shard_map
    return shard_map(
        local_step, mesh=mesh,
        in_specs=(spec_p, state_p, P(axis_name)),
        out_specs=(state_p, P(axis_name)),
    )
