"""Sharded, atomic, elastic checkpointing (no orbax).

Layout:  <dir>/step_<N>/
             manifest.json     tree structure, shapes/dtypes, shard map
             shard_<k>.npz     arrays, packed to ~512MB per shard
Writes go to ``step_<N>.tmp`` then ``os.replace`` — a crash mid-write never
corrupts the latest complete checkpoint.  ``keep_n`` oldest-step GC.

Elastic restore: arrays are saved *unsharded* (host-gathered); restore
device_puts them under whatever mesh/sharding the new world size defines, so
a checkpoint written on mesh A restarts on mesh B (tested 1<->8 host-devices).
Data-pipeline and SmartConf controller state ride along in the manifest, so a
restart resumes byte-identically.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import ml_dtypes
import numpy as np

__all__ = ["save", "restore", "latest_step", "Checkpointer"]

# numpy can't serialize ml_dtypes natively: store them as integer views and
# record the logical dtype in the manifest.
_EXOTIC = {
    np.dtype(ml_dtypes.bfloat16): np.uint16,
    np.dtype(ml_dtypes.float8_e4m3fn): np.uint8,
    np.dtype(ml_dtypes.float8_e5m2): np.uint8,
}
_EXOTIC_BY_NAME = {str(k): k for k in _EXOTIC}

_SHARD_BYTES = 512 * 1024 * 1024


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {jax.tree_util.keystr(path): leaf for path, leaf in flat}
    return keyed, treedef


def save(directory: str, step: int, tree, *, extra: dict | None = None,
         keep_n: int = 3) -> str:
    """Atomically write ``tree`` (params/opt state pytree) at ``step``."""
    keyed, _ = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    shards: list[dict[str, np.ndarray]] = [{}]
    sizes = [0]
    shard_of: dict[str, int] = {}
    dtypes: dict[str, str] = {}
    for key, leaf in keyed.items():
        arr = np.asarray(jax.device_get(leaf))
        dtypes[key] = str(arr.dtype)
        if arr.dtype in _EXOTIC:
            arr = arr.view(_EXOTIC[arr.dtype])
        if sizes[-1] + arr.nbytes > _SHARD_BYTES and shards[-1]:
            shards.append({})
            sizes.append(0)
        shards[-1][key] = arr
        sizes[-1] += arr.nbytes
        shard_of[key] = len(shards) - 1

    for i, shard in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{i}.npz"),
                 **{k.replace("/", "\x1f"): v for k, v in shard.items()})
    manifest = {
        "step": step,
        "keys": {k: {"shard": shard_of[k],
                     "shape": list(np.shape(keyed[k])),
                     "dtype": dtypes[k]}
                 for k in keyed},
        "extra": extra or {},
        "n_shards": len(shards),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as fh:
        json.dump(manifest, fh)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(directory, keep_n)
    return final


def _gc(directory: str, keep_n: int) -> None:
    steps = sorted(_steps(directory))
    for s in steps[:-keep_n]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def _steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def latest_step(directory: str) -> int | None:
    steps = _steps(directory)
    return max(steps) if steps else None


def restore(directory: str, step: int | None, like, *, shardings=None):
    """Rebuild a pytree structured like ``like`` (arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for elastic placement on the current mesh."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as fh:
        manifest = json.load(fh)
    data: dict[str, np.ndarray] = {}
    for i in range(manifest["n_shards"]):
        with np.load(os.path.join(path, f"shard_{i}.npz")) as z:
            for k in z.files:
                data[k.replace("\x1f", "/")] = z[k]

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (jax.tree_util.tree_flatten_with_path(shardings)[0]
                  if shardings is not None else None)
    leaves = []
    for idx, (pathkey, leaf) in enumerate(flat):
        key = jax.tree_util.keystr(pathkey)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        stored = manifest["keys"][key]["dtype"]
        if stored in _EXOTIC_BY_NAME:
            arr = arr.view(_EXOTIC_BY_NAME[stored])
        want_dtype = leaf.dtype
        val = arr.astype(want_dtype) if str(arr.dtype) != str(want_dtype) else arr
        if shard_flat is not None:
            val = jax.device_put(val, shard_flat[idx][1])
        else:
            val = jax.numpy.asarray(val)
        leaves.append(val)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["extra"], step


class Checkpointer:
    """Interval-driven checkpointing with a SmartConf-controllable interval.

    ``train.ckpt_interval_steps`` (direct, soft) trades recovery time against
    step-time overhead — the controller targets a user overhead budget
    (fraction of wall time spent writing checkpoints)."""

    def __init__(self, directory: str, *, interval_steps: int = 100,
                 keep_n: int = 3) -> None:
        self.directory = directory
        self.interval_steps = max(1, int(interval_steps))
        self.keep_n = keep_n
        self.last_saved = None
        self.write_seconds = 0.0
        self.writes = 0

    def set_interval(self, steps: int) -> None:
        self.interval_steps = max(1, int(steps))

    def maybe_save(self, step: int, tree, *, extra: dict | None = None,
                   force: bool = False) -> str | None:
        if not force and step % self.interval_steps != 0:
            return None
        import time
        t0 = time.monotonic()
        out = save(self.directory, step, tree, extra=extra, keep_n=self.keep_n)
        self.write_seconds += time.monotonic() - t0
        self.writes += 1
        self.last_saved = step
        return out
