"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the continuous-batching engine with SmartConf-governed admission and
KV budgets against a synthetic request trace (reduced config on CPU; full
configs deploy the dry-run-validated shardings on real meshes).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.models import zoo
from repro.serve import Request, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--budget-headroom-mb", type=float, default=2.0)
    ap.add_argument("--prefill-mode", default="auto",
                    choices=["auto", "bucketed", "packed", "one_shot"],
                    help="packed = unified ticks: ONE token-packed ragged "
                         "stream per tick carrying prefill chunks AND "
                         "every running slot's decode token as a length-1 "
                         "segment (one fused dispatch; the "
                         "serve.prefill_chunk_tokens knob is the literal "
                         "per-tick token budget); bucketed = padded "
                         "power-of-two chunked prefill + a separate decode "
                         "dispatch (compile-count O(log len)); one_shot = "
                         "exact whole-prompt prefill per request (the "
                         "legacy baseline)")
    ap.add_argument("--kv-mode", default="auto",
                    choices=["auto", "paged", "dense"],
                    help="paged = block-table KV cache + paged decode "
                         "kernel (attention-only archs); dense = per-slot "
                         "[max_batch, cache_len] cache")
    ap.add_argument("--full-size", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    params, _ = zoo.init(cfg, jax.random.key(0))
    weights = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                  for x in jax.tree.leaves(params))
    budget = int(weights + args.budget_headroom_mb * 1e6)
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      cache_len=args.cache_len, hbm_budget_bytes=budget,
                      prefill_mode=args.prefill_mode, kv_mode=args.kv_mode)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(8, 48)))
        eng.submit(Request(i, prompt.astype(np.int32), args.max_new_tokens))
    ticks = 0
    while len(eng.finished) < args.requests and ticks < 2000:
        eng.tick()
        ticks += 1
    kv = "paged" if eng.paged else "dense"
    print(f"{cfg.name}: {len(eng.finished)}/{args.requests} done in {ticks} "
          f"ticks; HBM violations {eng.accountant.violations}; "
          f"peak {eng.accountant.peak_bytes/1e6:.1f}/{budget/1e6:.1f} MB; "
          f"TTFT {eng.ttft.mean()*1e3:.0f}ms; prefill[{eng.prefill_impl}] "
          f"{eng.prefill_calls} calls / {eng.model_programs} programs, "
          f"{eng.model_dispatches/max(1, ticks):.2f} dispatches/tick, "
          f"pad_fraction {eng.pad_fraction:.2f}; "
          f"kv[{kv}] {eng.pool.used_blocks} blocks used, "
          f"{eng.preemptions} preemptions")
    eng.close()


if __name__ == "__main__":
    main()
