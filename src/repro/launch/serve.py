"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the continuous-batching engine with SmartConf-governed admission and
KV budgets against a synthetic request trace (reduced config on CPU; full
configs deploy the dry-run-validated shardings on real meshes).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.models import zoo
from repro.serve import Request, ServeEngine, ServeOptions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--budget-headroom-mb", type=float, default=2.0)
    ap.add_argument("--prefill-mode", default="auto",
                    choices=["auto", "bucketed", "packed", "one_shot"],
                    help="packed = unified ticks: ONE token-packed ragged "
                         "stream per tick carrying prefill chunks AND "
                         "every running slot's decode token as a length-1 "
                         "segment (one fused dispatch; the "
                         "serve.prefill_chunk_tokens knob is the literal "
                         "per-tick token budget); bucketed = padded "
                         "power-of-two chunked prefill + a separate decode "
                         "dispatch (compile-count O(log len)); one_shot = "
                         "exact whole-prompt prefill per request (the "
                         "legacy baseline)")
    ap.add_argument("--kv-mode", default="auto",
                    choices=["auto", "paged", "dense"],
                    help="paged = block-table KV cache + paged decode "
                         "kernel (attention-only archs); dense = per-slot "
                         "[max_batch, cache_len] cache")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over refcounted paged KV "
                         "blocks: requests whose prompts share a cached "
                         "prefix skip straight to the uncovered suffix "
                         "(copy-on-write at the block boundary); the "
                         "cache's share of the block budget is the "
                         "SmartConf-actuated serve.kv_cache_share knob. "
                         "Requires paged KV")
    ap.add_argument("--kv-cache-share", type=float, default=0.5,
                    help="initial fraction of the KV block budget the "
                         "prefix cache may hold (SmartConf adjusts it)")
    ap.add_argument("--prefix-groups", type=int, default=0,
                    help="with --trace: number of shared-prefix tenant "
                         "groups in the synthesized workload (0 = none)")
    ap.add_argument("--prefix-len", type=int, default=32,
                    help="with --trace: common preamble length (tokens) "
                         "for each prefix group")
    ap.add_argument("--spec-depth", type=int, default=0,
                    help="self-speculative decode draft depth k (0 = off): "
                         "each running slot's decode segment carries up to "
                         "k n-gram-drafted tokens verified in the same "
                         "fused dispatch; with SmartConf on, this is the "
                         "initial value of the serve.spec_depth knob. "
                         "Requires packed prefill mode")
    ap.add_argument("--spec-depth-max", type=int, default=8,
                    help="ceiling for the serve.spec_depth knob")
    ap.add_argument("--accept-rate-goal", type=float, default=0.5,
                    help="sc_spec setpoint: windowed draft accept rate the "
                         "depth controller holds the engine above")
    ap.add_argument("--no-spec-adaptive", action="store_true",
                    help="pin serve.spec_depth at --spec-depth instead of "
                         "letting SmartConf actuate it")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="serve on a (data, model) host mesh, e.g. 2x4: "
                         "the packed tick's one dispatch runs tensor-"
                         "parallel over the model axis (attention heads "
                         "and the KV block store shard on the Kv head "
                         "dim), token-identical to single-device.  Needs "
                         "packed prefill and data*model visible devices "
                         "(XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N on CPU); REPRO_SERVE_MESH sets the same "
                         "knob from the environment")
    ap.add_argument("--replicas", type=int, default=1,
                    help="with --trace: run N data-parallel engine "
                         "replicas behind one ReplicaRouter (weighted-"
                         "least-loaded dispatch; with --ttft-slo-s the "
                         "per-replica route.replica_weights are SmartConf-"
                         "actuated on each replica's TTFT-p99)")
    ap.add_argument("--full-size", action="store_true")
    # open-loop trace mode (serve/README.md): arrivals at trace rate on a
    # virtual clock, tier gating + SLO accounting + optional fault injection
    ap.add_argument("--trace", default=None,
                    choices=["poisson", "bursty", "diurnal"],
                    help="replay an open-loop arrival trace instead of the "
                         "one-shot synthetic batch")
    ap.add_argument("--rate-rps", type=float, default=10.0,
                    help="mean arrival rate for --trace (requests/s)")
    ap.add_argument("--horizon-s", type=float, default=10.0,
                    help="trace horizon in virtual seconds")
    ap.add_argument("--ttft-slo-s", type=float, default=None,
                    help="TTFT p99 SLO: enables per-request goodput "
                         "accounting and the serve.admit_tier_max brownout "
                         "controller")
    ap.add_argument("--chaos", action="store_true",
                    help="inject faults during --trace: slow ticks, a "
                         "mid-run KV budget cut, a NaN sensor window, one "
                         "worker preemption")
    ap.add_argument("--telemetry-dir", default=None,
                    help="enable the flight recorder and write trace.json "
                         "(Chrome trace-event / Perfetto), metrics.json, "
                         "audit.jsonl (controller decisions) and "
                         "flight.json (sensor-ring dumps) into this "
                         "directory (see serve/README.md)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    params, _ = zoo.init(cfg, jax.random.key(0))
    weights = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                  for x in jax.tree.leaves(params))
    budget = int(weights + args.budget_headroom_mb * 1e6)
    if args.replicas > 1 and args.trace is None:
        raise SystemExit("--replicas N needs --trace: the ReplicaRouter "
                         "serves an open-loop arrival stream")
    if args.trace is not None:
        _run_trace(cfg, params, budget, args)
        return
    tel = None
    if args.telemetry_dir:
        from repro.core.telemetry import Telemetry
        tel = Telemetry(enabled=True)
    eng = ServeEngine(cfg, params, options=ServeOptions(
        max_batch=args.max_batch, cache_len=args.cache_len,
        hbm_budget_bytes=budget, prefill_mode=args.prefill_mode,
        kv_mode=args.kv_mode, prefix_cache=args.prefix_cache,
        kv_cache_share=args.kv_cache_share, telemetry=tel,
        spec_depth=args.spec_depth, spec_depth_max=args.spec_depth_max,
        spec_adaptive=not args.no_spec_adaptive,
        accept_rate_goal=args.accept_rate_goal, mesh=args.mesh))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(8, 48)))
        eng.submit(Request(i, prompt.astype(np.int32), args.max_new_tokens))
    ticks = 0
    while len(eng.finished) < args.requests and ticks < 2000:
        eng.tick()
        ticks += 1
    kv = "paged" if eng.paged else "dense"
    print(f"{cfg.name}: {len(eng.finished)}/{args.requests} done in {ticks} "
          f"ticks; HBM violations {eng.accountant.violations}; "
          f"peak {eng.accountant.peak_bytes/1e6:.1f}/{budget/1e6:.1f} MB; "
          f"TTFT {eng.ttft.mean()*1e3:.0f}ms; prefill[{eng.prefill_impl}] "
          f"{eng.prefill_calls} calls / {eng.model_programs} programs, "
          f"{eng.model_dispatches/max(1, ticks):.2f} dispatches/tick, "
          f"pad_fraction {eng.pad_fraction:.2f}; "
          f"kv[{kv}] {eng.pool.used_blocks} blocks used, "
          f"{eng.preemptions} preemptions"
          + (f"; mesh {args.mesh}: {eng.tp_shards}-way TP ticks, "
             f"{eng.kv_shard_bytes()/1e6:.1f} MB KV per shard"
             if eng.mesh is not None else "")
          + (f"; prefix cache {eng._prefix_cache.blocks_held} blocks held, "
             f"hit rate {eng._prefix_cache.hit_rate:.2f}, "
             f"{eng.prefix_hit_tokens_total} prefill tokens reclaimed, "
             f"{eng.cow_copied_blocks} COW copies"
             if eng._prefix_cache is not None else "")
          + (f"; spec depth {eng.spec_depth}, "
             f"{eng.spec_accepted}/{eng.spec_proposed} drafts accepted"
             if eng.spec_enabled else ""))
    if tel is not None:
        paths = tel.write(args.telemetry_dir)
        print(f"telemetry: {paths['trace']} (open in https://ui.perfetto.dev), "
              f"{paths['metrics']}, {paths['audit']}, {paths['flight']}")
    eng.close()


def _run_trace(cfg, params, budget: int, args) -> None:
    from repro.serve import (ChaosMonkey, ChaosSpec, OpenLoopDriver,
                             ReplicaRouter, SLOSpec, ServeEngine,
                             TraceConfig, VirtualClock, as_requests,
                             synthesize_trace)

    vc = VirtualClock()
    slo = SLOSpec(ttft_s=args.ttft_slo_s) if args.ttft_slo_s else None
    tel = None
    if args.telemetry_dir:
        from repro.core.telemetry import Telemetry
        tel = Telemetry(enabled=True, clock=vc)  # virtual-time timestamps
    opts = ServeOptions(
        max_batch=args.max_batch, cache_len=args.cache_len,
        hbm_budget_bytes=budget, prefill_mode=args.prefill_mode,
        kv_mode=args.kv_mode, prefix_cache=args.prefix_cache,
        kv_cache_share=args.kv_cache_share, slo=slo, telemetry=tel,
        spec_depth=args.spec_depth, spec_depth_max=args.spec_depth_max,
        spec_adaptive=not args.no_spec_adaptive,
        accept_rate_goal=args.accept_rate_goal, mesh=args.mesh)
    if args.replicas > 1:
        # telemetry (and its decision audit) attaches to the router, which
        # owns the fleet-level control loop; each replica keeps its own
        # engine-level controllers
        engines = [ServeEngine(
            cfg, params,
            options=opts if i == 0 else dataclasses.replace(
                opts, telemetry=None), clock=vc)
            for i in range(args.replicas)]
        eng = ReplicaRouter(engines, clock=vc, slo=slo,
                            adaptive=slo is not None, telemetry=tel)
    else:
        eng = ServeEngine(cfg, params, options=opts, clock=vc)
    trace = synthesize_trace(TraceConfig(
        process=args.trace, rate_rps=args.rate_rps,
        horizon_s=args.horizon_s, seed=args.seed,
        prefix_groups=args.prefix_groups, prefix_len=args.prefix_len))
    chaos = None
    if args.chaos:
        # with replicas, the engine-level faults (budget cut, preemption,
        # sensor window) all land on replica 0 — the router must route
        # around them
        target = eng.engines[0] if args.replicas > 1 else eng
        chaos = ChaosMonkey(ChaosSpec(
            seed=args.seed, slow_tick_prob=0.04, slow_tick_s=0.15,
            budget_cut_tick=30, budget_cut_frac=0.6, budget_restore_tick=60,
            sensor_fault_tick=40, sensor_fault_ticks=10,
            preempt_tick=20, preempt_resume_ticks=3)).install(target)
    drv = OpenLoopDriver(
        eng, as_requests(trace, vocab=cfg.vocab_size, seed=args.seed),
        clock=vc, chaos=chaos)
    out = drv.run()
    slo_part = (f"goodput {out['goodput_tps']:.1f} tok/s under SLO "
                f"(throughput {out['throughput_tps']:.1f}); "
                if slo else "")
    print(f"{cfg.name}: open-loop {args.trace} trace, "
          f"{out['submitted']} arrivals over {args.horizon_s:.0f}s "
          f"(virtual elapsed {out['elapsed_s']:.1f}s, {out['ticks']} ticks); "
          f"{out['finished']} finished, {out['rejected']} rejected "
          f"{dict(out['reject_counts'])}; {slo_part}"
          f"{out['preemptions']} preemptions, "
          f"recompute {out['recompute_tokens']} tokens, "
          f"chaos events {len(chaos.events) if chaos else 0}, "
          f"unhandled {len(out['unhandled'])}"
          + (f"; prefix cache hit rate {eng._prefix_cache.hit_rate:.2f}, "
             f"{eng.prefix_hit_tokens_total} prefill tokens reclaimed"
             if getattr(eng, "_prefix_cache", None) is not None else "")
          + (f"; {args.replicas} replicas: weights "
             f"{[round(w, 2) for w in eng.weights]}, "
             f"{eng.reroutes} rerouted on replica loss"
             if args.replicas > 1 else ""))
    if tel is not None:
        paths = tel.write(args.telemetry_dir)
        print(f"telemetry: {paths['trace']} (open in https://ui.perfetto.dev), "
              f"{paths['metrics']}, {paths['audit']}, {paths['flight']}")
    eng.close()


if __name__ == "__main__":
    main()
