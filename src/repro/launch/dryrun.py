import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init); do not set that flag globally — smoke tests and
benchmarks should see one device.

Per cell this produces (and caches to ``experiments/dryrun/*.json``):
  * compile success + wall time,
  * ``cost_analysis`` flops / bytes (per-chip, post-SPMD),
  * per-kind collective bytes parsed from the per-device HLO,
  * ``memory_analysis`` (argument/output/temp/peak bytes per device),
  * the three roofline terms + dominant bottleneck + MODEL_FLOPS ratio.

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh both
    python -m repro.launch.dryrun --all            # every cell, both meshes
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, cells, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import zoo
from repro.optim import adamw
from repro.roofline import analysis as roof
from repro.roofline import hlo_cost
from repro.train import train_step as ts

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _sds_with_sharding(struct_tree, pspec_tree, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        struct_tree, pspec_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)))


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               mesh=None, shape=None, cfg=None):
    """Build and lower the step function for one cell.  Returns (lowered,
    mesh, n_chips).  ``mesh``/``shape``/``cfg`` overrides support in-test
    mini dry-runs on small host meshes."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = cfg or get_config(arch_id)
    shape = shape or SHAPES[shape_name]
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    is_train = shape.kind == "train"

    # Weight sharding: train = full TP x FSDP; serve = TP with *residual*
    # FSDP (only otherwise-replicated tensors — e.g. llama4's 40-head attn
    # that 16 does not divide — borrow the data axis; TP-sharded tensors and
    # 2D expert weights stay RESIDENT, so decode gathers only the residual
    # set).  See EXPERIMENTS.md SPerf llama4 iterations 1-3.
    fsdp_mode = True if is_train else "residual"
    with shd.use_mesh(mesh, fsdp=fsdp_mode):
        aparams, pshard, aopt, oshard = ts.state_shardings(
            cfg, mesh, fsdp=fsdp_mode, with_opt=is_train)
        bspecs = ts.batch_pspecs(cfg, shape, mesh)
        specs = zoo.input_specs(cfg, shape)

        if is_train:
            opt_cfg = adamw.AdamWConfig()
            step = ts.make_train_step(cfg, opt_cfg)
            batch_sds = {
                k: jax.ShapeDtypeStruct(
                    v.shape, v.dtype, sharding=NamedSharding(mesh, bspecs[k]))
                for k, v in specs.items()}
            fn = jax.jit(step, donate_argnums=(0, 1))
            lowered = fn.lower(
                _with_shardings(aparams, pshard),
                _with_shardings_opt(aopt, oshard, mesh),
                batch_sds)
        elif shape.kind == "prefill":
            step = ts.make_prefill_step(cfg, cache_len=shape.seq_len)
            batch_sds = {
                k: jax.ShapeDtypeStruct(
                    v.shape, v.dtype, sharding=NamedSharding(mesh, bspecs[k]))
                for k, v in specs.items()}
            fn = jax.jit(step)
            lowered = fn.lower(_with_shardings(aparams, pshard), batch_sds)
        else:  # decode
            step = ts.make_serve_step(cfg)
            cache_sds = _sds_with_sharding(specs["caches"],
                                           bspecs["caches"], mesh)
            tok = jax.ShapeDtypeStruct(
                specs["token"].shape, specs["token"].dtype,
                sharding=NamedSharding(mesh, bspecs["token"]))
            pos = jax.ShapeDtypeStruct(
                specs["pos"].shape, specs["pos"].dtype,
                sharding=NamedSharding(mesh, bspecs["pos"]))
            fn = jax.jit(step, donate_argnums=(1,))
            lowered = fn.lower(_with_shardings(aparams, pshard),
                               cache_sds, tok, pos)
    return lowered, mesh, n_chips


def _with_shardings(struct_tree, shard_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        struct_tree, shard_tree)


def _with_shardings_opt(aopt, oshard, mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P

    def f(s, sh):
        if isinstance(sh, P):
            sh = NamedSharding(mesh, sh)
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)

    return jax.tree.map(f, aopt, oshard,
                        is_leaf=lambda x: isinstance(
                            x, (jax.ShapeDtypeStruct, P, NamedSharding)))


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = OUT_DIR, force: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"{mesh_name}__{arch_id}__{shape_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as fh:
            return json.load(fh)

    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "ok": False}
    t0 = time.time()
    try:
        lowered, mesh, n_chips = lower_cell(arch_id, shape_name,
                                            multi_pod=multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_rec = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception as e:  # backend may not support it
            mem_rec = {"error": str(e)}
        hlo = compiled.as_text()
        # trip-count-aware analysis (cost_analysis misses while-loop bodies)
        mine = hlo_cost.analyze_module(hlo)
        coll = {k: mine[k] for k in
                ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                 "collective-permute", "ragged-all-to-all")}
        coll["count"] = mine["coll_count"]
        coll_bytes = mine["collective_bytes"]
        terms = roof.roofline_terms(
            {"flops": mine["flops"], "bytes accessed": mine["bytes"]},
            coll_bytes)
        mf = roof.model_flops(cfg, shape)
        hlo_flops_global = mine["flops"] * n_chips
        rec.update(
            ok=True,
            n_chips=n_chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops_per_chip=mine["flops"],
            bytes_per_chip=mine["bytes"],
            bytes_raw_per_chip=mine["bytes_raw"],
            collectives=coll,
            collective_bytes_per_chip=coll_bytes,
            memory=mem_rec,
            roofline=terms,
            model_flops_global=mf,
            useful_flops_ratio=(mf / hlo_flops_global
                                if hlo_flops_global else None),
            xla_cost={"flops": float(cost.get("flops", 0.0)),
                      "bytes_accessed": float(cost.get("bytes accessed", 0.0))},
        )
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=6)
    rec["total_s"] = round(time.time() - t0, 2)
    with open(out_path, "w") as fh:
        json.dump(rec, fh, indent=1)
    status = "OK " if rec["ok"] else "FAIL"
    print(f"[{status}] {mesh_name} {arch_id} {shape_name} "
          f"({rec['total_s']}s)" + ("" if rec["ok"] else f" :: {rec.get('error')}"),
          flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    targets = []
    arch_list = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    for aid in arch_list:
        for shape_name, _ in cells(aid):
            if args.shape and shape_name != args.shape:
                continue
            for mp in meshes:
                targets.append((aid, shape_name, mp))

    n_ok = 0
    for aid, shape_name, mp in targets:
        rec = run_cell(aid, shape_name, multi_pod=mp,
                       out_dir=args.out_dir, force=args.force)
        n_ok += bool(rec["ok"])
    print(f"\n{n_ok}/{len(targets)} cells compiled")
    if n_ok < len(targets):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
