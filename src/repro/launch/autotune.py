import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""SmartConf-for-the-compiler: auto-tune a compile-time PerfConf with the
paper's controller, using dry-run compiles as the sensor.

``train.microbatch_tokens`` cannot be adjusted mid-step (it is baked into
the jitted program), but the SmartConf loop still applies offline: the
"plant" is ``lower().compile().memory_analysis()`` (peak bytes/device), the
configuration is the microbatch count, and the user goal is the HBM budget
(hard).  The controller's indirect form fits naturally: the deputy is the
*activation* share of peak memory (what microbatching actually divides),
with the transducer mapping desired activation bytes -> microbatch count.

    python -m repro.launch.autotune --arch llama4-maverick-400b-a17b \
        --budget-gb 15

This is the paper's §5 machinery verbatim (virtual goal from a lambda,
two poles, best-effort alert) driving a knob the paper's JVM systems never
had: an XLA compile parameter.  Result feeds EXPERIMENTS.md §Perf.
"""

import argparse
import json

from repro.core import ControllerModel, GoalSpec, SmartConfIndirect
from repro.core.smartconf import ConfRegistry


def measure(arch: str, shape_name: str, n_micro: int) -> dict:
    """One dry-run compile probe at the given microbatch count."""
    import jax
    from repro.configs import SHAPES, get_config
    from repro.distributed import sharding as shd
    from repro.launch import dryrun as dr
    from repro.launch.mesh import make_production_mesh
    from repro.models import zoo
    from repro.optim import adamw
    from repro.train import train_step as ts
    from jax.sharding import NamedSharding

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    with shd.use_mesh(mesh, fsdp=True):
        aparams, pshard, aopt, oshard = ts.state_shardings(
            cfg, mesh, fsdp=True, with_opt=True)
        bspecs = ts.batch_pspecs(cfg, shape, mesh)
        specs = zoo.input_specs(cfg, shape)
        step = ts.make_train_step(cfg, adamw.AdamWConfig(), n_micro=n_micro)
        batch_sds = {
            k: jax.ShapeDtypeStruct(
                v.shape, v.dtype, sharding=NamedSharding(mesh, bspecs[k]))
            for k, v in specs.items()}
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
            dr._with_shardings(aparams, pshard),
            dr._with_shardings_opt(aopt, oshard, mesh),
            batch_sds)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    # On this backend peak==arguments (aliased); the activation working set
    # lives in temp_size.  True per-device requirement = args + temp.
    args_b = getattr(mem, "argument_size_in_bytes", 0)
    temp_b = getattr(mem, "temp_size_in_bytes", 0)
    return {"n_micro": n_micro, "peak_bytes": int(args_b + temp_b),
            "static_bytes": int(args_b), "temp_bytes": int(temp_b)}


def autotune(arch: str, shape_name: str, budget_bytes: float,
             *, max_iters: int = 5) -> list[dict]:
    from repro.configs import SHAPES
    batch = SHAPES[shape_name].global_batch

    # Seed probe: peak = static + activations(n_micro=1)
    history = [measure(arch, shape_name, 1)]
    static = history[0]["static_bytes"]
    act0 = max(history[0]["peak_bytes"] - static, 1)

    # Controller on the deputy "activation (temp) bytes"; the transducer is
    # INCREMENTAL — n_new = n * temp_now / temp_desired — so the controller
    # keeps integrating even where temp has a microbatch-independent floor
    # (paper: model error is disturbance, the loop corrects it).
    state = {"n": 1, "temp": float(act0)}

    def transduce(desired_temp: float) -> float:
        return state["n"] * state["temp"] / max(desired_temp, 1.0)

    model = ControllerModel(alpha=1.0, delta=1.3, lam=0.08,
                            conf_min=0.0, conf_max=float(act0), integer=False)
    registry = ConfRegistry()
    sc = SmartConfIndirect(
        "train.microbatch_tokens", metric="hbm_peak_bytes",
        goal=GoalSpec(budget_bytes, hard=True), initial=float(act0),
        model=model, registry=registry, transducer=transduce)
    from repro.optim.accum import quantize_microbatches
    for it in range(max_iters):
        rec = history[-1]
        state["n"] = rec["n_micro"]
        state["temp"] = float(max(rec["peak_bytes"] - static, 1))
        sc.set_perf(float(rec["peak_bytes"]), state["temp"])
        n_new = quantize_microbatches(batch, max(1.0, float(sc.get_conf())))
        if n_new == rec["n_micro"] and rec["peak_bytes"] > budget_bytes:
            # quantization rounded back down while still over budget:
            # actuate to the next feasible divisor (integer actuator floor)
            from repro.optim.accum import divisors
            bigger = [d for d in divisors(batch) if d > rec["n_micro"]]
            if not bigger:
                print("goal unreachable at max feasible microbatching "
                      "(controller best-effort alert)", flush=True)
                break
            n_new = bigger[0]
        elif n_new == rec["n_micro"]:
            break
        history.append(measure(arch, shape_name, n_new))
        if history[-1]["peak_bytes"] <= budget_bytes:
            break
    return history


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama4-maverick-400b-a17b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--budget-gb", type=float, default=64.0)
    ap.add_argument("--out", default="experiments/autotune_microbatch.json")
    args = ap.parse_args()

    history = autotune(args.arch, args.shape, args.budget_gb * 1e9)
    for rec in history:
        ok = "OK " if rec["peak_bytes"] <= args.budget_gb * 1e9 else "OVER"
        print(f"[{ok}] n_micro={rec['n_micro']:3d} "
              f"peak={rec['peak_bytes']/1e9:.2f}GB "
              f"(budget {args.budget_gb}GB)", flush=True)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump({"arch": args.arch, "shape": args.shape,
                   "budget_gb": args.budget_gb, "history": history}, fh,
                  indent=1)


if __name__ == "__main__":
    main()
