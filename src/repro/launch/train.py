"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real hardware this binds the production mesh and the dry-run-validated
shardings; on the CPU host it runs a reduced config end-to-end (the same
Trainer, SmartConf controllers, checkpointing, fault-tolerance paths).
"""

from __future__ import annotations

import argparse

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workdir", default="/tmp/repro_launch_train")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full architecture (TPU-scale memory!)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = reduced(cfg)
    print(f"training {cfg.name}: ~{cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch}x{args.seq}")
    tc = TrainerConfig(workdir=args.workdir, total_steps=args.steps,
                       ckpt_interval=max(args.steps // 5, 1),
                       batch_size=args.batch, seq_len=args.seq,
                       n_micro=args.microbatches)
    opt = adamw.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                            total_steps=args.steps)
    tr = Trainer(cfg, opt, tc)
    tr.preemption.install()
    log = tr.run()
    if log:
        print(f"loss {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f}; "
              f"last ckpt @ step {tr.ckpt.last_saved}")
    tr.close()


if __name__ == "__main__":
    main()
