"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 256 chips as (data=16, model=16).
Multi-pod: 2 pods = 512 chips as (pod=2, data=16, model=16) — the pod axis
is the slow (DCN/ICI-bridge) dimension and carries only data parallelism.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int | None = None, model: int = 1):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
