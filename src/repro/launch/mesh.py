"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 256 chips as (data=16, model=16).
Multi-pod: 2 pods = 512 chips as (pod=2, data=16, model=16) — the pod axis
is the slow (DCN/ICI-bridge) dimension and carries only data parallelism.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int | None = None, model: int = 1):
    """Small mesh over whatever local devices exist (tests/examples).

    Validates the shape against the visible device count instead of silently
    building a degenerate mesh: ``model > len(jax.devices())`` used to floor
    ``data`` to 0 and fail much later inside jax with an opaque shape error.
    """
    n = len(jax.devices())
    if model < 1:
        raise ValueError(f"make_host_mesh: model={model} must be >= 1")
    if model > n:
        raise ValueError(
            f"make_host_mesh: model={model} exceeds the {n} visible "
            f"device(s); run under XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={model} (or more) or shrink the model axis")
    if data is None:
        if n % model:
            raise ValueError(
                f"make_host_mesh: model={model} does not divide the {n} "
                f"visible device(s) evenly; pass data= explicitly or pick "
                f"a model size that divides {n}")
        data = n // model
    if data < 1:
        raise ValueError(f"make_host_mesh: data={data} must be >= 1")
    if data * model > n:
        raise ValueError(
            f"make_host_mesh: mesh ({data}x{model}) needs {data * model} "
            f"devices but only {n} are visible; run under XLA_FLAGS="
            f"--xla_force_host_platform_device_count={data * model} or "
            f"shrink the mesh")
    return jax.make_mesh((data, model), ("data", "model"))
