"""RWKV-6 "Finch" block (arXiv:2404.05892) — attention-free time mix with
*data-dependent decay* plus channel mix.

State per head: S in R^{N x N} (N = head dim, 64).  Per-token recurrence:

    S_t[i, j] = w_t[i] * S_{t-1}[i, j] + k_t[i] * v_t[j]
    y_t[j]    = sum_i r_t[i] * (S_{t-1}[i, j] + u[i] * k_t[i] * v_t[j])

with w_t = exp(-exp(w0 + lora_w(x_t))) the Finch data-dependent decay.

Training/prefill uses the standard **chunked** formulation (the recurrence is
diagonal-linear in S, so a chunk's contribution factorizes through cumulative
log-decays): per chunk of length L we build the per-channel decay kernel
D[t, s, i] = prod_{s<u<=t} w_u[i] and contract

    y_intra = einsum('lti,tsi,si,sj->lj'-style within the chunk,
    y_cross = (r_t * A_t) @ S_in,     A_t = prod_{u<=t} w_u
    S_out   = diag(A_L) S_in + sum_s (A_L / A_s) k_s^T v_s

then lax.scan over chunks carries S — O(S * L * N^2) FLOPs, O(N^2) state.
``repro.kernels.rwkv6`` implements the same schedule as a Pallas kernel.

Decode is the plain one-token recurrence (O(1) state — this is why rwkv6-7b
runs the long_500k shape).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import A, shard
from .layers import _dense_init

HEAD_DIM = 64
LORA_DIM = 64
CHUNK = 32  # intra-chunk decay kernel D is O(B*L^2*d) — keep L modest


def rwkv6_init(key, cfg) -> tuple[dict, dict]:
    d = cfg.d_model
    h = d // HEAD_DIM
    ks = jax.random.split(key, 12)
    params = {
        # token-shift mixing coefficients (static per channel)
        "mu_r": jnp.full((d,), 0.5, cfg.dtype),
        "mu_k": jnp.full((d,), 0.5, cfg.dtype),
        "mu_v": jnp.full((d,), 0.5, cfg.dtype),
        "mu_g": jnp.full((d,), 0.5, cfg.dtype),
        "mu_w": jnp.full((d,), 0.5, cfg.dtype),
        "wr": _dense_init(ks[0], (d, d), cfg.dtype),
        "wk": _dense_init(ks[1], (d, d), cfg.dtype),
        "wv": _dense_init(ks[2], (d, d), cfg.dtype),
        "wg": _dense_init(ks[3], (d, d), cfg.dtype),
        "wo": _dense_init(ks[4], (d, d), cfg.dtype),
        # data-dependent decay: w0 + tanh(x A) B   (LoRA, Finch eq. 6)
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "w_lora_a": _dense_init(ks[5], (d, LORA_DIM), cfg.dtype),
        "w_lora_b": _dense_init(ks[6], (LORA_DIM, d), cfg.dtype),
        "u": jnp.zeros((h, HEAD_DIM), jnp.float32),       # bonus
        "ln_scale": jnp.ones((d,), cfg.dtype),            # per-head groupnorm
        # channel mix
        "cm_mu": jnp.full((d,), 0.5, cfg.dtype),
        "cm_k": _dense_init(ks[7], (d, cfg.d_ff), cfg.dtype),
        "cm_v": _dense_init(ks[8], (cfg.d_ff, d), cfg.dtype),
    }
    axes = {
        "mu_r": A("embed"), "mu_k": A("embed"), "mu_v": A("embed"),
        "mu_g": A("embed"), "mu_w": A("embed"),
        "wr": A("embed", "ff"), "wk": A("embed", "ff"),
        "wv": A("embed", "ff"), "wg": A("embed", "ff"),
        "wo": A("ff", "embed"),
        "w0": A("embed"),
        "w_lora_a": A("embed", None), "w_lora_b": A(None, "embed"),
        "u": A("heads", None),
        "ln_scale": A("embed"),
        "cm_mu": A("embed"),
        "cm_k": A("embed", "ff"), "cm_v": A("ff", "embed"),
    }
    return params, axes


def _mix(x, x_prev, mu):
    """token shift: lerp between current token and previous token."""
    return x + (x_prev - x) * mu


def _projections(params, x, x_prev):
    """r,k,v,g,logw from shifted inputs.  x,x_prev: [..., d]."""
    r = _mix(x, x_prev, params["mu_r"]) @ params["wr"]
    k = _mix(x, x_prev, params["mu_k"]) @ params["wk"]
    v = _mix(x, x_prev, params["mu_v"]) @ params["wv"]
    g = _mix(x, x_prev, params["mu_g"]) @ params["wg"]
    xw = _mix(x, x_prev, params["mu_w"])
    lora = jnp.tanh(xw @ params["w_lora_a"]) @ params["w_lora_b"]
    logw = -jnp.exp(params["w0"] + lora.astype(jnp.float32))  # log(w) < 0
    return r, k, v, g, logw


def _heads(x, h):
    return x.reshape(*x.shape[:-1], h, HEAD_DIM)


def _groupnorm(y, scale, h):
    """per-head RMS normalization of the time-mix output."""
    dt = y.dtype
    y32 = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y32 = y32 * jax.lax.rsqrt(var + 1e-5)
    flat = y32.reshape(*y.shape[:-2], y.shape[-2] * y.shape[-1])
    return (flat * scale.astype(jnp.float32)).astype(dt)


def time_mix_chunked(params, x, state, x_last):
    """x: [B,S,d]; state: S matrices [B,H,N,N]; x_last: [B,d] (prev token for
    the shift at chunk boundaries).  Returns (y [B,S,d], state', x_last')."""
    b, s, d = x.shape
    h = d // HEAD_DIM
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    r, k, v, g, logw = _projections(params, x, x_prev)
    r, k, v = _heads(r, h), _heads(k, h), _heads(v, h)          # [B,S,H,N]
    logw = _heads(logw, h)                                       # [B,S,H,N]
    u = params["u"]

    n_chunks = max(1, s // CHUNK)
    L = s // n_chunks
    assert L * n_chunks == s, f"seq {s} not divisible into chunks"

    def reshape_c(t):
        return jnp.moveaxis(t.reshape(b, n_chunks, L, h, HEAD_DIM), 1, 0)

    rc, kc, vc, wc = map(reshape_c, (r, k, v, logw))             # [C,B,L,H,N]

    def chunk_body(S, inp):
        rr, kk, vv, lw = (t.astype(jnp.float32) for t in inp)    # [B,L,H,N]
        cum = jnp.cumsum(lw, axis=1)                             # inclusive
        ecum = cum - lw                                          # exclusive
        A = jnp.exp(ecum)                                        # [B,L,H,N]
        A_total = jnp.exp(cum[:, -1])                            # [B,H,N]
        # intra-chunk: D[t,s,i] = prod_{s<u<t} w_u = exp(ecum_t - cum_s), s<t
        ct = ecum[:, :, None, :, :]                              # [B,L,1,H,N]
        cs = cum[:, None, :, :, :]                               # [B,1,L,H,N]
        strict = jnp.tril(jnp.ones((L, L), bool), -1)[None, :, :, None, None]
        D = jnp.where(strict, jnp.exp(ct - cs), 0.0)             # [B,L,L,H,N]
        y_intra = jnp.einsum("blhi,blshi,bshi,bshj->blhj",
                             rr, D, kk, vv)
        y_diag = jnp.einsum("blhi,hi,blhi,blhj->blhj", rr, u, kk, vv)
        y_cross = jnp.einsum("blhi,bhij->blhj", rr * A, S)
        # state update: S' = diag(A_total) S + sum_s (A_total/A_s) k_s v_s^T
        decay_k = jnp.exp(cum[:, -1][:, None] - cum) * kk        # [B,L,H,N]
        S_new = A_total[..., None] * S + \
            jnp.einsum("blhi,blhj->bhij", decay_k, vv)
        return S_new, (y_intra + y_diag + y_cross)

    state, yc = jax.lax.scan(chunk_body, state.astype(jnp.float32),
                             (rc, kc, vc, wc))
    y = jnp.moveaxis(yc, 0, 1).reshape(b, s, h, HEAD_DIM)
    y = _groupnorm(y, params["ln_scale"], h)
    y = y * jax.nn.silu(g)
    out = y.astype(x.dtype) @ params["wo"]
    return out, state, x[:, -1, :]


def _last_valid(seq, prev, lengths):
    """Per-row last *valid* timestep of ``seq`` [B,S,d]; rows with
    ``lengths == 0`` keep their carried ``prev`` [B,d]."""
    idx = jnp.clip(lengths - 1, 0, seq.shape[1] - 1)
    picked = jnp.take_along_axis(seq, idx[:, None, None], axis=1)[:, 0]
    return jnp.where((lengths > 0)[:, None], picked, prev)


def time_mix_chunk(params, x, state, x_last, valid):
    """Padded-chunk time mix for chunked prefill (scan-state ABI).

    x: [B,C,d] ln1-normalized chunk (row-wise left-aligned); valid: [B,C]
    bool marks real tokens.  Pad tokens are neutralized before the kernel —
    decay w = 1 (logw = 0) and k = 0 — so S passes through them unchanged and
    the returned state equals the state after each row's last valid token;
    outputs at pad positions are garbage (callers mask by position).  Rows
    with no valid tokens keep (S, x_last) untouched.  Dispatches the
    recurrence through ``kernels.rwkv6.rwkv6_state_op`` (ref / Pallas).
    Returns (y [B,C,d], state' [B,H,N,N], x_last' [B,d]).

    This row-wise layout is also the segment layout of token-packed prefill:
    ``blocks.block_apply_packed`` scatters each packed segment to its slot's
    row (left-aligned, ``valid`` marking real tokens) before calling here,
    so one chunk ABI serves both the bucketed and the packed scheduler."""
    from repro.kernels.rwkv6 import rwkv6_state_op

    b, c, d = x.shape
    h = d // HEAD_DIM
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    r, k, v, g, logw = _projections(params, x, x_prev)
    rh, kh, vh = _heads(r, h), _heads(k, h), _heads(v, h)   # [B,C,H,N]
    lwh = _heads(logw, h)
    vm = valid[:, :, None, None]
    kh = jnp.where(vm, kh, 0.0)
    lwh = jnp.where(vm, lwh, 0.0)
    rh = jnp.where(vm, rh, 0.0)
    vh = jnp.where(vm, vh, 0.0)

    # pad time to a kernel-chunk multiple with more neutral tokens
    cp = -(-c // CHUNK) * CHUNK
    pad = [(0, 0), (0, cp - c), (0, 0), (0, 0)]

    def to_bh(t):
        t = jnp.pad(t.astype(jnp.float32), pad)
        return jnp.swapaxes(t, 1, 2).reshape(b * h, cp, HEAD_DIM)

    u = jnp.broadcast_to(params["u"].astype(jnp.float32)[None],
                         (b, h, HEAD_DIM)).reshape(b * h, HEAD_DIM)
    y, s_out = rwkv6_state_op(*map(to_bh, (rh, kh, vh, lwh)), u,
                              state.reshape(b * h, HEAD_DIM, HEAD_DIM))
    y = jnp.swapaxes(y.reshape(b, h, cp, HEAD_DIM), 1, 2)[:, :c]
    state = s_out.reshape(b, h, HEAD_DIM, HEAD_DIM)

    y = _groupnorm(y, params["ln_scale"], h)
    y = y * jax.nn.silu(g)
    out = y.astype(x.dtype) @ params["wo"]
    lengths = valid.sum(axis=1).astype(jnp.int32)
    return out, state, _last_valid(x, x_last, lengths)


def channel_mix_chunk(params, x, x_last, valid):
    """Padded-chunk channel mix: like :func:`channel_mix` on [B,C,d] but the
    carried token-shift value advances to each row's last *valid* position
    (pads and inactive rows never touch it)."""
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    xk = _mix(x, x_prev, params["cm_mu"])
    hidden = jnp.square(jax.nn.relu(xk @ params["cm_k"]))
    hidden = shard(hidden, "batch", "seq", "ff")
    lengths = valid.sum(axis=1).astype(jnp.int32)
    return hidden @ params["cm_v"], _last_valid(x, x_last, lengths)


def time_mix_step(params, x_t, state, x_last):
    """One decode step.  x_t: [B,d]; state [B,H,N,N]; x_last [B,d]."""
    b, d = x_t.shape
    h = d // HEAD_DIM
    r, k, v, g, logw = _projections(params, x_t, x_last)
    r, k, v = (_heads(t, h).astype(jnp.float32) for t in (r, k, v))  # [B,H,N]
    w = jnp.exp(_heads(logw, h))                                 # [B,H,N]
    u = params["u"]
    kv = k[..., :, None] * v[..., None, :]                       # [B,H,N,N]
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[..., None] * kv)
    state = w[..., None] * state + kv
    y = _groupnorm(y, params["ln_scale"], h)
    y = y * jax.nn.silu(g)
    out = y.astype(x_t.dtype) @ params["wo"]
    return out, state, x_t


def channel_mix(params, x, x_last):
    """RWKV channel mix (the FFN analogue).  Works for [B,S,d] and [B,d]."""
    if x.ndim == 3:
        x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
        new_last = x[:, -1, :]
    else:
        x_prev, new_last = x_last, x
    xk = _mix(x, x_prev, params["cm_mu"])
    hidden = jnp.square(jax.nn.relu(xk @ params["cm_k"]))
    hidden = shard(hidden, "batch", "seq", "ff") if hidden.ndim == 3 else hidden
    return hidden @ params["cm_v"], new_last


def init_state(cfg, batch: int):
    h = cfg.d_model // HEAD_DIM
    return {
        "S": jnp.zeros((batch, h, HEAD_DIM, HEAD_DIM), jnp.float32),
        "tm_last": jnp.zeros((batch, cfg.d_model), cfg.dtype),
        "cm_last": jnp.zeros((batch, cfg.d_model), cfg.dtype),
    }
