"""RG-LRU recurrent block (Griffin, arXiv:2402.19427) — RecurrentGemma's
recurrent unit, paired 2:1 with local attention.

    r_t = sigmoid(x W_a + b_a)            # recurrence gate
    i_t = sigmoid(x W_x + b_x)            # input gate
    a_t = exp(c * softplus(Lambda) * (-r_t))   # a^{c r_t}, a = sigmoid(Lambda)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is diagonal-linear, so training/prefill uses
``jax.lax.associative_scan`` (parallel prefix, log-depth) — the TPU-friendly
formulation; decode is the O(1) per-token step.  The block wraps the RG-LRU
with the Griffin recipe: linear in, short causal conv, gated GeLU branch,
linear out.  ``repro.kernels.rglru`` holds the Pallas twin of the scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import A, shard
from .layers import _dense_init

CONV_WIDTH = 4
C_FACTOR = 8.0


def rglru_init(key, cfg) -> tuple[dict, dict]:
    d = cfg.d_model
    dr = cfg.num_heads * cfg.resolved_head_dim   # recurrent width
    ks = jax.random.split(key, 6)
    params = {
        "w_in": _dense_init(ks[0], (d, dr), cfg.dtype),
        "w_gate_branch": _dense_init(ks[1], (d, dr), cfg.dtype),
        "conv_w": _dense_init(ks[2], (CONV_WIDTH, dr), cfg.dtype),
        "wa": _dense_init(ks[3], (dr, dr), cfg.dtype),
        "wx": _dense_init(ks[4], (dr, dr), cfg.dtype),
        "ba": jnp.zeros((dr,), jnp.float32),
        "bx": jnp.zeros((dr,), jnp.float32),
        "lam": jnp.full((dr,), 3.0, jnp.float32),   # sigmoid(3) ~ 0.95 decay
        "w_out": _dense_init(ks[5], (dr, d), cfg.dtype),
    }
    axes = {
        "w_in": A("embed", "ff"), "w_gate_branch": A("embed", "ff"),
        "conv_w": A(None, "ff"),
        "wa": A("ff", None), "wx": A("ff", None),
        "ba": A("embed"), "bx": A("embed"), "lam": A("embed"),
        "w_out": A("ff", "embed"),
    }
    return params, axes


def _gates(params, u):
    """u: [..., dr] -> (log_a, gated_input) in f32."""
    r = jax.nn.sigmoid((u @ params["wa"]).astype(jnp.float32) + params["ba"])
    i = jax.nn.sigmoid((u @ params["wx"]).astype(jnp.float32) + params["bx"])
    log_a = -C_FACTOR * jax.nn.softplus(params["lam"]) * r      # log a_t < 0
    a2 = jnp.exp(2.0 * log_a)
    scaled_in = jnp.sqrt(jnp.clip(1.0 - a2, 1e-9)) * (i * u.astype(jnp.float32))
    return log_a, scaled_in


def _conv(params, u, conv_state):
    """short causal conv along time.  u: [B,S,dr]; conv_state [B,W-1,dr]."""
    x = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    w = params["conv_w"]
    out = sum(x[:, i:i + u.shape[1], :] * w[i] for i in range(CONV_WIDTH))
    return out, x[:, -(CONV_WIDTH - 1):, :]


def rglru_block(params, x, state):
    """x: [B,S,d]; state dict {h:[B,dr], conv:[B,W-1,dr]}."""
    u = x @ params["w_in"]
    u = shard(u, "batch", "seq", "ff")
    u, conv_state = _conv(params, u, state["conv"])
    log_a, inp = _gates(params, u)
    # parallel prefix over the diagonal-linear recurrence
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, jnp.exp(a2) * b1 + b2
    # seed the scan with the carried state at t = -1
    log_a_seq = jnp.concatenate(
        [jnp.zeros_like(log_a[:, :1]), log_a], axis=1)
    inp_seq = jnp.concatenate(
        [state["h"].astype(jnp.float32)[:, None, :], inp], axis=1)
    _, h_all = jax.lax.associative_scan(combine, (log_a_seq, inp_seq), axis=1)
    h = h_all[:, 1:, :]
    new_state = {"h": h[:, -1, :], "conv": conv_state}
    gate = jax.nn.gelu((x @ params["w_gate_branch"]).astype(jnp.float32))
    y = (h * gate).astype(x.dtype) @ params["w_out"]
    return y, new_state


def rglru_chunk(params, x, state, valid):
    """Padded-chunk RG-LRU for chunked prefill (scan-state ABI).

    x: [B,C,d] chunk (row-wise left-aligned); valid: [B,C] bool marks real
    tokens; state {h: [B,dr], conv: [B,W-1,dr]} carried across chunk
    boundaries.  Pads are neutralized before the kernel — a = 1 (log_a = 0)
    and gated input 0 — so h passes through them unchanged; the conv carry
    advances to each row's last W-1 *valid* inputs.  Dispatches the
    recurrence through ``kernels.rglru.rglru_state_op`` (ref / Pallas).
    Returns (y [B,C,d], state').

    This row-wise layout is also the segment layout of token-packed prefill:
    ``blocks.block_apply_packed`` scatters each packed segment to its slot's
    row (left-aligned, ``valid`` marking real tokens) before calling here,
    so one chunk ABI serves both the bucketed and the packed scheduler."""
    from repro.kernels.rglru import rglru_state_op

    b, c, _ = x.shape
    u = x @ params["w_in"]
    u = shard(u, "batch", "seq", "ff")
    ext = jnp.concatenate([state["conv"].astype(u.dtype), u], axis=1)
    w = params["conv_w"]
    u_conv = sum(ext[:, i:i + c, :] * w[i] for i in range(CONV_WIDTH))
    log_a, inp = _gates(params, u_conv)
    vm = valid[:, :, None]
    log_a = jnp.where(vm, log_a, 0.0)
    inp = jnp.where(vm, inp, 0.0)
    # pad time to a kernel-chunk multiple with more neutral tokens
    from repro.kernels.rglru.rglru import CHUNK as KCHUNK
    cp = -(-c // KCHUNK) * KCHUNK if c > KCHUNK else c
    tpad = [(0, 0), (0, cp - c), (0, 0)]
    h_seq, h_out = rglru_state_op(jnp.pad(log_a, tpad), jnp.pad(inp, tpad),
                                  state["h"])
    h_seq = h_seq[:, :c]
    # conv carry: the last W-1 entries of [old conv ++ valid inputs] per row
    lengths = valid.sum(axis=1).astype(jnp.int32)
    idx = lengths[:, None] + jnp.arange(CONV_WIDTH - 1, dtype=jnp.int32)
    new_conv = jnp.take_along_axis(ext, idx[:, :, None], axis=1)
    new_state = {"h": h_out.astype(state["h"].dtype),
                 "conv": new_conv.astype(state["conv"].dtype)}
    gate = jax.nn.gelu((x @ params["w_gate_branch"]).astype(jnp.float32))
    y = (h_seq.astype(jnp.float32) * gate).astype(x.dtype) @ params["w_out"]
    return y, new_state


def rglru_step(params, x_t, state):
    """One decode token.  x_t: [B,d]."""
    u = x_t @ params["w_in"]
    # conv state: [B, W-1, dr] holds the last W-1 inputs
    xs = jnp.concatenate([state["conv"].astype(u.dtype), u[:, None, :]], axis=1)
    w = params["conv_w"]
    u_conv = sum(xs[:, i, :] * w[i] for i in range(CONV_WIDTH))
    log_a, inp = _gates(params, u_conv)
    h = jnp.exp(log_a) * state["h"].astype(jnp.float32) + inp
    new_state = {"h": h, "conv": xs[:, 1:, :]}
    gate = jax.nn.gelu((x_t @ params["w_gate_branch"]).astype(jnp.float32))
    y = (h * gate).astype(x_t.dtype) @ params["w_out"]
    return y, new_state


def init_state(cfg, batch: int):
    dr = cfg.num_heads * cfg.resolved_head_dim
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, dr), jnp.float32),
    }
