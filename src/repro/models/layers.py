"""Model building blocks: norms, RoPE, GQA attention (full / windowed /
bidirectional / decode-with-cache), dense MLPs.

Pure functions over parameter pytrees.  Every init returns ``(params, axes)``
where ``axes`` is a parallel tree of :class:`~repro.distributed.sharding.Axes`
logical-name leaves used to derive PartitionSpecs.

Attention uses a query-chunked exact algorithm (lax.scan over query blocks)
above ``CHUNK_THRESHOLD`` so scores never materialize at [S, S] — the XLA
twin of the Pallas flash kernel in ``repro.kernels.flash_attention`` (which
replaces the inner computation on real TPUs; see kernels/*/ops.py).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import A, shard

CHUNK_THRESHOLD = 2048
Q_CHUNK = 512

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> tuple[dict, dict]:
    return {"scale": jnp.ones((d,), dtype)}, {"scale": A("embed")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * params["scale"]


def layernorm_init(d: int, dtype) -> tuple[dict, dict]:
    return ({"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": A("embed"), "bias": A("embed")})


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * params["scale"] + params["bias"]


def norm_init(kind: str, d: int, dtype):
    return rmsnorm_init(d, dtype) if kind == "rms" else layernorm_init(d, dtype)


def apply_norm(kind: str, params: dict, x: jax.Array) -> jax.Array:
    return rmsnorm(params, x) if kind == "rms" else layernorm(params, x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: [..., S] (absolute)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]   # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense projections
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, in_axis=-2):
    fan_in = shape[in_axis]
    scale = 1.0 / jnp.sqrt(fan_in)
    return (jax.random.uniform(key, shape, jnp.float32, -1.0, 1.0) * scale).astype(dtype)


def attention_init(key, cfg, *, cross: bool = False) -> tuple[dict, dict]:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    params = {
        "wq": _dense_init(ks[0], (d, h, hd), cfg.dtype),
        "wk": _dense_init(ks[1], (d, kv, hd), cfg.dtype),
        "wv": _dense_init(ks[2], (d, kv, hd), cfg.dtype),
        "wo": _dense_init(ks[3], (h, hd, d), cfg.dtype),
    }
    axes = {
        "wq": A("embed", "heads", None),
        "wk": A("embed", "kv_heads", None),
        "wv": A("embed", "kv_heads", None),
        "wo": A("heads", None, "embed"),
    }
    return params, axes


def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: [B,Sq,H,D], k: [B,Sk,Kv,D] -> scores [B,H,Sq,Sk] without
    materializing repeated KV (GQA grouped einsum)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k)       # [B,Kv,G,Sq,Sk]
    return s.reshape(b, h, sq, k.shape[1])


def _grouped_out(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: [B,H,Sq,Sk], v: [B,Sk,Kv,D] -> [B,Sq,H,D]."""
    b, h, sq, sk = p.shape
    kvh = v.shape[2]
    g = h // kvh
    pg = p.reshape(b, kvh, g, sq, sk)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pg, v)
    return o.reshape(b, sq, h, o.shape[-1])


def _mask_bias(q_pos, k_pos, *, causal: bool, window: int) -> jax.Array:
    """[Sq, Sk] additive mask from absolute positions."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = jnp.ones(dq.shape[:1] + dk.shape[1:], bool)
    if causal:
        ok &= dk <= dq
    if window > 0:
        ok &= (dq - dk) < window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              q_pos: jax.Array, k_pos: jax.Array,
              causal: bool = True, window: int = 0) -> jax.Array:
    """Exact attention, query-chunked above CHUNK_THRESHOLD.

    q [B,Sq,H,D] / k,v [B,Sk,Kv,D]; positions are 1-D absolute indices.
    window=0 means unbounded (full); window=W keeps |q-k| < W (SWA/local).

    REPRO_ATTN_IMPL=pallas (or pallas_interpret for CPU validation) routes
    standard self-attention through the differentiable Pallas flash kernels
    (fwd + custom_vjp bwd, kernels/flash_attention) — the on-TPU path.
    """
    import os
    impl = os.environ.get("REPRO_ATTN_IMPL", "xla")
    if impl.startswith("pallas") and q.shape[1] == k.shape[1]:
        from repro.kernels.flash_attention.vjp import flash_attention_grad
        out = flash_attention_grad(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), causal, window,
            impl == "pallas_interpret")
        return jnp.swapaxes(out, 1, 2)
    scale = q.shape[-1] ** -0.5
    sq = q.shape[1]
    if sq <= CHUNK_THRESHOLD or sq % Q_CHUNK != 0:
        s = _grouped_scores(q * scale, k).astype(jnp.float32)
        s = s + _mask_bias(q_pos, k_pos, causal=causal, window=window)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return _grouped_out(p, v)

    n_chunks = sq // Q_CHUNK
    qc = q.reshape(q.shape[0], n_chunks, Q_CHUNK, *q.shape[2:])
    qp = q_pos.reshape(n_chunks, Q_CHUNK)

    # Windowed kinds only ever attend to the trailing `window` positions:
    # slice K/V per q-chunk to [W + C] instead of scoring all S keys
    # (EXPERIMENTS.md SPerf gemma3: local layers are 5/6 of the stack, so
    # score traffic drops ~2-3x at 4k and ~8x at 32k prefill).
    kv_span = min(window + Q_CHUNK, k.shape[1]) if window > 0 else k.shape[1]
    chunk_starts = jnp.clip(
        (jnp.arange(n_chunks) + 1) * Q_CHUNK - kv_span, 0, k.shape[1] - kv_span)

    # flash-attention memory behaviour on the XLA path: remat the chunk body
    # so the backward recomputes scores per chunk from (q_i, k, v) instead of
    # materializing f32 [chunks, H, Cq, S] score tensors.
    @partial(jax.checkpoint, prevent_cse=False,
             policy=jax.checkpoint_policies.nothing_saveable)
    def body(_, inp):
        q_i, qp_i, start = inp
        k_i = jax.lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
        v_i = jax.lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
        kp_i = jax.lax.dynamic_slice_in_dim(k_pos, start, kv_span, axis=0)
        s = _grouped_scores(q_i * scale, k_i).astype(jnp.float32)
        s = s + _mask_bias(qp_i, kp_i, causal=causal, window=window)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return None, _grouped_out(p, v_i)

    _, out = jax.lax.scan(body, None,
                          (jnp.moveaxis(qc, 1, 0), qp, chunk_starts))
    out = jnp.moveaxis(out, 0, 1)  # [B, n, C, H, D]
    return out.reshape(q.shape)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     k_pos: jax.Array, q_pos: jax.Array,
                     window: int = 0) -> jax.Array:
    """One-token attention against a cache.  q [B,1,H,D], caches [B,S,Kv,D].
    ``k_pos`` [B or 1, S] gives each slot's absolute position; unwritten or
    out-of-window slots are masked via position validity (pos >= 0).  The
    C=1 case of :func:`chunk_attention` — one masking implementation keeps
    decode and chunked prefill in exact agreement."""
    return chunk_attention(q, k_cache, v_cache, k_pos=k_pos,
                           q_pos=q_pos[:, None], window=window)


def chunk_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    k_pos: jax.Array, q_pos: jax.Array,
                    window: int = 0) -> jax.Array:
    """Multi-token attention against per-row positioned keys (chunked
    prefill).  q [B,C,H,D]; k,v [B,N,Kv,D]; k_pos [B,N] absolute slot
    positions (-1 = unwritten); q_pos [B,C] absolute query positions.

    The causal/window structure is carried entirely by the position arrays,
    so the same code attends a prompt chunk against (prior-chunk cache ++
    in-chunk keys) with exact masking."""
    scale = q.shape[-1] ** -0.5
    s = _grouped_scores(q * scale, k).astype(jnp.float32)   # [B,H,C,N]
    valid = k_pos[:, None, :] >= 0                           # [B,C,N]
    valid &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window > 0:
        valid &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    s = jnp.where(valid[:, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return _grouped_out(p, v)


def segment_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      q_pos: jax.Array, k_pos: jax.Array,
                      q_seg: jax.Array, k_seg: jax.Array,
                      window: int = 0) -> jax.Array:
    """Token-packed ragged attention (packed prefill).

    One flat stream carries chunks from *different* requests; every query
    and key names its owning segment, and a key is visible iff it belongs
    to the **same segment** (no cross-request attention), has been written
    (``k_pos >= 0``), is causal (``k_pos <= q_pos``), and sits inside the
    sliding window.  q [B,P,H,D]; k,v [B,N,Kv,D]; q_pos/q_seg [B,P];
    k_pos/k_seg [B,N] (segment id < 0 = dead pad: fully masked).

    The unmasked (segment, position) pairs are exactly the pairs the
    per-slot :func:`chunk_attention` path exposes, so packed and bucketed
    prefill agree up to summation order.  Fully-masked queries (dead pad
    lanes, or a live lane whose predicate admits no key) return exact
    zeros, so XLA-vs-Pallas parity holds on every lane.

    Dispatches through ``kernels/segment_attention`` (``REPRO_SEGMENT_IMPL``
    = ``xla`` | ``pallas`` | ``pallas_interpret``): the fused Pallas kernel
    runs an online softmax over K/V tiles with the same-segment / written /
    causal / window predicate fused into the tile mask, so the
    ``[B,H,P,N]`` score matrix never materializes."""
    # routed through the serving TP wrapper: head-sharded under an active
    # serve mesh (all-gathered back to the full head set in-body), the
    # plain fused op otherwise — bit-identical either way
    from repro.distributed.collectives import tp_segment_attention
    out = [tp_segment_attention(q[i], k[i], v[i], q_pos[i], k_pos[i],
                                q_seg[i], k_seg[i], window=window)
           for i in range(q.shape[0])]   # the packed stream is B == 1
    return jnp.stack(out).astype(q.dtype)


def attn_project_q(params, x, *, positions, theta):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    return rope(q, positions, theta)


def attn_project_kv(params, x, *, positions, theta):
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    return rope(k, positions, theta), v


def attn_output(params, o):
    return jnp.einsum("bshk,hkd->bsd", o, params["wo"])


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, d_ff: int, kind: str, dtype) -> tuple[dict, dict]:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        params = {
            "w_gate": _dense_init(ks[0], (d, d_ff), dtype),
            "w_up": _dense_init(ks[1], (d, d_ff), dtype),
            "w_down": _dense_init(ks[2], (d_ff, d), dtype),
        }
        axes = {"w_gate": A("embed", "ff"), "w_up": A("embed", "ff"),
                "w_down": A("ff", "embed")}
    else:  # gelu
        params = {
            "w_up": _dense_init(ks[0], (d, d_ff), dtype),
            "w_down": _dense_init(ks[1], (d_ff, d), dtype),
        }
        axes = {"w_up": A("embed", "ff"), "w_down": A("ff", "embed")}
    return params, axes


def mlp(params: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    h = shard(h, "batch", "seq", "ff")
    return h @ params["w_down"]
