"""Model zoo: build / batch / input-spec helpers over ArchConfig."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from . import transformer

__all__ = ["init", "loss_fn", "forward", "prefill", "prefill_chunk",
           "prefill_packed", "step_packed", "step_spec",
           "supports_chunked_prefill",
           "supports_paged_kv", "decode_step", "init_cache",
           "init_paged_cache", "map_paged_caches", "copy_paged_blocks",
           "make_batch", "input_specs"]

init = transformer.init
loss_fn = transformer.loss_fn
forward = transformer.forward
prefill = transformer.prefill
prefill_chunk = transformer.prefill_chunk
prefill_packed = transformer.prefill_packed
step_packed = transformer.step_packed
step_spec = transformer.step_spec
supports_chunked_prefill = transformer.supports_chunked_prefill
supports_paged_kv = transformer.supports_paged_kv
decode_step = transformer.decode_step
init_cache = transformer.init_cache
init_paged_cache = transformer.init_paged_cache
map_paged_caches = transformer.map_paged_caches
copy_paged_blocks = transformer.copy_paged_blocks


def token_seq_len(cfg: ArchConfig, seq_len: int) -> int:
    """Backbone sequence is seq_len; VLM prefixes patches inside it."""
    if cfg.frontend == "vision":
        return seq_len - cfg.num_patches
    return seq_len


def make_batch(cfg: ArchConfig, shape: ShapeConfig, rng: np.random.Generator):
    """Concrete small batch for CPU smoke tests / examples."""
    b, s = shape.global_batch, shape.seq_len
    st = token_seq_len(cfg, s)
    batch = {}
    if shape.kind in ("train", "prefill"):
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, st)), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (b, st)), jnp.int32)
    if cfg.frontend == "vision":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_patches, cfg.frontend_dim)),
            jnp.float32)
    if cfg.encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return batch


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell — the
    dry-run lowers against these (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    st = token_seq_len(cfg, s)
    sds = jax.ShapeDtypeStruct
    specs: dict = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = sds((b, st), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = sds((b, st), jnp.int32)
    else:  # decode
        specs["token"] = sds((b,), jnp.int32)
        specs["pos"] = sds((b,), jnp.int32)
        specs["caches"] = jax.eval_shape(
            lambda: init_cache(cfg, b, s))
    if cfg.frontend == "vision" and shape.kind != "decode":
        specs["patches"] = sds((b, cfg.num_patches, cfg.frontend_dim), jnp.float32)
    if cfg.encoder_decoder and shape.kind != "decode":
        specs["frames"] = sds((b, cfg.enc_seq, cfg.d_model), jnp.float32)
    return specs


def abstract_params(cfg: ArchConfig):
    """(ShapeDtypeStruct params tree, axes tree) without allocating.  The
    axes tree is plain Python built during tracing, captured via side box."""
    box = {}

    def f(k):
        p, a = init(cfg, k)
        box["axes"] = a
        return p

    params = jax.eval_shape(f, jax.random.key(0))
    return params, box["axes"]
