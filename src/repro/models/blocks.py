"""Per-layer blocks: init / apply / cache, dispatched on block *kind*.

Kinds (``ArchConfig.block_pattern`` entries):
  ``full``    causal full attention + FFN
  ``swa``     sliding-window attention (window = cfg.window)
  ``local``   same as swa (gemma3 local layers; ring KV cache)
  ``global``  full attention with the long-context rope theta (gemma3)
  ``bidir``   bidirectional attention (whisper encoder)
  ``rwkv6``   RWKV-6 time mix + channel mix (attention-free)
  ``rglru``   RG-LRU recurrent block + FFN (recurrentgemma)
A ``+moe`` suffix swaps the dense FFN for the MoE layer (e.g. ``full+moe``).

Every apply works in two modes:
  * full-seq (train / prefill): x [B,S,d]; optionally writes a decode cache.
  * step (decode): x [B,1,d] against the cache.
Caches are dict pytrees; attention caches hold (k, v, pos) with ring
semantics for windowed kinds.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import A, shard
from . import layers, moe as moe_lib, rglru as rglru_lib, rwkv6 as rwkv6_lib
from .layers import apply_norm, norm_init

ATTN_KINDS = ("full", "swa", "local", "global", "bidir")
# kinds the chunked/bucketed prefill path can serve: attention via position
# masking, recurrent via the state-in/state-out scan kernels
CHUNKABLE_KINDS = ATTN_KINDS + ("rwkv6", "rglru")


def split_kind(kind: str) -> tuple[str, bool]:
    if kind.endswith("+moe"):
        return kind[:-4], True
    return kind, False


def block_init(key, cfg, kind: str) -> tuple[dict, dict]:
    base, is_moe = split_kind(kind)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params: dict = {}
    axes: dict = {}
    params["ln1"], axes["ln1"] = norm_init(cfg.norm, cfg.d_model, cfg.dtype)
    if base in ATTN_KINDS:
        params["attn"], axes["attn"] = layers.attention_init(k1, cfg)
    elif base == "rwkv6":
        params["tm_cm"], axes["tm_cm"] = rwkv6_lib.rwkv6_init(k1, cfg)
        params["ln2"], axes["ln2"] = norm_init(cfg.norm, cfg.d_model, cfg.dtype)
        return params, axes          # rwkv6 block has its own channel mix
    elif base == "rglru":
        params["rglru"], axes["rglru"] = rglru_lib.rglru_init(k1, cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    params["ln2"], axes["ln2"] = norm_init(cfg.norm, cfg.d_model, cfg.dtype)
    if is_moe:
        params["moe"], axes["moe"] = moe_lib.moe_init(k2, cfg)
    else:
        params["mlp"], axes["mlp"] = layers.mlp_init(
            k2, cfg.d_model, cfg.d_ff, cfg.mlp, cfg.dtype)
    return params, axes


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def cache_len_for(cfg, kind: str, seq_len: int, margin: int = 0) -> int:
    """Ring length for one layer's dense cache.  ``margin`` widens windowed
    rings past ``cfg.window``: speculative decode writes up to ``k`` draft
    positions past the pending token before the oldest in-window key is
    dead, so a ring must hold ``window + k`` entries or a rejected draft
    would overwrite a key the next tick still attends to."""
    base, _ = split_kind(kind)
    if base in ("swa", "local"):
        return min(cfg.window + margin, seq_len)
    return seq_len


def block_cache_init(cfg, kind: str, batch: int, seq_len: int,
                     ring_margin: int = 0):
    base, _ = split_kind(kind)
    if base in ATTN_KINDS:
        n = cache_len_for(cfg, kind, seq_len, margin=ring_margin)
        hd = cfg.resolved_head_dim
        return {
            "k": jnp.zeros((batch, n, cfg.num_kv_heads, hd), cfg.dtype),
            "v": jnp.zeros((batch, n, cfg.num_kv_heads, hd), cfg.dtype),
            "pos": jnp.full((batch, n), -1, jnp.int32),
        }
    if base == "rwkv6":
        return rwkv6_lib.init_state(cfg, batch)
    if base == "rglru":
        # exactly rglru_step's state structure: cache trees from init_cache
        # and from apply must match for per-slot merges to tree.map
        return rglru_lib.init_state(cfg, batch)
    raise ValueError(kind)


def paged_cache_init(cfg, kind: str, num_blocks: int, block_tokens: int):
    """Physical block store for one attention layer: ``[N, Kv, T, D]``
    (kernels/paged_attention ABI).  There is no ``pos`` plane — positions
    are implied by block-table order — and no per-slot batch axis: all
    sequences share the store through their tables."""
    base, _ = split_kind(kind)
    if base not in ATTN_KINDS:
        raise ValueError(f"paged KV requires attention blocks, got {kind!r}")
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((num_blocks, cfg.num_kv_heads, block_tokens, hd),
                       cfg.dtype),
        "v": jnp.zeros((num_blocks, cfg.num_kv_heads, block_tokens, hd),
                       cfg.dtype),
    }


def _paged_scatter(cache, k, v, pos, valid, block_tables, seg=None):
    """Write per-token K/V into the block store through the table.

    k, v: [B, C, Kv, D]; pos: [B, C] absolute logical positions; valid:
    [B, C] bool (False rows/tokens are dropped).  The routing is fully
    **per-token**: each token resolves its own table row — by default the
    batch row it sits in, or, when ``seg`` ([B, C] int32 slot ids, -1 =
    dead) is given, the slot it *belongs to* regardless of where it sits
    in the stream (the packed-prefill layout, where one [1, P] stream
    carries chunks from many requests).  Distinct logical positions map to
    distinct (block, offset) pairs, so the scatter never collides."""
    n, _, t, _ = cache["k"].shape
    b, m = block_tables.shape
    blk = jnp.clip(pos // t, 0, m - 1)
    if seg is None:
        entry = jnp.take_along_axis(block_tables, blk, axis=1)   # [B, C]
    else:
        entry = block_tables[jnp.clip(seg, 0, b - 1), blk]       # [*, C]
        valid = valid & (seg >= 0)
    phys = jnp.where(valid & (entry >= 0), entry, n)             # n => drop
    off = (pos % t).astype(jnp.int32)
    return {
        "k": cache["k"].at[phys, :, off].set(
            k.astype(cache["k"].dtype), mode="drop"),
        "v": cache["v"].at[phys, :, off].set(
            v.astype(cache["v"].dtype), mode="drop"),
    }


def paged_copy_blocks(cache, src, dst, block_axis: int = 0):
    """Copy whole physical blocks ``src[i] -> dst[i]`` within one layer's
    block store — the device side of ``KVLease.writable`` copy-on-write
    resolution: before a borrower writes into a block it shares with the
    prefix cache (or a forked lease), the engine re-homes the block and
    copies the shared bytes here.  ``src``/``dst`` are [P] int32 physical
    ids; the gather happens before the scatter, so a source is read at its
    pre-copy value even under donation.  Duplicate pairs are allowed (the
    engine pads the pair list to a power-of-two shape by repeating one
    pair — both writes carry identical bytes)."""
    def cp(a):
        vals = jnp.take(a, src, axis=block_axis)
        idx = (slice(None),) * block_axis + (dst,)
        return a.at[idx].set(vals)
    return {"k": cp(cache["k"]), "v": cp(cache["v"])}


def _paged_view(cache, block_tables):
    """Materialize the logical [B, M*T, Kv, D] K/V view plus its position
    plane (-1 behind unallocated table entries) — the XLA twin of the paged
    Pallas kernel's scalar-prefetch gather, used by chunked prefill where
    queries span many tokens.  Delegates to the kernel family's
    ``paged_gather`` so the block-table ABI has one decoder."""
    from repro.kernels.paged_attention import paged_gather
    k, v, k_pos = paged_gather(cache["k"], cache["v"], block_tables)
    return jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2), k_pos


def _theta(cfg, base: str) -> float:
    if base == "global" and cfg.rope_theta_global:
        return cfg.rope_theta_global
    return cfg.rope_theta


# ---------------------------------------------------------------------------
# apply: full sequence (train / prefill)
# ---------------------------------------------------------------------------


def block_apply_seq(cfg, kind: str, params: dict, x: jax.Array,
                    positions: jax.Array, cache=None):
    """x: [B,S,d]; positions: [S] absolute.  If ``cache`` is given (prefill),
    the computed K/V (or recurrent state) is written into it.
    Returns (x, cache, aux)."""
    base, is_moe = split_kind(kind)
    aux = jnp.zeros((), jnp.float32)

    if base == "rwkv6":
        p = params["tm_cm"]
        st = cache if cache is not None else rwkv6_lib.init_state(cfg, x.shape[0])
        h = apply_norm(cfg.norm, params["ln1"], x)
        y, S_new, tm_last = rwkv6_lib.time_mix_chunked(p, h, st["S"], st["tm_last"])
        x = x + y
        h2 = apply_norm(cfg.norm, params["ln2"], x)
        cm_out, cm_last = rwkv6_lib.channel_mix(p, h2, st["cm_last"])
        x = x + cm_out
        new_cache = {"S": S_new, "tm_last": tm_last, "cm_last": cm_last}
        return x, (new_cache if cache is not None else None), aux

    if base == "rglru":
        st = cache if cache is not None else rglru_lib.init_state(cfg, x.shape[0])
        h = apply_norm(cfg.norm, params["ln1"], x)
        y, st_new = rglru_lib.rglru_block(params["rglru"], h, st)
        x = x + y
    else:
        theta = _theta(cfg, base)
        h = apply_norm(cfg.norm, params["ln1"], x)
        q = layers.attn_project_q(params["attn"], h, positions=positions,
                                  theta=theta)
        k, v = layers.attn_project_kv(params["attn"], h, positions=positions,
                                      theta=theta)
        q = shard(q, "batch", "seq", "heads", None)
        k = shard(k, "batch", "seq", "kv_heads", None)
        window = cfg.window if base in ("swa", "local") else 0
        causal = base != "bidir"
        o = layers.attention(q, k, v, q_pos=positions, k_pos=positions,
                             causal=causal, window=window)
        x = x + layers.attn_output(params["attn"], o)
        if cache is not None:
            cache = _write_cache(cache, k, v, positions)
        st_new = None

    h2 = apply_norm(cfg.norm, params["ln2"], x)
    if is_moe:
        y, aux = moe_lib.moe_apply_ep(params["moe"], h2, cfg, return_aux=True)
    else:
        y = layers.mlp(params["mlp"], h2, cfg.mlp)
    x = x + y
    x = shard(x, "batch", "seq", "embed")
    new_cache = st_new if base == "rglru" else cache
    return x, new_cache, aux


def _write_cache(cache, k, v, positions):
    """Write full-seq K/V into a (possibly ring) cache."""
    n = cache["k"].shape[1]
    s = k.shape[1]
    if s >= n:  # keep the last n entries, ring-indexed
        k_tail, v_tail = k[:, -n:], v[:, -n:]
        pos_tail = positions[-n:]
        slots = (pos_tail % n).astype(jnp.int32)
        order = jnp.argsort(slots)
        return {
            "k": k_tail[:, order],
            "v": v_tail[:, order],
            "pos": jnp.broadcast_to(pos_tail[order], (k.shape[0], n)),
        }
    kc = cache["k"].at[:, :s].set(k)
    vc = cache["v"].at[:, :s].set(v)
    pc = cache["pos"].at[:, :s].set(jnp.broadcast_to(positions, (k.shape[0], s)))
    return {"k": kc, "v": vc, "pos": pc}


# ---------------------------------------------------------------------------
# apply: prompt chunk against a live cache (chunked / bucketed prefill)
# ---------------------------------------------------------------------------


def block_apply_chunk(cfg, kind: str, params: dict, x: jax.Array,
                      pos: jax.Array, valid: jax.Array, cache: dict,
                      block_tables: jax.Array | None = None):
    """x: [B,C,d] padded prompt chunk; pos: [B,C] absolute positions
    (row-wise contiguous, left-aligned); valid: [B,C] bool marks real
    tokens (False = pad or inactive slot); cache: attention KV cache or
    recurrent state.  With ``block_tables`` ([B,M] int32, attention kinds
    only) the cache is a paged block store: chunk K/V are scattered into
    physical blocks first, then queries attend to the table-gathered logical
    view (write-then-gather is exact because rows prefill front-to-back, so
    every position <= q_pos is written).

    Attention kinds: queries attend to (prior cache entries ++ in-chunk
    keys) under one softmax, so a chunk mid-prompt sees its full history
    exactly.  Only the last ``min(row_len, ring)`` valid K/V land in the
    cache (drop-mode scatter), which both respects ring semantics and keeps
    pad/inactive rows from ever touching cache state.

    Recurrent kinds (rwkv6 / rglru): scan state is threaded across the
    chunk boundary through the state-in/state-out kernel variants — pads are
    neutralized (decay 1, input 0) so per-row state advances over valid
    tokens only (the scan-state ABI, kernels/README.md).

    MoE FFNs route with ``valid``-aware capacity so pad tokens cannot steal
    expert slots from real ones (overflow semantics unchanged)."""
    base, is_moe = split_kind(kind)
    aux = jnp.zeros((), jnp.float32)

    if base in ("rwkv6", "rglru"):
        # a row whose chunk starts at position 0 is beginning its prompt in
        # a (possibly reused) slot: its scan state must restart from zero.
        # Attention caches mask the previous occupant's entries by position;
        # recurrent state has no positions, so the reset is explicit here.
        fresh = (pos[:, 0] == 0) & valid[:, 0]               # [B]

        def reset(st):
            return jax.tree.map(
                lambda a: jnp.where(
                    fresh.reshape((-1,) + (1,) * (a.ndim - 1)),
                    jnp.zeros_like(a), a), st)

        cache = reset(cache)

    if base == "rwkv6":
        p = params["tm_cm"]
        h = apply_norm(cfg.norm, params["ln1"], x)
        y, S_new, tm_last = rwkv6_lib.time_mix_chunk(
            p, h, cache["S"], cache["tm_last"], valid)
        x = x + y
        h2 = apply_norm(cfg.norm, params["ln2"], x)
        cm_out, cm_last = rwkv6_lib.channel_mix_chunk(
            p, h2, cache["cm_last"], valid)
        x = x + cm_out
        new_cache = {"S": S_new.astype(cache["S"].dtype),
                     "tm_last": tm_last.astype(cache["tm_last"].dtype),
                     "cm_last": cm_last.astype(cache["cm_last"].dtype)}
        return x, new_cache, aux

    if base == "rglru":
        h = apply_norm(cfg.norm, params["ln1"], x)
        y, new_cache = rglru_lib.rglru_chunk(params["rglru"], h, cache, valid)
        x = x + y
    elif base in ATTN_KINDS:
        theta = _theta(cfg, base)
        h = apply_norm(cfg.norm, params["ln1"], x)
        q = layers.rope(jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wq"]),
                        pos, theta)
        k = layers.rope(jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wk"]),
                        pos, theta)
        v = jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wv"])

        window = cfg.window if base in ("swa", "local") else 0
        if block_tables is not None:
            new_cache = _paged_scatter(cache, k, v, pos, valid, block_tables)
            k_eff, v_eff, kpos_eff = _paged_view(new_cache, block_tables)
            o = layers.chunk_attention(q, k_eff, v_eff, k_pos=kpos_eff,
                                       q_pos=pos, window=window)
            x = x + layers.attn_output(params["attn"], o)
        else:
            kpos_chunk = jnp.where(valid, pos, -1).astype(jnp.int32)
            # cache entries at/after the chunk start are stale (a freed
            # slot's previous occupant); true history is strictly before it
            kpos_cache = jnp.where(cache["pos"] < pos[:, :1],
                                   cache["pos"], -1)
            k_eff = jnp.concatenate(
                [cache["k"], k.astype(cache["k"].dtype)], axis=1)
            v_eff = jnp.concatenate(
                [cache["v"], v.astype(cache["v"].dtype)], axis=1)
            kpos_eff = jnp.concatenate([kpos_cache, kpos_chunk], axis=1)
            o = layers.chunk_attention(q, k_eff, v_eff, k_pos=kpos_eff,
                                       q_pos=pos, window=window)
            x = x + layers.attn_output(params["attn"], o)

            # write-back: keep only each row's last min(len, n) valid
            # positions so ring slots are written at most once per call
            n = cache["k"].shape[1]
            row_len = valid.sum(axis=1).astype(jnp.int32)        # [B]
            last_pos = pos[:, 0] + row_len - 1
            keep = valid & (pos > (last_pos - n)[:, None])
            slots = jnp.where(keep, pos % n, n).astype(jnp.int32)  # n => drop
            bidx = jnp.arange(x.shape[0])[:, None]
            new_cache = {
                "k": cache["k"].at[bidx, slots].set(
                    k.astype(cache["k"].dtype), mode="drop"),
                "v": cache["v"].at[bidx, slots].set(
                    v.astype(cache["v"].dtype), mode="drop"),
                "pos": cache["pos"].at[bidx, slots].set(
                    pos.astype(jnp.int32), mode="drop"),
            }
    else:
        raise ValueError(f"chunked prefill cannot serve block kind {kind!r}")

    h2 = apply_norm(cfg.norm, params["ln2"], x)
    if is_moe:
        y = moe_lib.moe_apply_ep(params["moe"], h2, cfg, valid=valid)
    else:
        y = layers.mlp(params["mlp"], h2, cfg.mlp)
    x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# apply: token-packed ragged stream (packed prefill)
# ---------------------------------------------------------------------------


def block_apply_packed(cfg, kind: str, params: dict, x: jax.Array,
                       pos: jax.Array, slot_id: jax.Array, start: jax.Array,
                       seg_len: jax.Array, cache: dict,
                       block_tables: jax.Array | None = None):
    """One block over a token-packed ragged prefill stream.

    x: [1,P,d] — ONE flat stream holding contiguous chunks from up to B
    different requests (a new request's first chunk rides next to another
    request's later chunk); pos: [P] absolute position of each token in its
    own request; slot_id: [P] owning slot (-1 = dead pad, fully inert);
    start/seg_len: [B] per-slot chunk start and token count this call
    (the cu_seqlens twins: segment s spans stream indices
    ``[sum(seg_len[<s in stream order]), ...)``, but carrying them per-token
    keeps every mask O(1) to derive).  cache: the *batched* per-slot cache
    tree ([B, ...] leaves) or the paged block store.

    Attention kinds stay truly packed: queries attend through
    :func:`~repro.models.layers.segment_attention` against the flattened
    all-slot history view ++ in-stream keys, masked by segment id so no
    token ever sees another request; K/V write-back routes **per token** to
    its slot's dense ring row or paged block (``_paged_scatter`` with
    ``seg=slot_id``).

    Recurrent kinds (rwkv6/rglru) carry per-slot scan state with no
    position plane, so the stream is scattered to the per-slot left-aligned
    chunk layout, advanced through the existing scan-state ABI
    (:func:`block_apply_chunk`: pad neutralization, fresh-segment reset at
    position 0, MoE valid-aware capacity), and the outputs gathered back to
    their stream positions — segment-exact at B x P cost, which only the
    O(1)-state families pay."""
    base, is_moe = split_kind(kind)
    aux = jnp.zeros((), jnp.float32)
    p_len = x.shape[1]
    nslots = start.shape[0]
    valid = (slot_id >= 0)[None, :]                              # [1,P]

    if base in ("rwkv6", "rglru"):
        row = jnp.where(slot_id >= 0, slot_id, nslots)           # B => drop
        off = jnp.clip(pos - start[jnp.clip(slot_id, 0, nslots - 1)],
                       0, p_len - 1)
        xs = jnp.zeros((nslots, p_len, x.shape[2]), x.dtype)
        xs = xs.at[row, off].set(x[0], mode="drop")
        row_valid = (jnp.arange(p_len, dtype=jnp.int32)[None, :]
                     < seg_len[:, None])
        row_pos = start[:, None] + jnp.arange(p_len, dtype=jnp.int32)[None, :]
        y, new_cache, aux = block_apply_chunk(cfg, kind, params, xs, row_pos,
                                              row_valid, cache)
        xg = y[jnp.clip(slot_id, 0, nslots - 1), off][None]      # [1,P,d]
        return jnp.where(valid[..., None], xg, x), new_cache, aux

    if base not in ATTN_KINDS:
        raise ValueError(f"packed prefill cannot serve block kind {kind!r}")

    theta = _theta(cfg, base)
    h = apply_norm(cfg.norm, params["ln1"], x)
    pos2 = pos[None, :]                                          # [1,P]
    q = layers.rope(jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wq"]),
                    pos2, theta)
    k = layers.rope(jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wk"]),
                    pos2, theta)
    v = jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wv"])
    window = cfg.window if base in ("swa", "local") else 0
    q_seg = slot_id[None, :]                                     # [1,P]

    if block_tables is not None:
        # write-then-attend (exact: segments advance front-to-back, so every
        # position <= q_pos of the same segment is live in the store); the
        # in-stream keys are therefore already inside the block store.  The
        # xla impl materializes the table-gathered view; the Pallas kernel
        # gathers blocks via scalar prefetch with the segment predicate
        # fused into the tile mask (key segment = table row).
        from repro.distributed.collectives import tp_paged_segment_attention
        new_cache = _paged_scatter(cache, k, v, pos2, valid, block_tables,
                                   seg=q_seg)
        o = tp_paged_segment_attention(
            q[0], new_cache["k"], new_cache["v"], block_tables, pos,
            slot_id, window=window)[None].astype(q.dtype)
        x = x + layers.attn_output(params["attn"], o)
    else:
        b, n = cache["k"].shape[0], cache["k"].shape[1]
        kvh, hd = cache["k"].shape[2], cache["k"].shape[3]
        # every slot's history, flattened to one key axis; entries at/after a
        # slot's chunk start are stale (a freed slot's previous occupant)
        kpos_cache = jnp.where(cache["pos"] < start[:, None],
                               cache["pos"], -1)
        k_eff = jnp.concatenate(
            [cache["k"].reshape(1, b * n, kvh, hd),
             k.astype(cache["k"].dtype)], axis=1)
        v_eff = jnp.concatenate(
            [cache["v"].reshape(1, b * n, kvh, hd),
             v.astype(cache["v"].dtype)], axis=1)
        kpos_eff = jnp.concatenate(
            [kpos_cache.reshape(1, b * n),
             jnp.where(valid, pos2, -1).astype(jnp.int32)], axis=1)
        kseg_eff = jnp.concatenate(
            [jnp.repeat(jnp.arange(b, dtype=jnp.int32), n)[None, :],
             q_seg], axis=1)
        o = layers.segment_attention(q, k_eff, v_eff, q_pos=pos2,
                                     k_pos=kpos_eff, q_seg=q_seg,
                                     k_seg=kseg_eff, window=window)
        x = x + layers.attn_output(params["attn"], o)

        # per-token write-back into each token's OWN slot row; ring
        # semantics per segment: keep only the last min(seg_len, n) valid
        # positions so a ring slot is written at most once per call
        last_pos = start + seg_len - 1                           # [B]
        keep = (slot_id >= 0) & (
            pos > (last_pos[jnp.clip(slot_id, 0, b - 1)] - n))
        rows = jnp.where(keep, slot_id, b)                       # b => drop
        cols = (pos % n).astype(jnp.int32)
        new_cache = {
            "k": cache["k"].at[rows, cols].set(
                k[0].astype(cache["k"].dtype), mode="drop"),
            "v": cache["v"].at[rows, cols].set(
                v[0].astype(cache["v"].dtype), mode="drop"),
            "pos": cache["pos"].at[rows, cols].set(
                pos.astype(jnp.int32), mode="drop"),
        }

    h2 = apply_norm(cfg.norm, params["ln2"], x)
    if is_moe:
        y = moe_lib.moe_apply_ep(params["moe"], h2, cfg, valid=valid)
    else:
        y = layers.mlp(params["mlp"], h2, cfg.mlp)
    x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# apply: packed stream with speculative (length-k) decode segments
# ---------------------------------------------------------------------------


def block_apply_spec(cfg, kind: str, params: dict, x: jax.Array,
                     pos: jax.Array, slot_id: jax.Array, start: jax.Array,
                     seg_len: jax.Array, spec_rows: jax.Array, l_max: int,
                     cache: dict, block_tables: jax.Array | None = None):
    """:func:`block_apply_packed` for a stream whose decode segments carry
    speculative drafts (length ``1 + d`` segments, ``spec_rows`` [B] bool
    marking them; ``l_max`` static max segment length).

    Attention kinds need nothing new: the segment predicate
    (same-segment & ``k_pos <= q_pos``) already verifies every draft
    offset exactly, and rejected-suffix K/V self-heals — stale entries
    are position-masked (dense) or overwritten before the gather (paged)
    on the next tick.  Delegates unchanged.

    Recurrent kinds (rwkv6/rglru) mutate state per token, so a rejected
    draft must be *rolled back*.  Spec rows therefore advance through
    ``l_max`` sequential single-column chunk calls, snapshotting the state
    after each offset; non-spec rows take the normal full-chunk path.
    Returns the cache as a pending pair ``{"spec_stack": [L,B,...],
    "spec_full": [B,...]}`` — the caller selects snapshot ``accept[b]``
    per spec row once acceptance is known (``transformer.step_spec``)."""
    base, _ = split_kind(kind)
    if base not in ("rwkv6", "rglru"):
        return block_apply_packed(cfg, kind, params, x, pos, slot_id, start,
                                  seg_len, cache, block_tables=block_tables)

    p_len = x.shape[1]
    nslots = start.shape[0]
    valid = (slot_id >= 0)[None, :]                              # [1,P]
    row = jnp.where(slot_id >= 0, slot_id, nslots)               # B => drop
    off = jnp.clip(pos - start[jnp.clip(slot_id, 0, nslots - 1)],
                   0, p_len - 1)
    xs = jnp.zeros((nslots, p_len, x.shape[2]), x.dtype)
    xs = xs.at[row, off].set(x[0], mode="drop")
    row_valid = (jnp.arange(p_len, dtype=jnp.int32)[None, :]
                 < seg_len[:, None])
    row_pos = start[:, None] + jnp.arange(p_len, dtype=jnp.int32)[None, :]

    # non-spec (prefill) rows: one full-chunk call, spec rows masked out so
    # their state never advances here (and the fresh-at-0 reset still fires
    # only for genuine prompt starts)
    y_full, cache_full, aux = block_apply_chunk(
        cfg, kind, params, xs, row_pos, row_valid & ~spec_rows[:, None],
        cache)

    # spec rows: offsets advance one column at a time from the pre-tick
    # state, snapshotting after each offset — pads are neutral in the chunk
    # kernels, so width-1 sequential calls compose exactly
    l_eff = min(int(l_max), p_len)
    st = cache
    snaps, cols = [], []
    for j in range(l_eff):
        col_valid = spec_rows[:, None] & row_valid[:, j:j + 1]
        yj, st, aux_j = block_apply_chunk(
            cfg, kind, params, xs[:, j:j + 1], row_pos[:, j:j + 1],
            col_valid, st)
        aux = aux + aux_j
        snaps.append(st)
        cols.append(yj)
    y_spec = jnp.concatenate(cols, axis=1)                       # [B,l_eff,d]
    stack = jax.tree.map(lambda *s: jnp.stack(s), *snaps)        # [L,B,...]

    y_sp = jnp.zeros_like(y_full).at[:, :l_eff].set(y_spec)
    y = jnp.where(spec_rows[:, None, None], y_sp, y_full)
    xg = y[jnp.clip(slot_id, 0, nslots - 1), off][None]          # [1,P,d]
    pending = {"spec_stack": stack, "spec_full": cache_full}
    return jnp.where(valid[..., None], xg, x), pending, aux


# ---------------------------------------------------------------------------
# apply: single decode step
# ---------------------------------------------------------------------------


def _keep_active(active, new_state, old_state):
    """Per-row select so inactive slots' recurrent state stays untouched."""
    def sel(new, old):
        a = active.reshape(active.shape + (1,) * (new.ndim - 1))
        return jnp.where(a, new.astype(old.dtype), old)
    return jax.tree.map(sel, new_state, old_state)


def block_apply_step(cfg, kind: str, params: dict, x: jax.Array,
                     pos: jax.Array, cache: dict, active=None,
                     block_tables: jax.Array | None = None):
    """x: [B,1,d]; pos: [B] absolute position of this token.  ``active``
    ([B] bool, optional) masks cache/state writes for slots that are not
    decoding this tick (free, or mid chunked-prefill).  ``block_tables``
    ([B,M] int32, attention kinds only) switches the KV cache to the paged
    block store: this token's K/V is scattered into its physical block and
    attention runs through the paged decode kernel."""
    base, is_moe = split_kind(kind)
    aux = jnp.zeros((), jnp.float32)

    if base == "rwkv6":
        p = params["tm_cm"]
        h = apply_norm(cfg.norm, params["ln1"], x)[:, 0]
        y, S_new, tm_last = rwkv6_lib.time_mix_step(p, h, cache["S"], cache["tm_last"])
        x = x + y[:, None, :]
        h2 = apply_norm(cfg.norm, params["ln2"], x)[:, 0]
        cm_out, cm_last = rwkv6_lib.channel_mix(p, h2, cache["cm_last"])
        x = x + cm_out[:, None, :]
        new_cache = {"S": S_new, "tm_last": tm_last, "cm_last": cm_last}
        if active is not None:
            new_cache = _keep_active(active, new_cache, cache)
        return x, new_cache, aux

    if base == "rglru":
        h = apply_norm(cfg.norm, params["ln1"], x)[:, 0]
        y, st_new = rglru_lib.rglru_step(params["rglru"], h, cache)
        x = x + y[:, None, :]
        if active is not None:
            st_new = _keep_active(active, st_new, cache)
        new_cache = st_new
    else:
        theta = _theta(cfg, base)
        h = apply_norm(cfg.norm, params["ln1"], x)
        pos2d = pos[:, None]                                  # [B,1]
        q = layers.rope(jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wq"]),
                        pos2d, theta)
        k_t = layers.rope(jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wk"]),
                          pos2d, theta)
        v_t = jnp.einsum("bsd,dhk->bshk", h, params["attn"]["wv"])
        window = cfg.window if base in ("swa", "local") else 0
        if block_tables is not None:
            ok = jnp.ones(pos.shape, bool) if active is None else active
            new_cache = _paged_scatter(cache, k_t, v_t, pos[:, None],
                                       ok[:, None], block_tables)
            from repro.kernels.paged_attention import paged_decode_attention_op
            o = paged_decode_attention_op(q[:, 0], new_cache["k"],
                                          new_cache["v"], block_tables, pos,
                                          window=window)
            x = x + layers.attn_output(params["attn"], o[:, None])
        else:
            n = cache["k"].shape[1]
            slot = (pos % n).astype(jnp.int32)                # ring or direct
            if active is not None:
                slot = jnp.where(active, slot, n)             # n => dropped
            bidx = jnp.arange(x.shape[0])
            kc = cache["k"].at[bidx, slot].set(k_t[:, 0], mode="drop")
            vc = cache["v"].at[bidx, slot].set(v_t[:, 0], mode="drop")
            pc = cache["pos"].at[bidx, slot].set(pos.astype(jnp.int32),
                                                 mode="drop")
            o = layers.decode_attention(q, kc, vc, k_pos=pc, q_pos=pos,
                                        window=window)
            x = x + layers.attn_output(params["attn"], o)
            new_cache = {"k": kc, "v": vc, "pos": pc}

    h2 = apply_norm(cfg.norm, params["ln2"], x)
    if is_moe:
        y = moe_lib.moe_apply_ep_serve(
            params["moe"], h2, cfg,
            valid=None if active is None else active[:, None])
    else:
        y = layers.mlp(params["mlp"], h2, cfg.mlp)
    x = x + y
    return x, new_cache, aux
