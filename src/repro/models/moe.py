"""Fine-grained Mixture-of-Experts layer (DeepSeekMoE / Llama-4 style).

Design notes (roofline-honest):
  * shared experts always-on + routed experts top-k, softmax-renormalized.
  * capacity-based dispatch via **gather/scatter**, not GShard one-hot
    einsums: a [T,E,C] one-hot matmul would dominate compiled FLOPs by >100x
    over the expert GEMMs and poison the roofline's compute term.  Instead we
    compute each assignment's position-in-expert with a cumsum, scatter token
    ids into [G, E, C] slot tables, gather tokens, run batched expert GEMMs
    ([E, C, d] x [E, d, m]), and gather back — FLOPs = active-expert GEMMs
    only, as deployed MoE kernels achieve.
  * tokens are processed in fixed GROUPS along the sequence (<=512 tokens) so
    the slot tables stay small and shard over the data axes; capacity is per
    group: C = ceil(group * top_k / E * capacity_factor).  Overflow tokens
    drop to the shared path only (standard capacity-drop semantics).
  * expert dim shards over 'model' (EP); GSPMD inserts the all-to-alls.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import A, shard
from .layers import _dense_init

GROUP_TOKENS = 512


def moe_init(key, cfg) -> tuple[dict, dict]:
    d, e, m = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    params = {
        "router": _dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": _dense_init(ks[1], (e, d, m), cfg.dtype),
        "w_up": _dense_init(ks[2], (e, d, m), cfg.dtype),
        "w_down": _dense_init(ks[3], (e, m, d), cfg.dtype),
    }
    axes = {
        "router": A("embed", "experts"),
        "w_gate": A("experts", "embed", "moe_ff"),
        "w_up": A("experts", "embed", "moe_ff"),
        "w_down": A("experts", "moe_ff", "embed"),
    }
    if cfg.num_shared_experts:
        ms = cfg.moe_d_ff * cfg.num_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        params["shared"] = {
            "w_gate": _dense_init(ks2[0], (d, ms), cfg.dtype),
            "w_up": _dense_init(ks2[1], (d, ms), cfg.dtype),
            "w_down": _dense_init(ks2[2], (ms, d), cfg.dtype),
        }
        axes["shared"] = {"w_gate": A("embed", "ff"), "w_up": A("embed", "ff"),
                          "w_down": A("ff", "embed")}
    return params, axes


def _group_shape(batch: int, seq: int) -> tuple[int, int]:
    g_tokens = min(GROUP_TOKENS, seq)
    while seq % g_tokens:
        g_tokens -= 1
    return batch * (seq // g_tokens), g_tokens


def _valid_cap(nv, cap: int, cfg):
    """Drop threshold for a group with ``nv`` REAL tokens (traced scalar or
    [G] vector): capacity scales with the valid-token count so padding can
    neither steal nor inflate expert capacity.  ``cap`` (static, computed
    over the padded group size) stays the slot-table shape and upper bound."""
    cap_v = jnp.ceil(nv.astype(jnp.float32) * cfg.top_k / cfg.num_experts
                     * cfg.capacity_factor)
    return jnp.clip(cap_v.astype(jnp.int32), 1, cap)


def moe_apply(params: dict, x: jax.Array, cfg, *, return_aux: bool = False,
              valid: jax.Array | None = None):
    """x: [B, S, d] -> [B, S, d] (+ aux load-balance loss scalar).

    ``valid`` ([B, S] bool, optional) marks real tokens in a padded chunk
    (chunked prefill / masked decode): invalid tokens are excluded from the
    position-in-expert count AND the per-group capacity is clamped to
    ``ceil(n_valid * k / e * capacity_factor)``, so pads neither steal nor
    inflate expert capacity — capacity is computed over valid tokens.  Note
    that under capacity *overflow* the drop pattern still depends on how
    tokens are grouped (a chunked prompt is dispatched in chunk-sized
    groups, the one-shot path in up-to-``GROUP_TOKENS`` groups), so chunked
    and one-shot prefill are token-identical only when routing is drop-free
    (ample ``capacity_factor``; serving keeps drops exceptional)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    g, gt = _group_shape(b, s)
    cap = max(1, math.ceil(gt * k / e * cfg.capacity_factor))

    xg = x.reshape(g, gt, d)
    xg = shard(xg, "batch", None, "embed")

    logits = (xg.astype(jnp.float32) @ params["router"])          # [G,T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                        # [G,T,K]
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each assignment inside its expert (token-major priority)
    flat_i = top_i.reshape(g, gt * k)                             # [G,TK]
    onehot = jax.nn.one_hot(flat_i, e, dtype=jnp.int32)           # [G,TK,E]
    if valid is not None:
        # token-major repeat matches flat_i's [T, K] -> [T*K] layout
        valid_flat = jnp.repeat(valid.reshape(g, gt), k, axis=1)  # [G,TK]
        onehot = onehot * valid_flat[..., None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - onehot                     # prior count
    pos = jnp.take_along_axis(pos, flat_i[..., None], axis=2)[..., 0]  # [G,TK]
    if valid is None:
        keep = pos < cap
    else:
        cap_v = _valid_cap(valid.reshape(g, gt).sum(axis=1), cap, cfg)
        keep = valid_flat & (pos < cap_v[:, None])

    # slot tables: token index per (expert, capacity) slot
    token_ids = jnp.tile(jnp.arange(gt, dtype=jnp.int32)[:, None], (1, k)) \
        .reshape(gt * k)
    slot_tok = jnp.full((g, e, cap), gt, jnp.int32)   # gt = "no token" sentinel

    def fill(slot, fi, p, kp, tid):
        fi = jnp.where(kp, fi, e)       # overflow -> dropped via index clip
        p = jnp.where(kp, p, cap)
        return slot.at[fi, p].set(tid, mode="drop")

    slot_tok = jax.vmap(fill)(slot_tok, flat_i, pos, keep,
                              jnp.broadcast_to(token_ids, (g, gt * k)))

    # gather tokens into expert slots ([G,E,C,d]); sentinel rows read zeros
    xg_pad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    expert_in = jnp.take_along_axis(
        xg_pad[:, None, :, :],                       # [G,1,T+1,d]
        slot_tok[..., None].clip(0, gt),             # [G,E,C,1]
        axis=2)                                      # [G,E,C,d]
    expert_in = shard(expert_in, "batch", "experts", None, "embed")

    # batched expert GEMMs (EP: expert dim on 'model')
    h = jnp.einsum("gecd,edm->gecm", expert_in, params["w_gate"])
    u = jnp.einsum("gecd,edm->gecm", expert_in, params["w_up"])
    act = jax.nn.silu(h) * u
    expert_out = jnp.einsum("gecm,emd->gecd", act, params["w_down"])
    expert_out = shard(expert_out, "batch", "experts", None, "embed")

    # combine: gather each assignment's slot output, weight, sum over k
    flat_pos = pos.reshape(g, gt, k)
    flat_keep = keep.reshape(g, gt, k)
    gather_idx = (top_i * cap + flat_pos).clip(0, e * cap - 1)    # [G,T,K]
    eo_flat = expert_out.reshape(g, e * cap, d)
    picked = jnp.take_along_axis(
        eo_flat[:, None, :, :],                      # [G,1,EC,d]
        gather_idx[..., None],                       # [G,T,K,1]
        axis=2)                                      # [G,T,K,d]
    w = (top_p * flat_keep).astype(picked.dtype)[..., None]
    routed = (picked * w).sum(axis=2)                # [G,T,d]
    out = routed

    if "shared" in params:
        sh = params["shared"]
        hs = jax.nn.silu(xg @ sh["w_gate"]) * (xg @ sh["w_up"])
        out = out + hs @ sh["w_down"]

    out = out.reshape(b, s, d)
    if not return_aux:
        return out
    # load-balance aux loss (Switch style): E * sum_e f_e * p_e
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_i[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    mean_probs = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * mean_probs)
    return out, aux


def moe_reference(params: dict, x: jax.Array, cfg) -> jax.Array:
    """Oracle: per-token loop over selected experts (no capacity drops when
    capacity is ample).  Used by tests only."""
    b, s, d = x.shape
    probs = jax.nn.softmax(x.astype(jnp.float32) @ params["router"], axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(x)
    for kk in range(cfg.top_k):
        idx = top_i[..., kk]                                  # [B,S]
        wg = params["w_gate"][idx]                            # [B,S,d,m]
        wu = params["w_up"][idx]
        wd = params["w_down"][idx]
        h = jax.nn.silu(jnp.einsum("bsd,bsdm->bsm", x, wg)) * \
            jnp.einsum("bsd,bsdm->bsm", x, wu)
        y = jnp.einsum("bsm,bsmd->bsd", h, wd)
        out = out + y * top_p[..., kk][..., None].astype(x.dtype)
    if "shared" in params:
        sh = params["shared"]
        hs = jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])
        out = out + hs @ sh["w_down"]
    return out


# ---------------------------------------------------------------------------
# Expert-parallel dispatch via shard_map (beyond-paper optimization)
# ---------------------------------------------------------------------------
#
# The gather/scatter dispatch above is correct but GSPMD partitions it into
# all-reduces of full activation tensors (measured: 349 GB/chip/step on
# deepseek-moe train_4k — the collective-bound cell).  This version pins the
# communication pattern explicitly: tokens stay sharded over the data axes,
# experts over 'model'; each device runs only its local experts over its
# local tokens and ONE psum over 'model' combines the top-k contributions —
# the minimal EP collective (activation-sized, not dispatch-table-sized).


def _moe_local(router, w_gate, w_up, w_down, x_loc, valid_loc, *, cfg,
               e_local, axis_name):
    """Per-shard body: x_loc [B_loc, S, d]; valid_loc [B_loc, S] bool;
    w_* [E_local, d, m]."""
    b, s, d = x_loc.shape
    k = cfg.top_k
    e = cfg.num_experts
    t = b * s
    xt = x_loc.reshape(t, d)
    logits = xt.astype(jnp.float32) @ router              # full router [d, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                # global expert ids
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    shard = jax.lax.axis_index(axis_name)
    e0 = shard * e_local
    cap = max(1, math.ceil(t * k / e * cfg.capacity_factor))

    # assignments targeting LOCAL experts only (invalid/pad tokens excluded
    # from the slot count so they cannot steal capacity)
    flat_i = top_i.reshape(t * k)
    local_i = flat_i - e0                                 # [TK] in [0, e_local)
    is_local = (local_i >= 0) & (local_i < e_local)
    is_local &= jnp.repeat(valid_loc.reshape(t), k)
    onehot = jax.nn.one_hot(jnp.where(is_local, local_i, e_local),
                            e_local + 1, dtype=jnp.int32)[:, :e_local]
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(
        pos, jnp.clip(local_i, 0, e_local - 1)[:, None], axis=1)[:, 0]
    # drop threshold scales with the REAL token count (see _valid_cap)
    keep = is_local & (pos < _valid_cap(valid_loc.sum(), cap, cfg))

    token_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    slot_tok = jnp.full((e_local, cap), t, jnp.int32)
    slot_tok = slot_tok.at[
        jnp.where(keep, local_i, e_local),
        jnp.where(keep, pos, cap)].set(token_ids, mode="drop")

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    expert_in = xt_pad[slot_tok.clip(0, t)]               # [E_loc, C, d]
    h = jnp.einsum("ecd,edm->ecm", expert_in, w_gate)
    u = jnp.einsum("ecd,edm->ecm", expert_in, w_up)
    act = jax.nn.silu(h) * u
    expert_out = jnp.einsum("ecm,emd->ecd", act, w_down)  # [E_loc, C, d]

    # combine local contributions, then ONE activation psum over 'model'
    gather_idx = (jnp.clip(local_i, 0, e_local - 1) * cap
                  + jnp.clip(pos, 0, cap - 1))
    picked = expert_out.reshape(e_local * cap, d)[gather_idx]   # [TK, d]
    w = (top_p.reshape(t * k) * keep).astype(picked.dtype)
    routed = jnp.zeros((t, d), picked.dtype).at[token_ids].add(
        picked * w[:, None])
    routed = jax.lax.psum(routed, axis_name)
    return routed.reshape(b, s, d)


def moe_apply_ep(params: dict, x: jax.Array, cfg, *, return_aux: bool = False,
                 valid: jax.Array | None = None):
    """shard_map expert-parallel MoE.  Falls back to :func:`moe_apply` when
    no mesh with a 'model' axis is active or experts don't divide it.
    ``valid`` masks pad tokens out of the capacity count (chunked prefill)."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import current_mesh, shard_map

    mesh = current_mesh()
    if (mesh is None or "model" not in mesh.axis_names
            or cfg.num_experts % mesh.shape["model"]
            or (x.shape[0] % _dp_size(mesh) and x.shape[0] != 1)):
        return moe_apply(params, x, cfg, return_aux=return_aux, valid=valid)
    e_local = cfg.num_experts // mesh.shape["model"]
    if x.shape[0] % _dp_size(mesh):
        # the serve engine's token-packed stream is one [1, P] batch row —
        # indivisible by any real data axis, but EP still pays: replicate
        # the tokens over the data axes and shard only the experts
        dp_axes: tuple = ()
    else:
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)

    if valid is None:
        valid = jnp.ones(x.shape[:2], bool)
    fn = shard_map(
        partial(_moe_local, cfg=cfg, e_local=e_local, axis_name="model"),
        mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), P(batch_spec, None, None),
                  P(batch_spec, None)),
        out_specs=P(batch_spec, None, None),
    )
    out = fn(params["router"], params["w_gate"], params["w_up"],
             params["w_down"], x, valid)

    if "shared" in params:
        sh = params["shared"]
        hs = jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])
        hs = shard(hs, "batch", "seq", "ff")
        out = out + hs @ sh["w_down"]
    if not return_aux:
        return out
    # aux load-balance loss computed on the (cheap, replicated) router pass
    probs = jax.nn.softmax(x.astype(jnp.float32) @ params["router"], axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.num_experts, dtype=jnp.float32),
                    axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(frac * probs.mean(axis=(0, 1)))
    return out, aux


def _dp_size(mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def _moe_local_serve(router, w_gate, w_up, w_down, x_loc, valid_loc, *, cfg,
                     e_local, dp_axes):
    """Decode-path shard body: expert weights stay RESIDENT, 2D-sharded
    (experts x moe_ff); the (few) decode tokens are all-gathered instead.
    Collectives per layer = O(tokens * d), not O(weights)."""
    b_loc, s, d = x_loc.shape
    k = cfg.top_k
    e = cfg.num_experts
    # gather the token batch over the data axes (tiny at decode)
    x_all = x_loc
    valid_all = valid_loc
    for ax in dp_axes:
        x_all = jax.lax.all_gather(x_all, ax, axis=0, tiled=True)
        valid_all = jax.lax.all_gather(valid_all, ax, axis=0, tiled=True)
    t = x_all.shape[0] * s
    xt = x_all.reshape(t, d)
    logits = xt.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    shard_idx = jax.lax.axis_index("model")
    e0 = shard_idx * e_local
    cap = max(1, math.ceil(t * k / e * cfg.capacity_factor))

    # inactive-slot tokens are excluded from the capacity count, and the
    # drop threshold scales with the REAL token count (see moe_apply)
    flat_i = top_i.reshape(t * k)
    local_i = flat_i - e0
    is_local = (local_i >= 0) & (local_i < e_local)
    is_local &= jnp.repeat(valid_all.reshape(t), k)
    onehot = jax.nn.one_hot(jnp.where(is_local, local_i, e_local),
                            e_local + 1, dtype=jnp.int32)[:, :e_local]
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(
        pos, jnp.clip(local_i, 0, e_local - 1)[:, None], axis=1)[:, 0]
    keep = is_local & (pos < _valid_cap(valid_all.sum(), cap, cfg))

    token_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    slot_tok = jnp.full((e_local, cap), t, jnp.int32)
    slot_tok = slot_tok.at[
        jnp.where(keep, local_i, e_local),
        jnp.where(keep, pos, cap)].set(token_ids, mode="drop")

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    expert_in = xt_pad[slot_tok.clip(0, t)]               # [E_loc, C, d]
    h = jnp.einsum("ecd,edm->ecm", expert_in, w_gate)     # m = local slice
    u = jnp.einsum("ecd,edm->ecm", expert_in, w_up)
    act = jax.nn.silu(h) * u
    expert_out = jnp.einsum("ecm,emd->ecd", act, w_down)  # partial over m

    gather_idx = (jnp.clip(local_i, 0, e_local - 1) * cap
                  + jnp.clip(pos, 0, cap - 1))
    picked = expert_out.reshape(e_local * cap, d)[gather_idx]
    w = (top_p.reshape(t * k) * keep).astype(picked.dtype)
    routed = jnp.zeros((t, d), picked.dtype).at[token_ids].add(
        picked * w[:, None])
    # sum m-partials over data AND expert contributions over model
    routed = jax.lax.psum(routed, ("model",) + tuple(dp_axes))
    # slice back this shard's batch
    didx = jnp.zeros((), jnp.int32)
    mult = 1
    for ax in reversed(dp_axes):
        didx = didx + jax.lax.axis_index(ax) * mult
        mult = mult * jax.lax.psum(1, ax)
    start = didx * b_loc
    routed = jax.lax.dynamic_slice_in_dim(routed.reshape(x_all.shape[0], s, d),
                                          start, b_loc, axis=0)
    return routed


def moe_apply_ep_serve(params: dict, x: jax.Array, cfg,
                       valid: jax.Array | None = None):
    """Decode-time EP: resident weights, token gather (see _moe_local_serve).
    ``valid`` ([B, S] bool) masks inactive decode slots out of the capacity
    count so a free slot's stale token can't steal an expert slot."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import current_mesh, shard_map

    mesh = current_mesh()
    dp_axes = tuple(a for a in ("pod", "data") if a in (mesh.axis_names if mesh else ()))
    if (mesh is None or "model" not in mesh.axis_names
            or cfg.num_experts % mesh.shape["model"]
            or cfg.moe_d_ff % _dp_size(mesh)
            or x.shape[0] % _dp_size(mesh)):
        return moe_apply(params, x, cfg, valid=valid)
    e_local = cfg.num_experts // mesh.shape["model"]
    batch_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    dspec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)

    if valid is None:
        valid = jnp.ones(x.shape[:2], bool)
    fn = shard_map(
        partial(_moe_local_serve, cfg=cfg, e_local=e_local, dp_axes=dp_axes),
        mesh=mesh,
        in_specs=(P(), P("model", None, dspec), P("model", None, dspec),
                  P("model", dspec, None), P(batch_spec, None, None),
                  P(batch_spec, None)),
        out_specs=P(batch_spec, None, None),
    )
    out = fn(params["router"], params["w_gate"], params["w_up"],
             params["w_down"], x, valid)
    if "shared" in params:
        sh = params["shared"]
        hs = jax.nn.silu(x @ sh["w_gate"]) * (x @ sh["w_up"])
        out = out + hs @ sh["w_down"]
    return out
