"""The model: decoder-only LM (all families) + encoder-decoder (whisper).

Layer stacking uses a **group scan**: the repeating ``block_pattern`` (e.g.
gemma3's 5 local + 1 global, recurrentgemma's rglru/rglru/attn) becomes one
scan body with per-slot static code; parameters are stacked across groups so
the HLO is O(pattern), not O(num_layers).  ``first_k_dense`` prefix layers
and the pattern remainder are unrolled explicitly.

Public entry points (all pure):
    init(cfg, key)                      -> (params, axes)
    forward(cfg, params, batch)         -> logits | hidden
    loss_fn(cfg, params, batch)         -> (loss, aux)     [chunked CE]
    prefill(cfg, params, batch, cache_len) -> (last_logits, caches)
    prefill_chunk(cfg, params, caches, tokens, start, lengths)
                                        -> (last_logits, caches)  [in-place]
    step_packed(cfg, params, caches, tokens, slot_id, pos, start, seg_len)
                                        -> (last_logits, caches)  [in-place;
                                        one ragged stream of prefill chunks
                                        + length-1 decode segments]
    step_spec(cfg, params, caches, tokens, slot_id, pos, start, seg_len,
              spec_rows, spec_idx, draft_len)
                                        -> (accept, toks, caches) [packed
                                        stream whose decode segments carry
                                        length-(1+d) speculative drafts;
                                        greedy acceptance computed in-graph]
    decode_step(cfg, params, caches, token, pos) -> (logits, caches)
    init_cache(cfg, batch, cache_len)   -> caches
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import A, Axes, shard
from . import blocks as B
from .layers import _dense_init, apply_norm, norm_init, attention
from . import layers

LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# structure helpers
# ---------------------------------------------------------------------------


def _plan(cfg):
    """(prefix_kinds, pattern, n_groups, remainder_kinds) for the decoder."""
    pattern = tuple(cfg.block_pattern)
    n_prefix = cfg.first_k_dense
    n_rest = cfg.num_layers - n_prefix
    n_groups, rem = divmod(n_rest, len(pattern))
    prefix = tuple(_strip_moe(pattern[i % len(pattern)]) for i in range(n_prefix))
    return prefix, pattern, n_groups, pattern[:rem]


def _strip_moe(kind: str) -> str:
    base, _ = B.split_kind(kind)
    return base


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(cfg, key) -> tuple[dict, dict]:
    prefix, pattern, n_groups, rem = _plan(cfg)
    keys = jax.random.split(key, 8)
    params: dict = {}
    axes: dict = {}

    params["embed"] = _dense_init(keys[0], (cfg.vocab_size, cfg.d_model), cfg.dtype)
    axes["embed"] = A("vocab", "embed")
    if not cfg.tie_embeddings:
        params["head"] = _dense_init(keys[1], (cfg.d_model, cfg.vocab_size), cfg.dtype)
        axes["head"] = A("embed", "vocab")
    params["ln_f"], axes["ln_f"] = norm_init(cfg.norm, cfg.d_model, cfg.dtype)

    if cfg.frontend == "vision":
        k1, k2 = jax.random.split(keys[2])
        params["connector"] = {
            "w1": _dense_init(k1, (cfg.frontend_dim, cfg.d_model), cfg.dtype),
            "w2": _dense_init(k2, (cfg.d_model, cfg.d_model), cfg.dtype),
        }
        axes["connector"] = {"w1": A(None, "embed"), "w2": A("embed", "embed")}
    if cfg.encoder_decoder:
        # learned absolute positions (whisper)
        max_pos = 65536
        params["pos_emb"] = jnp.zeros((max_pos, cfg.d_model), cfg.dtype)
        axes["pos_emb"] = A(None, "embed")

    def stack_axes(ax_tree):
        # stacked params gain a leading layer/group dim: unsharded
        return jax.tree.map(
            lambda ax: A(None, *ax.names), ax_tree,
            is_leaf=lambda x: isinstance(x, Axes))

    def stack_init(kinds, key, n_copies=1, *, stack=False):
        ps, axs = [], None
        for i in range(n_copies):
            kp, key = jax.random.split(key)
            group_p, group_a = [], []
            for j, kind in enumerate(kinds):
                kj, kp = jax.random.split(kp)
                p, a = B.block_init(kj, cfg, kind)
                group_p.append(p)
                group_a.append(a)
            ps.append(group_p)
            axs = group_a
        if not stack:
            return ps[0], axs
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
        return stacked, axs

    if prefix:
        params["prefix"], axes["prefix"] = stack_init(prefix, keys[3])
    if n_groups:
        params["groups"], ga = stack_init(pattern, keys[4], n_groups,
                                          stack=True)
        axes["groups"] = stack_axes(ga)
    if rem:
        params["rem"], axes["rem"] = stack_init(rem, keys[5])

    if cfg.encoder_decoder:
        enc_p, enc_a = [], None
        kp = keys[6]
        for _ in range(cfg.enc_layers):
            kj, kp = jax.random.split(kp)
            p, a = B.block_init(kj, cfg, "bidir")
            enc_p.append(p)
            enc_a = a
        params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc_p)
        axes["encoder"] = stack_axes(enc_a)
        params["ln_enc"], axes["ln_enc"] = norm_init(cfg.norm, cfg.d_model, cfg.dtype)
        # cross attention per decoder layer (stacked over ALL layers)
        xp, xa = [], None
        for _ in range(cfg.num_layers):
            kj, kp = jax.random.split(kp)
            p, a = layers.attention_init(kj, cfg)
            ln, lna = norm_init(cfg.norm, cfg.d_model, cfg.dtype)
            xp.append({"attn": p, "ln": ln})
            xa = {"attn": a, "ln": lna}
        params["cross"] = jax.tree.map(lambda *xs: jnp.stack(xs), *xp)
        axes["cross"] = stack_axes(xa)
    return params, axes


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _embed_tokens(cfg, params, tokens):
    x = params["embed"][tokens]
    return shard(x, "batch", "seq", "embed")


def _logits(cfg, params, x):
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return shard(logits, "batch", "seq", "vocab")


def _inputs_embeds(cfg, params, batch):
    """Token embeddings, with modality prefixes where configured.
    Returns (x [B,S,d], positions [S])."""
    tokens = batch["tokens"]
    x = _embed_tokens(cfg, params, tokens)
    if cfg.frontend == "vision":
        p = batch["patches"]                       # [B,P,frontend_dim]
        c = params["connector"]
        pe = jax.nn.gelu(p.astype(cfg.dtype) @ c["w1"]) @ c["w2"]
        x = jnp.concatenate([pe, x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    if "pos_emb" in params and not cfg.encoder_decoder:
        x = x + params["pos_emb"][positions]
    return x, positions


# ---------------------------------------------------------------------------
# decoder trunk (full-seq)
# ---------------------------------------------------------------------------


def _run_blocks_seq(cfg, params, x, positions, *, enc_out=None, caches=None,
                    remat: str = "none"):
    """Runs prefix -> scanned groups -> remainder.  caches=None for training;
    otherwise a cache pytree from init_cache to be filled (prefill)."""
    prefix, pattern, n_groups, rem = _plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    layer_idx = 0

    def maybe_cross(x, li):
        if enc_out is None:
            return x
        cp = jax.tree.map(lambda t: t[li], params["cross"])
        h = apply_norm(cfg.norm, cp["ln"], x)
        q = jnp.einsum("bsd,dhk->bshk", h, cp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wv"])
        kp = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        qp = jnp.arange(x.shape[1], dtype=jnp.int32)
        o = attention(q, k, v, q_pos=qp, k_pos=kp, causal=False, window=0)
        return x + layers.attn_output(cp["attn"], o)

    # -- prefix (unrolled)
    for j, kind in enumerate(prefix):
        c = None if caches is None else caches["prefix"][j]
        x, c, aux = B.block_apply_seq(cfg, kind, params["prefix"][j], x,
                                      positions, cache=c)
        x = maybe_cross(x, layer_idx)
        if caches is not None:
            caches["prefix"][j] = c
        aux_total += aux
        layer_idx += 1

    # -- scanned groups
    if n_groups:
        group_params = params["groups"]
        has_cross = enc_out is not None

        def group_body(carry, xs):
            x, aux_in, li = carry
            gp, gc = xs
            new_caches = []
            for j, kind in enumerate(pattern):
                cj = None if gc is None else gc[j]
                x, cj, aux = B.block_apply_seq(cfg, kind, gp[j], x,
                                               positions, cache=cj)
                if has_cross:
                    # cross-attn params indexed dynamically per layer
                    cp = jax.tree.map(
                        lambda t: jax.lax.dynamic_index_in_dim(
                            t, li + j, 0, keepdims=False), params["cross"])
                    h = apply_norm(cfg.norm, cp["ln"], x)
                    q = jnp.einsum("bsd,dhk->bshk", h, cp["attn"]["wq"])
                    k = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wk"])
                    v = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wv"])
                    kp = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
                    qp = jnp.arange(x.shape[1], dtype=jnp.int32)
                    o = attention(q, k, v, q_pos=qp, k_pos=kp, causal=False,
                                  window=0)
                    x = x + layers.attn_output(cp["attn"], o)
                new_caches.append(cj)
                aux_in = aux_in + aux
            ys = new_caches if gc is not None else None
            return (x, aux_in, li + len(pattern)), ys

        body = group_body
        if remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if remat == "dots" else None)
            body = jax.checkpoint(group_body, policy=policy,
                                  prevent_cse=False)

        gcaches = None if caches is None else caches["groups"]
        (x, aux_total, layer_idx), group_caches_out = jax.lax.scan(
            body, (x, aux_total, jnp.asarray(layer_idx, jnp.int32)),
            (group_params, gcaches))
        if caches is not None:
            caches["groups"] = group_caches_out

    # -- remainder (unrolled)
    for j, kind in enumerate(rem):
        c = None if caches is None else caches["rem"][j]
        x, c, aux = B.block_apply_seq(cfg, kind, params["rem"][j], x,
                                      positions, cache=c)
        x = maybe_cross(x, layer_idx)
        if caches is not None:
            caches["rem"][j] = c
        aux_total += aux
        layer_idx += 1

    return x, caches, aux_total


def _run_encoder(cfg, params, frames):
    """whisper encoder over precomputed frame embeddings [B,Se,d]."""
    x = frames.astype(cfg.dtype)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    if "pos_emb" in params:
        x = x + params["pos_emb"][positions]

    def body(x, lp):
        x, _, _ = B.block_apply_seq(cfg, "bidir", lp, x, positions)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return apply_norm(cfg.norm, params["ln_enc"], x)


# ---------------------------------------------------------------------------
# public: forward / loss
# ---------------------------------------------------------------------------


def forward(cfg, params, batch, *, remat: str = "none"):
    enc_out = None
    if cfg.encoder_decoder:
        enc_out = _run_encoder(cfg, params, batch["frames"])
    x, positions = _inputs_embeds(cfg, params, batch)
    if "pos_emb" in params and cfg.encoder_decoder:
        x = x + params["pos_emb"][positions]
    x, _, aux = _run_blocks_seq(cfg, params, x, positions, enc_out=enc_out,
                                remat=remat)
    x = apply_norm(cfg.norm, params["ln_f"], x)
    return x, aux


def loss_fn(cfg, params, batch, *, remat: str = "dots",
            aux_weight: float = 0.01):
    """Chunked cross-entropy: the [B,S,V] logits tensor never materializes
    (decisive for 262k-vocab gemma3 at 1M tokens)."""
    x, aux = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vision":               # prefix positions carry no loss
        x = x[:, -labels.shape[1]:]
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    b, s, d = x.shape
    chunk = min(LOSS_CHUNK, s)
    while s % chunk:
        chunk -= 1
    n = s // chunk
    xc = jnp.moveaxis(x.reshape(b, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)

    @partial(jax.checkpoint, prevent_cse=False)
    def chunk_loss(x_i, l_i):
        logits = jnp.einsum("bsd,dv->bsv", x_i, head).astype(jnp.float32)
        logits = shard(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_i[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    def body(acc, xs):
        x_i, l_i = xs
        return acc + chunk_loss(x_i, l_i), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    loss = total / (b * s)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# public: serving
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, cache_len: int, ring_margin: int = 0):
    """``ring_margin`` widens windowed (swa/local) rings past ``cfg.window``
    — required when speculative drafts write up to ``k`` rejected positions
    past the pending token (see :func:`blocks.cache_len_for`)."""
    prefix, pattern, n_groups, rem = _plan(cfg)
    caches = {}
    if prefix:
        caches["prefix"] = [B.block_cache_init(cfg, k, batch, cache_len,
                                               ring_margin=ring_margin)
                            for k in prefix]
    if n_groups:
        group = [B.block_cache_init(cfg, k, batch, cache_len,
                                    ring_margin=ring_margin) for k in pattern]
        caches["groups"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(), group)
    if rem:
        caches["rem"] = [B.block_cache_init(cfg, k, batch, cache_len,
                                            ring_margin=ring_margin)
                         for k in rem]
    if cfg.encoder_decoder:
        caches["enc_out"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), cfg.dtype)
    return caches


def prefill(cfg, params, batch, *, cache_len: int):
    tokens = batch["tokens"]
    enc_out = None
    if cfg.encoder_decoder:
        enc_out = _run_encoder(cfg, params, batch["frames"])
    x, positions = _inputs_embeds(cfg, params, batch)
    if "pos_emb" in params and cfg.encoder_decoder:
        x = x + params["pos_emb"][positions]
    caches = init_cache(cfg, tokens.shape[0], cache_len)
    if cfg.encoder_decoder:
        caches["enc_out"] = enc_out
    x, caches, _ = _run_blocks_seq(cfg, params, x, positions, enc_out=enc_out,
                                   caches=caches)
    x = apply_norm(cfg.norm, params["ln_f"], x)
    logits = _logits(cfg, params, x[:, -1:, :])[:, 0]
    return logits, caches


def _all_kinds(cfg) -> set:
    return set(cfg.block_pattern) | {k for k in (_plan(cfg)[0] or ())}


def supports_chunked_prefill(cfg) -> bool:
    """Chunked/bucketed (padded) prefill needs every block to either be
    position-maskable (attention kinds) or to thread scan state across chunk
    boundaries through the state-in/state-out kernel variants (rwkv6/rglru,
    with pads neutralized); MoE routing is pad-aware, so MoE archs qualify
    too.  Only the vision/encoder-decoder frontends — whose unpadded
    modality prefixes have no chunk representation — keep the exact one-shot
    path, and requesting chunked prefill for them raises."""
    if cfg.encoder_decoder or cfg.frontend == "vision":
        return False
    return all(B.split_kind(k)[0] in B.CHUNKABLE_KINDS
               for k in _all_kinds(cfg))


def supports_paged_kv(cfg) -> bool:
    """Paged KV (block-table cache + paged decode kernel) needs every block
    to be a dense-attention kind (MoE FFNs are fine — only the attention
    K/V is paged) and prefill to go through the chunked path (the one-shot
    legacy prefill builds a dense per-slot cache with no paged equivalent).
    Recurrent blocks carry O(1) state — nothing to page — so rwkv6/rglru
    archs serve chunked prefill from the dense per-slot cache instead."""
    if not supports_chunked_prefill(cfg):
        return False
    return all(B.split_kind(k)[0] in B.ATTN_KINDS for k in _all_kinds(cfg))


def init_paged_cache(cfg, num_blocks: int, block_tokens: int):
    """Per-layer physical block stores ``[num_blocks, Kv, T, D]`` replacing
    the dense per-slot cache (structure mirrors :func:`init_cache`)."""
    if not supports_paged_kv(cfg):
        raise ValueError(f"{cfg.name}: block pattern {cfg.block_pattern} "
                         "does not support paged KV")
    prefix, pattern, n_groups, rem = _plan(cfg)
    caches = {}
    if prefix:
        caches["prefix"] = [B.paged_cache_init(cfg, k, num_blocks, block_tokens)
                            for k in prefix]
    if n_groups:
        group = [B.paged_cache_init(cfg, k, num_blocks, block_tokens)
                 for k in pattern]
        caches["groups"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(), group)
    if rem:
        caches["rem"] = [B.paged_cache_init(cfg, k, num_blocks, block_tokens)
                         for k in rem]
    return caches


def map_paged_caches(caches, fn):
    """Apply ``fn(array, block_axis)`` to every store plane of a paged cache
    tree (block axis 0 for prefix/rem layers, 1 for the group-stacked ones).
    Used by the engine to physically resize the block store when
    ``serve.kv_block_budget`` moves."""
    out = dict(caches)
    if "prefix" in caches:
        out["prefix"] = [{n: fn(a, 0) for n, a in c.items()}
                         for c in caches["prefix"]]
    if "groups" in caches:
        out["groups"] = [jax.tree.map(lambda a: fn(a, 1), c)
                         for c in caches["groups"]]
    if "rem" in caches:
        out["rem"] = [{n: fn(a, 0) for n, a in c.items()}
                      for c in caches["rem"]]
    return out


def copy_paged_blocks(caches, src, dst):
    """Block-level copy-on-write across every layer of a paged cache tree:
    physical blocks ``src[i] -> dst[i]`` in each store plane (the engine
    jits this with cache donation and applies it before a lease's first
    write into a shared block — see ``KVLease.writable``)."""
    out = dict(caches)
    if "prefix" in caches:
        out["prefix"] = [B.paged_copy_blocks(c, src, dst)
                         for c in caches["prefix"]]
    if "groups" in caches:
        out["groups"] = [B.paged_copy_blocks(c, src, dst, block_axis=1)
                         for c in caches["groups"]]
    if "rem" in caches:
        out["rem"] = [B.paged_copy_blocks(c, src, dst)
                      for c in caches["rem"]]
    return out


def prefill_chunk(cfg, params, caches, tokens, start, lengths,
                  block_tables=None):
    """Advance prefill by one padded chunk per batch row, in place.

    tokens: [B,C] int32 (row-wise left-aligned, zero-padded); start: [B]
    absolute position of each row's first chunk token; lengths: [B] valid
    tokens this chunk (0 = inactive row: no cache/state writes, garbage
    logits).  ``block_tables`` ([B,M] int32, optional) switches the
    attention caches to paged block stores.  Returns (next-token logits
    [B,V] at each row's last valid position, caches).  Attention chunks
    attend to prior chunks through the cache; recurrent blocks thread their
    scan state across the boundary (state-in/state-out kernels, pads
    neutralized); MoE routing is ``valid``-aware — so calling this
    repeatedly over a long prompt is exact chunked prefill for every
    supported family."""
    if not supports_chunked_prefill(cfg):
        raise ValueError(f"{cfg.name}: block pattern {cfg.block_pattern} "
                         "does not support chunked prefill")
    prefix, pattern, n_groups, rem = _plan(cfg)
    b, c = tokens.shape
    pos = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]   # [B,C]
    valid = jnp.arange(c, dtype=jnp.int32)[None, :] < lengths[:, None]
    x = params["embed"][tokens]

    for j, kind in enumerate(prefix):
        x, caches["prefix"][j], _ = B.block_apply_chunk(
            cfg, kind, params["prefix"][j], x, pos, valid,
            caches["prefix"][j], block_tables=block_tables)

    if n_groups:
        def group_body(x, xs):
            gp, gc = xs
            new_c = []
            for j, kind in enumerate(pattern):
                x, cj, _ = B.block_apply_chunk(cfg, kind, gp[j], x, pos,
                                               valid, gc[j],
                                               block_tables=block_tables)
                new_c.append(cj)
            return x, new_c

        x, new_groups = jax.lax.scan(
            group_body, x, (params["groups"], caches["groups"]))
        caches["groups"] = new_groups

    for j, kind in enumerate(rem):
        x, caches["rem"][j], _ = B.block_apply_chunk(
            cfg, kind, params["rem"][j], x, pos, valid, caches["rem"][j],
            block_tables=block_tables)

    x = apply_norm(cfg.norm, params["ln_f"], x)
    last = jnp.clip(lengths - 1, 0, c - 1)
    xl = x[jnp.arange(b), last][:, None, :]                  # [B,1,d]
    logits = _logits(cfg, params, xl)[:, 0]
    return logits, caches


def step_packed(cfg, params, caches, tokens, slot_id, pos, start, seg_len,
                block_tables=None):
    """Advance the engine by ONE token-packed ragged stream, in place —
    prefill chunks AND decode tokens ride the same call (unified ticks).

    tokens: [1,P] int32 — a single flat stream packing contiguous segments
    from up to B requests back-to-back: a prefilling request contributes
    its next prompt chunk, a running request contributes its one decode
    token as a length-1 segment (no per-slot padding, no separate decode
    dispatch); slot_id: [P] owning slot per token (-1 = dead pad); pos: [P]
    absolute position of each token within its own request; start/seg_len:
    [B] per-slot segment start and token count this call (the segment
    boundaries, cu_seqlens-style; a decode segment has ``start == its
    current position`` and ``seg_len == 1``).  ``block_tables`` ([B,M]
    int32, optional) routes attention K/V through the paged block store
    with a per-token scatter.  Returns (next-token logits [B,V] at each
    slot's last packed token — garbage for slots with no tokens this call —
    and the updated caches), so the caller samples every segment that
    completed a row this tick: prefill-finishers and decoders alike.

    Attention masks by segment id (:func:`~repro.models.layers
    .segment_attention`, the fused Pallas kernel family), so no token
    attends across requests — a length-1 decode segment sees exactly its
    own slot's history plus itself, which is the decode-attention
    predicate; recurrent blocks scatter the stream to the per-slot chunk
    layout and thread scan state through the state-in/state-out kernels (a
    length-1 segment is one scan step); MoE routes with the packed
    ``valid`` mask.  Calling this repeatedly over a workload is exact
    chunked prefill + decode for every supported family, with a jit cache
    of O(1) entries (one packed shape) instead of one per padded bucket
    plus a decode program."""
    if not supports_chunked_prefill(cfg):
        raise ValueError(f"{cfg.name}: block pattern {cfg.block_pattern} "
                         "does not support packed prefill")
    prefix, pattern, n_groups, rem = _plan(cfg)
    x = params["embed"][tokens]

    for j, kind in enumerate(prefix):
        x, caches["prefix"][j], _ = B.block_apply_packed(
            cfg, kind, params["prefix"][j], x, pos, slot_id, start, seg_len,
            caches["prefix"][j], block_tables=block_tables)

    if n_groups:
        def group_body(x, xs):
            gp, gc = xs
            new_c = []
            for j, kind in enumerate(pattern):
                x, cj, _ = B.block_apply_packed(cfg, kind, gp[j], x, pos,
                                                slot_id, start, seg_len,
                                                gc[j],
                                                block_tables=block_tables)
                new_c.append(cj)
            return x, new_c

        x, new_groups = jax.lax.scan(
            group_body, x, (params["groups"], caches["groups"]))
        caches["groups"] = new_groups

    for j, kind in enumerate(rem):
        x, caches["rem"][j], _ = B.block_apply_packed(
            cfg, kind, params["rem"][j], x, pos, slot_id, start, seg_len,
            caches["rem"][j], block_tables=block_tables)

    x = apply_norm(cfg.norm, params["ln_f"], x)
    nslots = start.shape[0]
    t_idx = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    last_idx = jnp.max(
        jnp.where(slot_id[None, :]
                  == jnp.arange(nslots, dtype=jnp.int32)[:, None],
                  t_idx[None, :], -1), axis=1)                   # [B]
    xl = x[0, jnp.clip(last_idx, 0)][:, None, :]                 # [B,1,d]
    logits = _logits(cfg, params, xl)[:, 0]
    return logits, caches


# prefill-only packed streams are the decode-segment-free special case
prefill_packed = step_packed


def _is_pending(c) -> bool:
    return isinstance(c, dict) and "spec_stack" in c


def _resolve_pending(c, accept, spec_rows, *, grouped: bool):
    """Select the post-acceptance recurrent snapshot per spec row.

    ``spec_stack`` leaves are [L,B,...] (or [G,L,B,...] for scanned
    groups): snapshot ``j`` is the state after consuming offsets ``0..j``
    of the spec segment, so ``accept[b]`` names exactly the state after
    the last *emitted-and-consumed* token.  Non-spec rows keep the
    full-chunk result."""
    def pick(stack, full):
        if grouped:
            l = stack.shape[1]
            idx = jnp.clip(accept, 0, l - 1).reshape(
                (1, 1, -1) + (1,) * (stack.ndim - 3))
            sel = jnp.take_along_axis(stack, idx, axis=1)[:, 0]
            m = spec_rows.reshape((1, -1) + (1,) * (sel.ndim - 2))
        else:
            l = stack.shape[0]
            idx = jnp.clip(accept, 0, l - 1).reshape(
                (1, -1) + (1,) * (stack.ndim - 2))
            sel = jnp.take_along_axis(stack, idx, axis=0)[0]
            m = spec_rows.reshape((-1,) + (1,) * (sel.ndim - 1))
        return jnp.where(m, sel.astype(full.dtype), full)

    return jax.tree.map(pick, c["spec_stack"], c["spec_full"])


def step_spec(cfg, params, caches, tokens, slot_id, pos, start, seg_len,
              spec_rows, spec_idx, draft_len, block_tables=None):
    """One packed stream whose decode segments carry speculative drafts.

    Layout is :func:`step_packed`'s, except a running slot's segment is
    ``[pending, d1..dd]`` (length ``1 + d``, ``start = pos``): the pending
    token — the slot's last sampled, not-yet-consumed token — followed by
    ``d`` drafted continuations.  Extra inputs: spec_rows [B] bool marks
    draft-carrying rows; spec_idx [B, L] stream index of each segment
    offset (rows with shorter segments repeat their last index — masked by
    draft_len); draft_len [B] drafted tokens per row (0 for prefill rows,
    whose spec_idx[:, 0] names their last packed prompt token).

    Verification is the per-offset argmax over the SAME dispatch:
    ``m[b, j]`` is the model's next token after consuming offsets
    ``0..j``.  Greedy acceptance keeps the longest prefix of drafts that
    match: ``accept[b] = #{j >= 1 : drafts[1..j] all equal m[..j-1]}`` —
    the emitted tokens ``m[b, 0..accept[b]]`` are exactly what ``accept+1``
    sequential non-speculative steps would have produced, so speculation
    is token-identical by construction.  Returns (accept [B] int32,
    toks [B, L] int32 per-offset argmaxes, caches): the caller emits
    ``toks[b, :accept[b]+1]`` and re-bases the slot at
    ``start + accept + 1``.

    Rejected-suffix K/V needs no undo: dense entries at/after the next
    tick's ``start`` are position-masked as stale, paged entries are
    overwritten before the gather (write-then-gather) and causally hidden
    past the new frontier.  Recurrent state IS rolled back — spec rows
    advance through per-offset snapshots and the ``accept``-selected
    snapshot is written back here (:func:`blocks.block_apply_spec`)."""
    if not supports_chunked_prefill(cfg):
        raise ValueError(f"{cfg.name}: block pattern {cfg.block_pattern} "
                         "does not support packed prefill")
    prefix, pattern, n_groups, rem = _plan(cfg)
    l_max = spec_idx.shape[1]
    x = params["embed"][tokens]

    for j, kind in enumerate(prefix):
        x, caches["prefix"][j], _ = B.block_apply_spec(
            cfg, kind, params["prefix"][j], x, pos, slot_id, start, seg_len,
            spec_rows, l_max, caches["prefix"][j],
            block_tables=block_tables)

    if n_groups:
        def group_body(x, xs):
            gp, gc = xs
            new_c = []
            for j, kind in enumerate(pattern):
                x, cj, _ = B.block_apply_spec(cfg, kind, gp[j], x, pos,
                                              slot_id, start, seg_len,
                                              spec_rows, l_max, gc[j],
                                              block_tables=block_tables)
                new_c.append(cj)
            return x, new_c

        x, new_groups = jax.lax.scan(
            group_body, x, (params["groups"], caches["groups"]))
        caches["groups"] = new_groups

    for j, kind in enumerate(rem):
        x, caches["rem"][j], _ = B.block_apply_spec(
            cfg, kind, params["rem"][j], x, pos, slot_id, start, seg_len,
            spec_rows, l_max, caches["rem"][j], block_tables=block_tables)

    x = apply_norm(cfg.norm, params["ln_f"], x)
    xs = x[0, spec_idx]                                     # [B, L, d]
    toks = jnp.argmax(_logits(cfg, params, xs), axis=-1).astype(jnp.int32)
    drafted = tokens[0, spec_idx]                           # [B, L]
    offs = jnp.arange(1, l_max, dtype=jnp.int32)[None, :]
    match = ((drafted[:, 1:] == toks[:, :-1])
             & (offs <= draft_len[:, None]))
    accept = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)

    # recurrent pending pairs -> the accept-selected canonical state tree
    for key in ("prefix", "rem"):
        if key in caches:
            caches[key] = [
                _resolve_pending(c, accept, spec_rows, grouped=False)
                if _is_pending(c) else c for c in caches[key]]
    if "groups" in caches:
        caches["groups"] = [
            _resolve_pending(c, accept, spec_rows, grouped=True)
            if _is_pending(c) else c for c in caches["groups"]]
    return accept, toks, caches


def decode_step(cfg, params, caches, token, pos, active=None,
                block_tables=None):
    """token: [B] int32; pos: [B] absolute position.  ``active`` ([B] bool,
    optional) masks cache/state writes for non-decoding slots.
    ``block_tables`` ([B,M] int32, optional) routes attention caches through
    the paged block store + paged decode kernel.  Returns
    (logits [B,V], caches')."""
    prefix, pattern, n_groups, rem = _plan(cfg)
    x = params["embed"][token][:, None, :]                # [B,1,d]
    if "pos_emb" in params:
        x = x + params["pos_emb"][pos][:, None, :]
    enc_out = caches.get("enc_out") if cfg.encoder_decoder else None
    layer_idx = 0

    def maybe_cross(x, li):
        if enc_out is None:
            return x
        cp = jax.tree.map(lambda t: jax.lax.dynamic_index_in_dim(
            t, li, 0, keepdims=False), params["cross"])
        h = apply_norm(cfg.norm, cp["ln"], x)
        q = jnp.einsum("bsd,dhk->bshk", h, cp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wv"])
        s = jnp.einsum("bqhk,bshk->bhqs", q * (q.shape[-1] ** -0.5), _rep(k, q))
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
        o = jnp.einsum("bhqs,bshk->bqhk", p, _rep(v, q))
        return x + layers.attn_output(cp["attn"], o)

    def _rep(kv, q):
        g = q.shape[2] // kv.shape[2]
        return jnp.repeat(kv, g, axis=2) if g > 1 else kv

    for j, kind in enumerate(prefix):
        x, caches["prefix"][j], _ = B.block_apply_step(
            cfg, kind, params["prefix"][j], x, pos, caches["prefix"][j],
            active=active, block_tables=block_tables)
        x = maybe_cross(x, layer_idx)
        layer_idx += 1

    if n_groups:
        def group_body(carry, xs):
            x, li = carry
            gp, gc = xs
            new_c = []
            for j, kind in enumerate(pattern):
                x, cj, _ = B.block_apply_step(cfg, kind, gp[j], x, pos, gc[j],
                                              active=active,
                                              block_tables=block_tables)
                if enc_out is not None:
                    x = maybe_cross(x, li + j)
                new_c.append(cj)
            return (x, li + len(pattern)), new_c

        (x, layer_idx), new_groups = jax.lax.scan(
            group_body, (x, jnp.asarray(layer_idx, jnp.int32)),
            (params["groups"], caches["groups"]))
        caches["groups"] = new_groups

    for j, kind in enumerate(rem):
        x, caches["rem"][j], _ = B.block_apply_step(
            cfg, kind, params["rem"][j], x, pos, caches["rem"][j],
            active=active, block_tables=block_tables)
        x = maybe_cross(x, layer_idx)
        layer_idx += 1

    x = apply_norm(cfg.norm, params["ln_f"], x)
    logits = _logits(cfg, params, x)[:, 0]
    return logits, caches
