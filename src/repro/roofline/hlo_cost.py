"""Trip-count-aware HLO cost analyzer.

``compiled.cost_analysis()`` visits every while body ONCE (a documented
HloCostAnalysis limitation), so a scanned-layers model under-reports FLOPs by
~num_layers x.  This analyzer parses the per-device post-SPMD HLO text and
computes, per computation, recursively:

  * dot FLOPs: 2 * prod(result dims) * prod(contracted dims)
  * HBM-traffic proxy bytes: one write per instruction result + one read per
    operand use (free ops excluded), i.e. post-fusion materialized buffers
  * collective payload bytes per kind (result sizes)

then multiplies while bodies by their ``known_trip_count`` annotation
(emitted by XLA whenever the trip count is static — true for every lax.scan
here) and adds called computations (fusions, calls) where referenced.
"""

from __future__ import annotations

import re

__all__ = ["analyze_module"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|"
    r"c64|c128)\[([0-9,]*)\]")

_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(./?.*?)\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

# Ops whose operands/results genuinely travel HBM<->VMEM on TPU.  Elementwise
# chains, broadcasts and converts fuse into producer/consumer epilogues on
# TPU, so counting them (as raw cost_analysis does) wildly inflates the
# memory term; this set is the analytic-roofline byte model: matmuls,
# memory-movement ops (cache updates, gathers), reductions and collectives.
_HBM_OPS = {
    "dot", "convolution", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "reduce", "sort", "concatenate", "pad",
    "reduce-window", "select-and-scatter", "copy",
} | set(_COLLECTIVES)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class _Instr:
    __slots__ = ("name", "shape", "op", "rest")

    def __init__(self, name, shape, op, rest):
        self.name, self.shape, self.op, self.rest = name, shape, op, rest


def _parse(hlo_text: str):
    comps: dict[str, list[_Instr]] = {}
    current: list[_Instr] | None = None
    for raw in hlo_text.splitlines():
        m = _COMP_RE.match(raw)
        if m and " = " not in raw:
            current = comps.setdefault(m.group(2), [])
            continue
        if raw.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        mi = _INSTR_RE.match(raw)
        if mi:
            name, shape, op = mi.groups()
            rest = raw[mi.end():]
            current.append(_Instr(name, shape, op, rest))
    return comps


def _dot_flops(instr: _Instr, shapes: dict[str, str]) -> float:
    out = 1
    for d in _first_dims(instr.shape):
        out *= d
    m = _LHS_C_RE.search(instr.rest)
    contract = 1
    if m:
        ops = _OPERAND_RE.findall(instr.rest.split(")", 1)[0])
        if ops:
            lhs_shape = shapes.get(ops[0], "")
            dims = _first_dims(lhs_shape)
            for idx_s in m.group(1).split(","):
                if idx_s and int(idx_s) < len(dims):
                    contract *= dims[int(idx_s)]
    return 2.0 * out * contract


def analyze_module(hlo_text: str) -> dict:
    comps = _parse(hlo_text)
    memo: dict[str, dict] = {}

    def cost(name: str) -> dict:
        if name in memo:
            return memo[name]
        acc = {"flops": 0.0, "bytes": 0.0, "bytes_raw": 0.0,
               "coll_count": 0}
        for k in _COLLECTIVES:
            acc[k] = 0.0
        memo[name] = acc  # cycle guard
        instrs = comps.get(name, [])
        shapes = {i.name: i.shape for i in instrs}
        for i in instrs:
            base_op = i.op[:-6] if i.op.endswith("-start") else i.op
            if i.op.endswith("-done"):
                continue
            if base_op == "dot":
                acc["flops"] += _dot_flops(i, shapes)
            if base_op in _COLLECTIVES:
                nbytes = _shape_bytes(i.shape)
                acc[base_op] += nbytes
                acc["coll_count"] += 1
            if base_op not in _FREE_OPS:
                operands = _OPERAND_RE.findall(i.rest.split(")", 1)[0])
                nbytes = _shape_bytes(i.shape)
                for opnd in operands:
                    nbytes += _shape_bytes(shapes.get(opnd, ""))
                acc["bytes_raw"] += nbytes
                if base_op in _HBM_OPS:
                    # slice-accurate traffic: in-place update ops touch only
                    # the written/read window, not the full base buffer
                    if base_op in ("dynamic-slice", "gather"):
                        hbm = 2 * _shape_bytes(i.shape)
                    elif base_op == "dynamic-update-slice":
                        upd = shapes.get(operands[1], "") if len(operands) > 1 else ""
                        hbm = 2 * _shape_bytes(upd)
                    elif base_op == "scatter":
                        upd = shapes.get(operands[2], "") if len(operands) > 2 else ""
                        hbm = 2 * _shape_bytes(upd)
                    else:
                        hbm = nbytes
                    acc["bytes"] += hbm
            # recurse into referenced computations
            mult = 1.0
            callee = None
            if base_op == "while":
                mb = _BODY_RE.search(i.rest)
                mt = _TRIP_RE.search(i.rest)
                mult = float(mt.group(1)) if mt else 1.0
                callee = mb.group(1) if mb else None
            elif base_op in ("fusion", "call", "async-start"):
                mc = _CALLS_RE.search(i.rest) or _TO_APPLY_RE.search(i.rest)
                callee = mc.group(1) if mc else None
            elif base_op == "conditional":
                for cn in re.findall(r"(?:true_computation|false_computation|"
                                     r"branch_computations=\{)([^,}]+)",
                                     i.rest):
                    sub = cost(cn.strip().lstrip("%"))
                    for key, v in sub.items():
                        acc[key] = acc.get(key, 0) + v
            if callee is not None:
                sub = cost(callee)
                for key, v in sub.items():
                    acc[key] = acc.get(key, 0) + mult * v
        return acc

    entry = None
    header_iter = re.finditer(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
    for m in header_iter:
        entry = m.group(1)
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    out = cost(entry)
    out["collective_bytes"] = sum(out[k] for k in _COLLECTIVES)
    out["entry"] = entry
    return out


def attribute(hlo_text: str, top: int = 20) -> list[dict]:
    """Per-op attribution of bytes/flops, weighted by execution counts
    (while trip products).  Groups by (op, jax op_name metadata) — the
    profiler's view for the §Perf hypothesis loop."""
    comps = _parse(hlo_text)
    # pass 1: execution count per computation
    counts: dict[str, float] = {}
    entry = None
    for m in re.finditer(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M):
        entry = m.group(1)
    if entry is None:
        return []

    import collections
    pending = collections.deque([(entry, 1.0)])
    while pending:
        name, mult = pending.popleft()
        counts[name] = counts.get(name, 0.0) + mult
        for i in comps.get(name, []):
            base_op = i.op[:-6] if i.op.endswith("-start") else i.op
            callee, m2 = None, mult
            if base_op == "while":
                mb = _BODY_RE.search(i.rest)
                mt = _TRIP_RE.search(i.rest)
                callee = mb.group(1) if mb else None
                m2 = mult * (float(mt.group(1)) if mt else 1.0)
            elif base_op in ("fusion", "call", "async-start"):
                mc = _CALLS_RE.search(i.rest) or _TO_APPLY_RE.search(i.rest)
                callee = mc.group(1) if mc else None
            if callee is not None and callee in comps:
                pending.append((callee, m2))

    _META_RE = re.compile(r'op_name="([^"]+)"')
    agg: dict[tuple, dict] = {}
    for name, instrs in comps.items():
        cnt = counts.get(name, 0.0)
        if cnt == 0.0:
            continue
        shapes = {i.name: i.shape for i in instrs}
        for i in instrs:
            base_op = i.op[:-6] if i.op.endswith("-start") else i.op
            if i.op.endswith("-done") or base_op in _FREE_OPS:
                continue
            operands = _OPERAND_RE.findall(i.rest.split(")", 1)[0])
            if base_op in _HBM_OPS:
                if base_op in ("dynamic-slice", "gather"):
                    nbytes = 2 * _shape_bytes(i.shape)
                elif base_op == "dynamic-update-slice":
                    upd = shapes.get(operands[1], "") if len(operands) > 1 else ""
                    nbytes = 2 * _shape_bytes(upd)
                elif base_op == "scatter":
                    upd = shapes.get(operands[2], "") if len(operands) > 2 else ""
                    nbytes = 2 * _shape_bytes(upd)
                else:
                    nbytes = _shape_bytes(i.shape) + sum(
                        _shape_bytes(shapes.get(o, "")) for o in operands)
            else:
                nbytes = 0
            flops = _dot_flops(i, shapes) if base_op == "dot" else 0.0
            if nbytes == 0 and flops == 0.0:
                continue
            mm = _META_RE.search(i.rest)
            tag = mm.group(1).split("/")[-1] if mm else ""
            key = (base_op, tag)
            rec = agg.setdefault(key, {"op": base_op, "tag": tag,
                                       "bytes": 0.0, "flops": 0.0})
            rec["bytes"] += nbytes * cnt
            rec["flops"] += flops * cnt
    out = sorted(agg.values(), key=lambda r: -(r["bytes"]))
    return out[:top]
