"""Roofline-term derivation from compiled dry-run artifacts (DESIGN.md §9).

Terms (seconds, per training/serving step):
    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / ICI_link_bw

``compiled.cost_analysis()`` reports the per-device executable (post-SPMD),
so its flops/bytes are already per chip.  Collective bytes are not in
cost_analysis: :func:`parse_collectives` sums the operand/result sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in the per-device HLO.

Hardware model (TPU v5e-like, per assignment): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import re

__all__ = ["HW", "parse_collectives", "roofline_terms", "model_flops"]

HW = {
    "peak_flops": 197e12,   # bf16 per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "ici_bw": 50e9,         # bytes/s per link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all typed shapes in a (possibly tuple) shape str."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict[str, int]:
    """Bytes moved per collective kind, from per-device HLO text.

    For each collective instruction we count the *result* size (for
    all-reduce this equals the payload; for all-gather it is the gathered
    result, a standard upper proxy for link traffic)."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # result shape appears left of '= <op>('; match ' = all-gather('
        m = re.search(r"=\s*(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(", s)
        if not m:
            continue
        if m.group(2) == "-done":
            continue  # avoid double counting start/done pairs
        kind = m.group(1)
        lhs = s.split("=", 1)[0]
        nbytes = _shape_bytes(lhs)
        out[kind] += nbytes
        out["count"] += 1
    return out


def roofline_terms(cost: dict, collective_bytes: int, *, hw=HW) -> dict:
    flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / hw["peak_flops"]
    t_memory = raw_bytes / hw["hbm_bw"]
    t_collective = collective_bytes / hw["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    total = max(t_compute, t_memory, t_collective)
    terms["bound_s"] = total
    return terms


def model_flops(cfg, shape) -> float:
    """Useful-math FLOPs for the cell: 6*N*D train (N = active params),
    2*N*D for a forward-only prefill, 2*N*B for one decode step."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # one decode token per seq
