from .analysis import HW, model_flops, parse_collectives, roofline_terms

__all__ = ["HW", "model_flops", "parse_collectives", "roofline_terms"]
