"""Microbatch gradient accumulation (lax.scan over microbatches).

``train.microbatch_tokens`` is a SmartConf-managed PerfConf (DESIGN.md §4):
smaller microbatches trade step time for activation memory, so the controller
targets the per-step activation HBM budget.  Because microbatch count is a
*compile-time* knob in XLA, the controller output feeds the trainer's
re-jit boundary (quantized to divisors of the batch), not a runtime scalar.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp


def split_batch(batch: dict, n_micro: int) -> dict:
    """[B, ...] -> [n_micro, B/n_micro, ...] for every leaf."""

    def f(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} % microbatches {n_micro}"
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    return jax.tree.map(f, batch)


def accumulate_grads(loss_fn, params, batch: dict, n_micro: int):
    """Mean loss/grads over n_micro sequential microbatches.

    loss_fn(params, micro_batch) -> (loss, aux_dict)."""
    if n_micro <= 1:
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, aux, grads

    micro = split_batch(batch, n_micro)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def body(carry, mb):
        acc, loss_acc, aux_acc = carry
        (loss, aux), g = grad_fn(params, mb)
        acc = jax.tree.map(jnp.add, acc, g)
        aux_acc = jax.tree.map(jnp.add, aux_acc, aux)
        return (acc, loss_acc + loss, aux_acc), None

    zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    l0 = jnp.zeros((), jnp.float32)
    aux0 = jax.eval_shape(lambda: grad_fn(params, jax.tree.map(lambda x: x[0], micro))[0][1])
    aux0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), aux0)
    (grads, loss, aux), _ = jax.lax.scan(body, (zeros_g, l0, aux0), micro)
    inv = 1.0 / n_micro
    return (loss * inv,
            jax.tree.map(lambda a: a * inv, aux),
            jax.tree.map(lambda g: g * inv, grads))


def divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def quantize_microbatches(batch_size: int, desired: float) -> int:
    """Nearest valid microbatch count for a controller-desired value."""
    ds = divisors(batch_size)
    return min(ds, key=lambda d: abs(d - desired))
