from . import accum, adamw

__all__ = ["accum", "adamw"]
