"""AdamW from scratch (no optax): decoupled weight decay, bias correction,
global-norm clipping, cosine schedule.  Optimizer moments are f32 and inherit
each parameter's PartitionSpec — including the FSDP data-axis sharding — so
optimizer state is ZeRO-sharded across the full mesh by construction.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init(params) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """linear warmup -> cosine decay to min_lr_ratio * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, AdamWState(step, new_m, new_v), metrics


def state_pspecs(param_pspecs) -> AdamWState:
    """Optimizer-state PartitionSpecs mirror the parameters' (ZeRO-1/2)."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), m=param_pspecs, v=param_pspecs)
