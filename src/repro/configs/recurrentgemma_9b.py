"""recurrentgemma-9b — Griffin: RG-LRU + local attention 2:1.
[arXiv:2402.19427; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    head_dim=256, d_ff=12288, vocab_size=256000,
    block_pattern=("rglru", "rglru", "swa"), window=2048,
    norm="rms", mlp="geglu", rope_theta=10000.0,
    supports_long_context=True,    # RG-LRU state + w=2048 ring cache
    notes="MQA local attention (kv=1); 12 groups + 2 remainder rglru",
)
