"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6,
first layer dense.  [arXiv:2401.06066; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944,                    # layer-0 dense FFN width (real model)
    vocab_size=102400,
    block_pattern=("full+moe",), first_k_dense=1,
    norm="rms", mlp="swiglu", rope_theta=10000.0,
    moe=True, num_experts=64, num_shared_experts=2, top_k=6, moe_d_ff=1408,
    supports_long_context=False,
    notes="assignment d_ff=1408 is the routed-expert width",
)
