"""Assigned architecture configs (--arch <id>)."""

import importlib

from .base import ArchConfig, ShapeConfig, SHAPES, reduced

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "reduced", "ARCH_IDS",
           "get_config", "cells"]

_MODULES = {
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "starcoder2-15b": "starcoder2_15b",
    "yi-6b": "yi_6b",
    "gemma3-4b": "gemma3_4b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "rwkv6-7b": "rwkv6_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-tiny": "whisper_tiny",
    "internvl2-1b": "internvl2_1b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def cells(arch_id: str):
    """The (arch x shape) cells this arch runs (long_500k gated)."""
    cfg = get_config(arch_id)
    for shape_name, shape in SHAPES.items():
        if shape_name == "long_500k" and not cfg.supports_long_context:
            continue
        yield shape_name, shape
