"""yi-6b — llama-architecture GQA model.  [arXiv:2403.04652; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11008, vocab_size=64000,
    block_pattern=("full",),
    norm="rms", mlp="swiglu", rope_theta=5000000.0,
    supports_long_context=False,
    notes="llama arch; GQA kv=4",
)
