"""internvl2-1b — InternViT (stub) + Qwen2-0.5B LM backbone.
[arXiv:2404.16821; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655,
    block_pattern=("full",),
    norm="rms", mlp="swiglu", rope_theta=1000000.0,
    frontend="vision", num_patches=256, frontend_dim=1024,
    supports_long_context=False,
    notes="patch embeddings precomputed by the stub ViT; MLP connector",
)
