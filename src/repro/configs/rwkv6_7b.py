"""rwkv6-7b — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    block_pattern=("rwkv6",),
    norm="layer", mlp="gelu",      # rwkv uses LN; mlp unused (channel mix)
    supports_long_context=True,    # O(1) recurrent state
    notes="heads = d_model/64 internally; attn-free",
)
