"""llama4-maverick-400b-a17b — interleaved dense/MoE, 128 experts top-1
plus shared expert.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    block_pattern=("full", "full+moe"),     # MoE every other layer
    norm="rms", mlp="swiglu", rope_theta=500000.0,
    moe=True, num_experts=128, num_shared_experts=1, top_k=1, moe_d_ff=8192,
    supports_long_context=False,
    notes="early-fusion multimodal in the real model; LM backbone here",
)
