"""starcoder2-15b — GQA + RoPE, LayerNorm/GELU coder model.
[arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
    d_ff=24576, vocab_size=49152,
    block_pattern=("full",),
    norm="layer", mlp="gelu", rope_theta=100000.0,
    supports_long_context=False,  # pure full attention: long_500k skipped
    notes="GQA kv=4; RoPE; LayerNorm + GELU MLP",
)
