"""whisper-tiny — encoder-decoder ASR; conv frontend is a STUB
(input_specs provides precomputed 1500-frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    block_pattern=("full",),
    norm="layer", mlp="gelu",
    encoder_decoder=True, enc_layers=4, enc_seq=1500,
    frontend="audio",
    supports_long_context=False,   # enc-dec; 500k decode out of envelope
    notes="decoder shapes lower serve_step for the decoder",
)
