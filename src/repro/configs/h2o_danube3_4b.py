"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
    d_ff=10240, vocab_size=32000,
    block_pattern=("swa",), window=4096,
    norm="rms", mlp="swiglu", rope_theta=10000.0,
    supports_long_context=True,   # all-SWA => ring KV cache, sub-quadratic
    notes="GQA kv=8; SWA window 4096",
)
