"""gemma3-4b — 5:1 local:global attention, 262k vocab, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
    head_dim=256, d_ff=10240, vocab_size=262144,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    norm="rms", mlp="geglu", rope_theta=10000.0, rope_theta_global=1000000.0,
    supports_long_context=True,   # local layers ring-cache; globals SP-shard
    notes="5:1 local(w=1024):global; theta 10k local / 1M global",
)
