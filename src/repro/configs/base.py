"""Architecture and input-shape configuration (assignment spec, DESIGN.md §5).

``ArchConfig`` is the single source of truth a model is built from; one file
per assigned architecture lives next to this module.  ``ShapeConfig`` defines
the four assigned input shapes.  ``--arch <id>`` resolution happens in
:func:`repro.configs.get_config`.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads

    # block schedule: one entry per layer *within a repeating group*.
    # kinds: full | swa | local | global | rwkv6 | rglru
    block_pattern: Tuple[str, ...] = ("full",)
    window: int = 4096           # swa/local attention window
    first_k_dense: int = 0       # MoE: leading dense-FFN layers (DeepSeek: 1)

    # normalization / mlp flavour
    norm: str = "rms"            # rms | layer
    mlp: str = "swiglu"          # swiglu | gelu
    rope_theta: float = 10000.0
    rope_theta_global: float = 0.0  # 0 -> same as rope_theta (gemma3 globals use 1e6)

    # MoE
    moe: bool = False
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # routed-expert hidden dim (fine-grained MoE)
    capacity_factor: float = 1.25

    # encoder-decoder (whisper)
    encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500          # precomputed audio-frame embeddings (stub)

    # multimodal frontend stub
    frontend: str = ""           # "" | audio | vision
    num_patches: int = 0         # vision: prefix patch embeddings
    frontend_dim: int = 0        # raw embedding dim fed by the stub

    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # which assigned shapes this arch runs (long_500k only for sub-quadratic)
    supports_long_context: bool = False
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return all(k in ("rwkv6",) for k in self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        total = v * d                       # token embedding
        if not self.tie_embeddings:
            total += v * d                  # lm head
        if self.frontend == "vision":
            total += self.frontend_dim * d + d * d   # connector MLP
        if self.encoder_decoder:
            total += self.enc_seq * 0       # frame embeddings arrive precomputed

        def attn_params() -> int:
            return d * n_q + 2 * d * n_kv + n_q * d

        def dense_mlp() -> int:
            if self.mlp == "swiglu":
                return 3 * d * self.d_ff
            return 2 * d * self.d_ff

        def moe_mlp() -> int:
            routed = self.num_experts * 3 * d * self.moe_d_ff
            shared = self.num_shared_experts * 3 * d * self.moe_d_ff
            router = d * self.num_experts
            return routed + shared + router

        def rwkv6_block() -> int:
            # time-mix (r,k,v,g,o + decay lora + bonus u) + channel-mix
            tm = 5 * d * d + 2 * d * 64 + d
            cm = 2 * d * int(self.d_ff)
            return tm + cm

        def rglru_block() -> int:
            # recurrent block: input/gate projections + RG-LRU params + out
            d_rnn = n_q
            return 2 * d * d_rnn + 3 * d_rnn + d_rnn * d

        n_layers = self.num_layers
        pattern = self.block_pattern
        per_kind = {}
        for kind in set(pattern):
            if kind == "rwkv6":
                per_kind[kind] = rwkv6_block() + dense_mlp() * 0
            elif kind == "rglru":
                per_kind[kind] = rglru_block() + dense_mlp()
            else:
                per_kind[kind] = attn_params() + dense_mlp()
        # MoE replaces the dense MLP beyond first_k_dense layers
        total_blocks = 0
        for i in range(n_layers):
            kind = pattern[i % len(pattern)]
            blk = per_kind[kind]
            if self.moe and i >= self.first_k_dense and kind not in ("rwkv6", "rglru"):
                blk = attn_params() + moe_mlp()
            total_blocks += blk
        total += total_blocks
        if self.encoder_decoder:
            # encoder blocks + decoder cross-attention
            total += self.enc_layers * (attn_params() + dense_mlp())
            total += self.num_layers * attn_params()   # cross-attn per dec layer
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top_k experts only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        inactive = (self.num_experts - self.top_k) * 3 * d * self.moe_d_ff
        n_moe_layers = self.num_layers - self.first_k_dense
        return self.param_count() - n_moe_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests: few layers, narrow
    width, small vocab/experts — structure preserved."""
    pattern = cfg.block_pattern
    n_layers = max(len(pattern), 2)
    if cfg.first_k_dense:
        n_layers = max(n_layers, cfg.first_k_dense + 1)
    heads = max(2, min(4, cfg.num_heads))
    kv = max(1, min(heads, cfg.num_kv_heads if cfg.num_kv_heads < cfg.num_heads else heads))
    base = dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        window=min(cfg.window, 32),
        moe_d_ff=32 if cfg.moe else 0,
        num_experts=min(cfg.num_experts, 8) if cfg.moe else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        top_k=min(cfg.top_k, 2) if cfg.moe else 0,
        enc_layers=2 if cfg.encoder_decoder else 0,
        enc_seq=24 if cfg.encoder_decoder else cfg.enc_seq,
        num_patches=8 if cfg.frontend == "vision" else 0,
        frontend_dim=32 if cfg.frontend else 0,
        dtype="float32",
    )
    return dataclasses.replace(base, **overrides) if overrides else base
