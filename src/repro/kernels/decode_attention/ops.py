"""Jitted wrapper with backend dispatch (pallas on TPU, XLA elsewhere)."""

from __future__ import annotations

import jax

from .decode_attention import decode_attention
from .ref import decode_attention_ref


def decode_attention_op(q, k, v, k_pos, q_pos, *, window: int = 0,
                        force: str | None = None):
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "xla")
    if mode == "xla":
        return decode_attention_ref(q, k, v, k_pos, q_pos, window=window)
    return decode_attention(q, k, v, k_pos, q_pos, window=window,
                            interpret=(mode == "pallas_interpret"))
