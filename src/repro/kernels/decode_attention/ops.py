"""Jitted wrapper with backend dispatch (pallas on TPU, XLA elsewhere);
``REPRO_ATTN_IMPL`` overrides (see :func:`repro.kernels.resolve_impl`)."""

from __future__ import annotations

from repro.kernels import resolve_impl

from .decode_attention import decode_attention
from .ref import decode_attention_ref

ENV_VAR = "REPRO_ATTN_IMPL"


def decode_attention_op(q, k, v, k_pos, q_pos, *, window: int = 0,
                        force: str | None = None):
    mode = resolve_impl(force, ENV_VAR)
    if mode == "xla":
        return decode_attention_ref(q, k, v, k_pos, q_pos, window=window)
    return decode_attention(q, k, v, k_pos, q_pos, window=window,
                            interpret=(mode == "pallas_interpret"))
