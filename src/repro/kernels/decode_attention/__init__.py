from .decode_attention import DEFAULT_BLOCK_KV, decode_attention, padded_cache_len
from .ops import decode_attention_op
from .ref import decode_attention_ref

__all__ = ["DEFAULT_BLOCK_KV", "decode_attention", "decode_attention_op",
           "decode_attention_ref", "padded_cache_len"]
