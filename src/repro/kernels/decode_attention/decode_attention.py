"""Pallas TPU decode-attention kernel: one query token against a long KV
cache (flash-decoding style).

Grid = (batch, kv_heads, kv_blocks), kv innermost/sequential; the (m, l, acc)
online-softmax state lives in VMEM scratch.  The query block holds the G =
H/Kv query heads that share one KV head, so GQA needs no KV repetition.
Cache slots carry their absolute position (`k_pos`); slots that are empty
(pos < 0), in the future (pos > q_pos), or outside the sliding window are
masked — exactly the ring-cache semantics of ``models.blocks``.

The same per-shard (m, l, acc) math backs the sequence-parallel distributed
decode path (DESIGN.md §6): each shard runs this kernel over its KV slice and
the partial results combine with a 3-float logsumexp reduction per head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_KV = 512
NEG_INF = -1e30


def padded_cache_len(n: int, block_kv: int = DEFAULT_BLOCK_KV) -> int:
    """Smallest cache length >= n that :func:`decode_attention` never pads.

    The kernel tiles the KV axis by ``min(block_kv, S)``; any S above
    ``block_kv`` that is not a multiple of it forces a ``jnp.pad`` of K/V
    (a full cache copy) on *every* decode call.  Sizing the cache with this
    helper at engine init moves that cost to allocation time, once."""
    if n <= block_kv:
        return n
    return -(-n // block_kv) * block_kv


def _kernel(q_ref, k_ref, v_ref, kpos_ref, qpos_ref, o_ref,
            m_scr, l_scr, acc_scr, *, window: int, block_kv: int):
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)           # [G, d]
    k = k_ref[0, 0].astype(jnp.float32)           # [bkv, d]
    v = v_ref[0, 0].astype(jnp.float32)
    k_pos = kpos_ref[0]                           # [bkv]
    q_pos = qpos_ref[0]                           # scalar int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s *= q.shape[-1] ** -0.5                      # [G, bkv]

    valid = (k_pos >= 0) & (k_pos <= q_pos)
    if window > 0:
        valid &= (q_pos - k_pos) < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(valid[None, :], jnp.exp(s - m_cur[:, None]), 0.0)
    l_cur = alpha * l_scr[:, 0] + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_cur[:, None], m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_cur[:, None], l_scr.shape)

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.where(l_scr[:, 0] == 0.0, 1.0, l_scr[:, 0])
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_kv", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     k_pos: jax.Array, q_pos: jax.Array, *,
                     window: int = 0, block_kv: int = DEFAULT_BLOCK_KV,
                     interpret: bool = False) -> jax.Array:
    """q: [B, H, D]; k, v: [B, Kv, S, D]; k_pos: [B, S]; q_pos: [B] ->
    [B, H, D]."""
    b, h, d = q.shape
    kv_heads, s = k.shape[1], k.shape[2]
    g = h // kv_heads
    block_kv = min(block_kv, s)
    pad = (-s) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    sp = s + pad
    qg = q.reshape(b, kv_heads, g, d)
    q_pos = q_pos.astype(jnp.int32).reshape(b, 1)

    grid = (b, kv_heads, sp // block_kv)
    out = pl.pallas_call(
        functools.partial(_kernel, window=window, block_kv=block_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, ki: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda b_, h_, ki: (b_, h_, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda b_, h_, ki: (b_, h_, ki, 0)),
            pl.BlockSpec((1, block_kv), lambda b_, h_, ki: (b_, ki)),
            pl.BlockSpec((1, 1), lambda b_, h_, ki: (b_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h_, ki: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv_heads, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, k_pos, q_pos)
    return out.reshape(b, h, d)
