"""Pure-jnp oracle for decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, k_pos, q_pos, *, window: int = 0):
    """q: [B,H,D]; k,v: [B,Kv,S,D]; k_pos [B,S]; q_pos [B] -> [B,H,D]."""
    b, h, d = q.shape
    kv_heads = k.shape[1]
    if kv_heads != h:
        k = jnp.repeat(k, h // kv_heads, axis=1)
        v = jnp.repeat(v, h // kv_heads, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    valid = (k_pos >= 0) & (k_pos <= q_pos[:, None])
    if window > 0:
        valid &= (q_pos[:, None] - k_pos) < window
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)
