"""Jitted wrappers with backend dispatch (pallas on TPU, XLA elsewhere).

``REPRO_SEGMENT_IMPL`` overrides the automatic choice (``xla`` | ``pallas``
| ``pallas_interpret``); ``pallas_interpret`` lets CPU CI run the real fused
kernels end-to-end through the serve engine's unified prefill+decode ticks.
"""

from __future__ import annotations

from repro.kernels import resolve_impl

from .ref import paged_segment_attention_ref, segment_attention_ref
from .segment_attention import paged_segment_attention, segment_attention

ENV_VAR = "REPRO_SEGMENT_IMPL"


def segment_attention_op(q, k, v, q_pos, k_pos, q_seg, k_seg, *,
                         window: int = 0, force: str | None = None):
    """Flat-key segment attention: q [P,H,D]; k,v [N,Kv,D]; q_pos/q_seg [P];
    k_pos/k_seg [N] -> [P,H,D]."""
    mode = resolve_impl(force, ENV_VAR)
    if mode == "xla":
        return segment_attention_ref(q, k, v, q_pos, k_pos, q_seg, k_seg,
                                     window=window)
    return segment_attention(q, k, v, q_pos, k_pos, q_seg, k_seg,
                             window=window,
                             interpret=(mode == "pallas_interpret"))


def paged_segment_attention_op(q, k_store, v_store, block_tables, q_pos,
                               q_seg, *, window: int = 0,
                               force: str | None = None):
    """Block-store segment attention: q [P,H,D]; stores [N,Kv,T,D]; tables
    [B,M] -> [P,H,D].  The xla mode materializes the table-gathered view
    (the oracle); pallas gathers via scalar prefetch inside the kernel."""
    mode = resolve_impl(force, ENV_VAR)
    if mode == "xla":
        return paged_segment_attention_ref(q, k_store, v_store, block_tables,
                                           q_pos, q_seg, window=window)
    return paged_segment_attention(q, k_store, v_store, block_tables, q_pos,
                                   q_seg, window=window,
                                   interpret=(mode == "pallas_interpret"))
