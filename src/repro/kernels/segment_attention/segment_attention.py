"""Pallas TPU fused segment-attention kernels: one packed query stream
against segment-tagged keys, without ever materializing the ``[H, P, N]``
score matrix.

Two entry points share one online-softmax body structure:

  * :func:`segment_attention` — keys are a flat axis carrying per-key
    ``(k_pos, k_seg)`` tags: the dense packed path's flattened all-slot ring
    view ++ in-stream keys.  Grid = (heads, q_tiles, k_tiles), k innermost /
    sequential; the (m, l, acc) state lives in VMEM scratch per q tile.
  * :func:`paged_segment_attention` — keys live in the paged block store and
    are gathered through per-slot block tables consumed as a
    **scalar-prefetch** operand (like ``kernels/paged_attention``): grid =
    (heads, q_tiles, B * max_blocks_per_seq), each K/V block's DMA issued
    from ``block_tables[j // M, j % M]`` before the body runs.  Key
    positions are implied by table order, key segments by table row, so no
    ``[B, M*T]`` logical view is ever materialized.

The same-segment / written / causal / window predicate is fused into the
tile mask (the packed-segment ABI of ``models.layers.segment_attention``),
and tiles the predicate fully masks — a decode rider's q tile against
another slot's keys, the common case once decode segments share the stream
— skip their matmul entirely (an exact no-op for the online softmax), so
key work stays proportional to the live predicate.
GQA is handled by gridding over *query* heads and mapping each to its KV
head (``h // group``), so no K/V repetition happens.  Fully-masked queries
(dead pad lanes, ``q_seg < 0``) finish with ``l == 0`` and emit exact
zeros — bit-identical to the ref oracle on every lane.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 256


def _online_update(s, valid, v, m_scr, l_scr, acc_scr):
    """One online-softmax tile update over scores ``s`` [bq, bk]."""
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_scr[:, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(valid, jnp.exp(s - m_cur[:, None]), 0.0)
    l_cur = alpha * l_scr[:, 0] + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_cur[:, None], m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_cur[:, None], l_scr.shape)


def _finish(o_ref, l_scr, acc_scr):
    # fully-masked rows keep l == 0: emit exact zeros (dead pad lanes)
    denom = jnp.where(l_scr[:, 0] == 0.0, 1.0, l_scr[:, 0])
    o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def _kernel(q_ref, k_ref, v_ref, qpos_ref, qseg_ref, kpos_ref, kseg_ref,
            o_ref, m_scr, l_scr, acc_scr, *, window: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qp = qpos_ref[0][:, None]                         # [bq, 1]
    qs = qseg_ref[0][:, None]
    kp = kpos_ref[0][None, :]                         # [1, bk]
    ks = kseg_ref[0][None, :]
    valid = (ks == qs) & (qs >= 0) & (kp >= 0) & (kp <= qp)
    if window > 0:
        valid &= (qp - kp) < window

    # fully-masked (q_tile, k_tile) pairs — e.g. a decode rider's tile
    # against another slot's ring — are an exact no-op for the online
    # softmax (p = 0, m/l/acc unchanged): skip their matmul entirely, so
    # per-segment key work stays proportional to the live predicate
    @pl.when(valid.any())
    def _update():
        q = q_ref[0].astype(jnp.float32)              # [bq, d]
        k = k_ref[0].astype(jnp.float32)              # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= q.shape[-1] ** -0.5                      # [bq, bk]
        _online_update(s, valid, v, m_scr, l_scr, acc_scr)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _done():
        _finish(o_ref, l_scr, acc_scr)


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret"))
def segment_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, k_pos: jax.Array, q_seg: jax.Array,
                      k_seg: jax.Array, *, window: int = 0,
                      block_q: int = DEFAULT_BLOCK_Q,
                      block_k: int = DEFAULT_BLOCK_K,
                      interpret: bool = False) -> jax.Array:
    """q: [P, H, D]; k, v: [N, Kv, D]; q_pos/q_seg: [P]; k_pos/k_seg: [N]
    -> [P, H, D]."""
    p, h, d = q.shape
    n, kvh, _ = k.shape
    g = h // kvh
    block_q = min(block_q, p)
    block_k = min(block_k, n)
    pad_q = (-p) % block_q
    pad_k = (-n) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q))
        q_seg = jnp.pad(q_seg, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad_k), constant_values=-1)
        k_seg = jnp.pad(k_seg, (0, pad_k), constant_values=-1)
    pp, nn = p + pad_q, n + pad_k

    qt = jnp.swapaxes(q, 0, 1)                        # [H, P, D]
    kt = jnp.swapaxes(k, 0, 1)                        # [Kv, N, D]
    vt = jnp.swapaxes(v, 0, 1)

    grid = (h, pp // block_q, nn // block_k)
    out = pl.pallas_call(
        functools.partial(_kernel, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h_, qi, ki: (h_, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda h_, qi, ki: (h_ // g, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda h_, qi, ki: (h_ // g, ki, 0)),
            pl.BlockSpec((1, block_q), lambda h_, qi, ki: (0, qi)),
            pl.BlockSpec((1, block_q), lambda h_, qi, ki: (0, qi)),
            pl.BlockSpec((1, block_k), lambda h_, qi, ki: (0, ki)),
            pl.BlockSpec((1, block_k), lambda h_, qi, ki: (0, ki)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda h_, qi, ki: (h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, pp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, q_pos.astype(jnp.int32)[None], q_seg.astype(jnp.int32)[None],
      k_pos.astype(jnp.int32)[None], k_seg.astype(jnp.int32)[None])
    return jnp.swapaxes(out, 0, 1)[:p]


def _paged_kernel(bt_ref, q_ref, k_ref, v_ref, qpos_ref, qseg_ref,
                  o_ref, m_scr, l_scr, acc_scr, *, window: int,
                  block_tokens: int, blocks_per_seq: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    slot = j // blocks_per_seq                        # key segment id
    entry = bt_ref[slot, j % blocks_per_seq]          # scalar int32

    # logical positions covered by table slot (2-D iota for TPU)
    kp = (j % blocks_per_seq) * block_tokens + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_tokens), 1)              # [1, T]
    qp = qpos_ref[0][:, None]                         # [bq, 1]
    qs = qseg_ref[0][:, None]
    valid = (entry >= 0) & (qs == slot) & (qs >= 0) & (kp <= qp)
    if window > 0:
        valid &= (qp - kp) < window

    # blocks owned by a slot no query in this tile belongs to (the common
    # case once decode riders share the stream) are an exact no-op: skip
    # the matmul, leaving the (m, l, acc) state untouched
    @pl.when(valid.any())
    def _update():
        q = q_ref[0].astype(jnp.float32)              # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)           # [T, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= q.shape[-1] ** -0.5                      # [bq, T]
        _online_update(s, valid, v, m_scr, l_scr, acc_scr)

    @pl.when(j == pl.num_programs(2) - 1)
    def _done():
        _finish(o_ref, l_scr, acc_scr)


@functools.partial(jax.jit, static_argnames=("window", "block_q",
                                             "interpret"))
def paged_segment_attention(q: jax.Array, k_store: jax.Array,
                            v_store: jax.Array, block_tables: jax.Array,
                            q_pos: jax.Array, q_seg: jax.Array, *,
                            window: int = 0, block_q: int = DEFAULT_BLOCK_Q,
                            interpret: bool = False) -> jax.Array:
    """q: [P, H, D]; k_store/v_store: [N, Kv, T, D]; block_tables: [B, M]
    int32 (-1 = unallocated, clamped for the DMA and masked in the body);
    q_pos/q_seg: [P] (segment id == block-table row) -> [P, H, D]."""
    p, h, d = q.shape
    n_blocks, kvh, t, _ = k_store.shape
    b, m = block_tables.shape
    g = h // kvh
    block_q = min(block_q, p)
    pad_q = (-p) % block_q
    if pad_q:
        q = jnp.pad(q, ((0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q))
        q_seg = jnp.pad(q_seg, (0, pad_q), constant_values=-1)
    pp = p + pad_q
    qt = jnp.swapaxes(q, 0, 1)                        # [H, P, D]
    block_tables = block_tables.astype(jnp.int32)

    def kv_map(h_, qi, j, bt):
        # -1 entries are clamped to a real block for the DMA; the body
        # masks them out entirely via `entry >= 0`
        return (jnp.clip(bt[j // m, j % m], 0, n_blocks - 1), h_ // g, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(h, pp // block_q, b * m),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h_, qi, j, bt: (h_, qi, 0)),
            pl.BlockSpec((1, 1, t, d), kv_map),
            pl.BlockSpec((1, 1, t, d), kv_map),
            pl.BlockSpec((1, block_q), lambda h_, qi, j, bt: (0, qi)),
            pl.BlockSpec((1, block_q), lambda h_, qi, j, bt: (0, qi)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda h_, qi, j, bt: (h_, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, window=window, block_tokens=t,
                          blocks_per_seq=m),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((h, pp, d), q.dtype),
        interpret=interpret,
    )(block_tables, qt, k_store, v_store,
      q_pos.astype(jnp.int32)[None], q_seg.astype(jnp.int32)[None])
    return jnp.swapaxes(out, 0, 1)[:p]
