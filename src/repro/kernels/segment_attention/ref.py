"""Pure-jnp oracle for fused segment-masked attention (packed streams).

One flat query stream carries contiguous chunks from *different* requests
(prefill chunks and length-1 decode segments alike); every query and key
names its owning segment, and a key is visible iff it belongs to the same
segment, has been written (``k_pos >= 0``), is causal (``k_pos <= q_pos``),
and sits inside the sliding window.  Queries whose segment id is negative
(dead pad lanes) — or whose predicate masks every key — return **exact
zeros**, so kernel parity can be asserted on all lanes, not just live ones.

The paged oracle gathers the logical K/V view through the block table
(``kernels.paged_attention.paged_gather``) and defers to the flat oracle, so
the paged and flat oracles can never drift apart.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _segment_mask(q_pos, k_pos, q_seg, k_seg, window: int):
    """[P, N] bool visibility predicate (the packed-segment ABI)."""
    ok = (k_seg[None, :] == q_seg[:, None]) & (q_seg[:, None] >= 0)
    ok &= k_pos[None, :] >= 0
    ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return ok


def segment_attention_ref(q, k, v, q_pos, k_pos, q_seg, k_seg, *,
                          window: int = 0):
    """q: [P,H,D]; k,v: [N,Kv,D]; q_pos/q_seg: [P]; k_pos/k_seg: [N]
    -> [P,H,D].  GQA/MQA via grouped einsum (no repeated K/V)."""
    p, h, d = q.shape
    kvh = k.shape[1]
    g = h // kvh
    scale = d ** -0.5
    qg = (q * scale).reshape(p, kvh, g, d)
    s = jnp.einsum("pkgd,nkd->kgpn", qg.astype(jnp.float32),
                   k.astype(jnp.float32))                    # [Kv,G,P,N]
    ok = _segment_mask(q_pos, k_pos, q_seg, k_seg, window)    # [P,N]
    s = jnp.where(ok[None, None], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    # fully-masked queries (dead pad lanes) would softmax uniformly over
    # -1e30 scores and emit garbage; zero them instead
    live = ok.any(axis=-1)                                    # [P]
    pr = jnp.where(live[None, None, :, None], pr, 0.0)
    o = jnp.einsum("kgpn,nkd->pkgd", pr, v.astype(jnp.float32))
    return o.reshape(p, h, d).astype(q.dtype)


def paged_segment_attention_ref(q, k_store, v_store, block_tables, q_pos,
                                q_seg, *, window: int = 0):
    """q: [P,H,D]; k_store/v_store: [N,Kv,T,D]; block_tables: [B,M] int32
    (-1 = unallocated); q_pos/q_seg: [P] (segment id == block-table row)
    -> [P,H,D].  Key positions are implied by table order and key segments
    by table row; write-then-gather callers rely on every same-segment
    position <= q_pos being live in the store."""
    from repro.kernels.paged_attention import paged_gather
    k, v, k_pos = paged_gather(k_store, v_store, block_tables)
    b, kvh, mt, d = k.shape
    k_flat = jnp.swapaxes(k, 1, 2).reshape(b * mt, kvh, d)
    v_flat = jnp.swapaxes(v, 1, 2).reshape(b * mt, kvh, d)
    kpos_flat = k_pos.reshape(b * mt)
    kseg_flat = jnp.repeat(jnp.arange(b, dtype=jnp.int32), mt)
    return segment_attention_ref(q, k_flat, v_flat, q_pos, kpos_flat,
                                 q_seg, kseg_flat, window=window)
