from .ops import paged_segment_attention_op, segment_attention_op
from .ref import (paged_segment_attention_ref, segment_attention_ref)
from .segment_attention import paged_segment_attention, segment_attention

__all__ = [
    "segment_attention",
    "segment_attention_ref",
    "segment_attention_op",
    "paged_segment_attention",
    "paged_segment_attention_ref",
    "paged_segment_attention_op",
]
