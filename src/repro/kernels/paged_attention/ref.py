"""Pure-jnp oracle for paged decode attention.

Gathers the per-sequence logical KV view through the block table and defers
to the dense decode-attention oracle, so the paged and dense oracles can
never drift apart.  Logical position ``p`` of row ``b`` lives in physical
block ``block_tables[b, p // T]`` at offset ``p % T``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref


def paged_gather(k_store, v_store, block_tables):
    """Materialize each row's logical KV view from the global block store.

    k_store/v_store: [N, Kv, T, D]; block_tables: [B, M] int32 (-1 = hole).
    Returns (k [B, Kv, M*T, D], v [B, Kv, M*T, D], k_pos [B, M*T]) where
    ``k_pos`` carries the logical position of each view slot, -1 for slots
    behind a -1 table entry (so downstream masking drops them).
    """
    n, kv_heads, t, d = k_store.shape
    b, m = block_tables.shape
    idx = jnp.clip(block_tables, 0, n - 1)               # [B, M]
    k = k_store[idx].transpose(0, 2, 1, 3, 4).reshape(b, kv_heads, m * t, d)
    v = v_store[idx].transpose(0, 2, 1, 3, 4).reshape(b, kv_heads, m * t, d)
    pos = jnp.arange(m * t, dtype=jnp.int32)[None, :]     # [1, M*T]
    ok = jnp.repeat(block_tables >= 0, t, axis=1)         # [B, M*T]
    k_pos = jnp.where(ok, pos, -1)
    return k, v, k_pos


def paged_decode_attention_ref(q, k_store, v_store, block_tables, q_pos, *,
                               window: int = 0):
    """q: [B,H,D]; k_store/v_store: [N,Kv,T,D]; block_tables: [B,M] int32;
    q_pos: [B] -> [B,H,D].  Keys at logical positions > q_pos (or behind -1
    table entries, or outside the sliding window) are masked."""
    k, v, k_pos = paged_gather(k_store, v_store, block_tables)
    return decode_attention_ref(q, k, v, k_pos, q_pos, window=window)
