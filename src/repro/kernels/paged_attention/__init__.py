from .ops import paged_decode_attention_op
from .paged_attention import paged_decode_attention
from .ref import paged_decode_attention_ref, paged_gather

__all__ = ["paged_decode_attention", "paged_decode_attention_op",
           "paged_decode_attention_ref", "paged_gather"]
