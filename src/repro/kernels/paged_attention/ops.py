"""Jitted wrapper with backend dispatch (pallas on TPU, XLA elsewhere).

``REPRO_PAGED_IMPL`` overrides the automatic choice (``xla`` |
``pallas`` | ``pallas_interpret``); ``pallas_interpret`` lets CPU CI run the
real kernel end-to-end through the serve engine.
"""

from __future__ import annotations

from repro.kernels import resolve_impl

from .paged_attention import paged_decode_attention
from .ref import paged_decode_attention_ref

ENV_VAR = "REPRO_PAGED_IMPL"


def paged_decode_attention_op(q, k_store, v_store, block_tables, q_pos, *,
                              window: int = 0, force: str | None = None):
    mode = resolve_impl(force, ENV_VAR)
    if mode == "xla":
        return paged_decode_attention_ref(q, k_store, v_store, block_tables,
                                          q_pos, window=window)
    return paged_decode_attention(q, k_store, v_store, block_tables, q_pos,
                                  window=window,
                                  interpret=(mode == "pallas_interpret"))
