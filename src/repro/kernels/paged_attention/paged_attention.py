"""Pallas TPU paged decode-attention kernel: one query token against K/V
scattered across a physical block store, gathered through per-sequence block
tables (vLLM-style paged KV cache).

Layout / ABI (shared with ``repro.serve.paging`` and ``models.blocks``):

  * block store   ``k_store, v_store: [num_blocks, kv_heads, T, head_dim]``
    — the single physical HBM allocation all sequences share; ``T`` is the
    block token granularity (``KVBlockPool.block_tokens``).
  * block table   ``block_tables: [B, max_blocks_per_seq] int32`` — entry
    ``i`` of row ``b`` names the physical block holding that row's logical
    tokens ``[i*T, (i+1)*T)``; ``-1`` marks an unallocated table slot.
  * logical position ``p`` of row ``b`` therefore lives at
    ``store[block_tables[b, p // T], :, p % T]``.

Grid = (batch, kv_heads, max_blocks_per_seq) with the block-table axis
innermost/sequential; the (m, l, acc) online-softmax state lives in VMEM
scratch exactly as in ``decode_attention``.  The block table is a
scalar-prefetch operand, so each K/V block's DMA is issued from
``block_tables[b, i]`` *before* the kernel body runs — the gather is free,
no dense [B, S] cache is ever materialized.  Invalid table entries (-1) are
clamped to block 0 for the DMA and fully masked in the body.

Unlike the dense kernel there is no ``k_pos`` operand: positions are
implied by table order (slot ``i`` covers ``[i*T, (i+1)*T)``), and validity
is ``entry >= 0 and pos <= q_pos`` (plus the sliding window).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, q_ref, k_ref, v_ref, qpos_ref, o_ref,
            m_scr, l_scr, acc_scr, *, window: int, block_tokens: int):
    b = pl.program_id(0)
    i = pl.program_id(2)
    n_i = pl.num_programs(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # [G, d]
    k = k_ref[0, 0].astype(jnp.float32)              # [T, d]
    v = v_ref[0, 0].astype(jnp.float32)
    entry = bt_ref[b, i]                             # scalar int32
    q_pos = qpos_ref[0, 0]                           # scalar int32

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s *= q.shape[-1] ** -0.5                         # [G, T]

    # logical positions covered by table slot i (2-D iota for TPU)
    k_pos = i * block_tokens + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_tokens), 1)             # [1, T]
    valid = (entry >= 0) & (k_pos <= q_pos)
    if window > 0:
        valid &= (q_pos - k_pos) < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.where(valid, jnp.exp(s - m_cur[:, None]), 0.0)
    l_cur = alpha * l_scr[:, 0] + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_cur[:, None], m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_cur[:, None], l_scr.shape)

    @pl.when(i == n_i - 1)
    def _finish():
        denom = jnp.where(l_scr[:, 0] == 0.0, 1.0, l_scr[:, 0])
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q: jax.Array, k_store: jax.Array,
                           v_store: jax.Array, block_tables: jax.Array,
                           q_pos: jax.Array, *, window: int = 0,
                           interpret: bool = False) -> jax.Array:
    """q: [B, H, D]; k_store/v_store: [N, Kv, T, D]; block_tables: [B, M]
    int32 (-1 = unallocated); q_pos: [B] -> [B, H, D]."""
    b, h, d = q.shape
    n_blocks, kv_heads, t, _ = k_store.shape
    m = block_tables.shape[1]
    g = h // kv_heads
    qg = q.reshape(b, kv_heads, g, d)
    q_pos = q_pos.astype(jnp.int32).reshape(b, 1)
    block_tables = block_tables.astype(jnp.int32)

    def kv_map(b_, h_, i_, bt):
        # -1 entries are clamped to a real block for the DMA; the body
        # masks them out entirely via `entry >= 0`
        return (jnp.clip(bt[b_, i_], 0, n_blocks - 1), h_, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kv_heads, m),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, i_, bt: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, t, d), kv_map),
            pl.BlockSpec((1, 1, t, d), kv_map),
            pl.BlockSpec((1, 1), lambda b_, h_, i_, bt: (b_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h_, i_, bt: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, window=window, block_tokens=t),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv_heads, g, d), q.dtype),
        interpret=interpret,
    )(block_tables, qg, k_store, v_store, q_pos)
    return out.reshape(b, h, d)
