"""Pallas kernel families (flash/decode/paged/segment attention, rwkv6,
rglru).

Each family package holds the kernel (`<name>.py`), a pure-jnp oracle
(`ref.py`), and a thin dispatcher (`ops.py`).  Every dispatcher resolves its
implementation through :func:`resolve_impl`, the single place defining the
``xla | pallas | pallas_interpret`` semantics:

  * ``xla``              — run the oracle (exact jnp reference);
  * ``pallas``           — run the compiled Pallas TPU kernel;
  * ``pallas_interpret`` — run the Pallas kernel in interpreter mode, so CPU
    CI exercises the real kernel code path end-to-end.

Resolution order: explicit ``force=`` argument, then the family's environment
variable (``REPRO_ATTN_IMPL``, ``REPRO_PAGED_IMPL``, ``REPRO_SEGMENT_IMPL``,
``REPRO_RWKV6_IMPL``, ``REPRO_RGLRU_IMPL``), then the backend default
(``pallas`` on TPU, ``xla`` everywhere else).
"""

from __future__ import annotations

import os

import jax

IMPLS = ("xla", "pallas", "pallas_interpret")


def resolve_impl(force: str | None = None, env_var: str | None = None) -> str:
    """Resolve a kernel implementation choice to one of :data:`IMPLS`."""
    mode = force
    if mode is None and env_var:
        mode = os.environ.get(env_var) or None
    if mode is None:
        mode = "pallas" if jax.default_backend() == "tpu" else "xla"
    if mode not in IMPLS:
        raise ValueError(
            f"unknown kernel impl {mode!r}"
            + (f" (from ${env_var})" if force is None and env_var else "")
            + f"; expected one of {IMPLS}")
    return mode
