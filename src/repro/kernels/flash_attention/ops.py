"""Jitted wrapper: picks the Pallas kernel on TPU, the exact XLA chunked path
elsewhere (and in dry-runs so GSPMD sees plain einsums).  ``REPRO_ATTN_IMPL``
overrides the automatic choice (see :func:`repro.kernels.resolve_impl`)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import resolve_impl

from .flash_attention import flash_attention
from .ref import attention_ref

ENV_VAR = "REPRO_ATTN_IMPL"


def attention_op(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool = True, window: int = 0,
                 force: str | None = None) -> jax.Array:
    """q: [B,S,H,D]; k,v: [B,S,Kv,D] (model layout).  force: None|'pallas'|
    'pallas_interpret'|'xla'."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    mode = resolve_impl(force, ENV_VAR)
    if mode == "xla":
        out = attention_ref(qt, kt, vt, causal=causal, window=window)
    else:
        out = flash_attention(qt, kt, vt, causal=causal, window=window,
                              interpret=(mode == "pallas_interpret"))
    return jnp.swapaxes(out, 1, 2)
