"""Pallas TPU flash-attention BACKWARD kernels + custom_vjp wrapper.

Standard two-kernel formulation (FlashAttention v2 style), recomputing the
probability tiles from (q, k, lse) instead of reading stored scores:

    L_i  = logsumexp_j(s_ij)                 (saved by the forward kernel)
    D_i  = rowsum(dO_i * O_i)
    P_ij = exp(s_ij - L_i)
    dV_j = sum_i P_ij^T dO_i
    dS   = P * (dO V^T - D_i)
    dQ_i = scale * sum_j dS_ij K_j           (kernel A: kv innermost, dq scratch)
    dK_j = scale * sum_i dS_ij^T Q_i         (kernel B: q innermost, dk/dv scratch)

Grids are TPU-sequential so the accumulators persist in VMEM scratch.  GQA
is handled by computing per-query-head dK/dV and group-summing outside the
kernel (correctness-first; fusing the group sum into kernel B is the next
perf step).  ``flash_attention_vjp`` wires these into jax.custom_vjp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .flash_attention import DEFAULT_BLOCK_KV, DEFAULT_BLOCK_Q


def _mask(qi, ki, bq, bkv, *, causal, window, seq_len, shape):
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, shape, 0)
    k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, shape, 1)
    ok = k_pos < seq_len
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    return ok


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref, dq_ref,
               acc_scr, *, causal, window, block_q, block_kv, seq_len, scale):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)      # [bq, 128] broadcast cols
    dsum = dsum_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ok = _mask(qi, ki, block_q, block_kv, causal=causal, window=window,
               seq_len=seq_len, shape=s.shape)
    p = jnp.where(ok, jnp.exp(s - lse[:, :1]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dsum[:, :1])
    acc_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _done():
        dq_ref[0, 0] = (acc_scr[...] * scale).astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, causal, window,
                block_q, block_kv, seq_len, scale):
    ki = pl.program_id(2)
    qi = pl.program_id(3)
    n_q = pl.num_programs(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    dsum = dsum_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    ok = _mask(qi, ki, block_q, block_kv, causal=causal, window=window,
               seq_len=seq_len, shape=s.shape)
    p = jnp.where(ok, jnp.exp(s - lse[:, :1]), 0.0)      # [bq, bkv]
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - dsum[:, :1])
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(qi == n_q - 1)
    def _done():
        dk_ref[0, 0] = (dk_scr[...] * scale).astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, window=0,
                        block_q=DEFAULT_BLOCK_Q, block_kv=DEFAULT_BLOCK_KV,
                        interpret=False):
    """q,o,do: [B,H,S,D]; k,v: [B,Kv,S,D]; lse: [B,H,S].
    Returns (dq [B,H,S,D], dk [B,Kv,S,D], dv [B,Kv,S,D])."""
    b, h, s, d = q.shape
    kv_heads = k.shape[1]
    g = h // kv_heads
    scale = d ** -0.5
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    sq = s + (-s) % block_q
    skv = s + (-s) % block_kv
    sp = max(sq, skv)

    qp = _pad_to(q, sp, 2)
    kp = _pad_to(jnp.repeat(k, g, axis=1), sp, 2)
    vp = _pad_to(jnp.repeat(v, g, axis=1), sp, 2)
    dop = _pad_to(do, sp, 2)
    # per-row logsumexp and D = rowsum(dO * O), laid out [B,H,S,128] lanes
    dsum = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    lse_l = _pad_to(jnp.broadcast_to(lse[..., None], (b, h, s, 128)), sp, 2)
    dsum_l = _pad_to(jnp.broadcast_to(dsum[..., None], (b, h, s, 128)), sp, 2)

    common = dict(causal=causal, window=window, block_q=block_q,
                  block_kv=block_kv, seq_len=s, scale=scale)
    in_specs = [
        pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        pl.BlockSpec((1, 1, block_kv, d), lambda b_, h_, qi, ki: (b_, h_, ki, 0)),
        pl.BlockSpec((1, 1, block_kv, d), lambda b_, h_, qi, ki: (b_, h_, ki, 0)),
        pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        pl.BlockSpec((1, 1, block_q, 128), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        pl.BlockSpec((1, 1, block_q, 128), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
    ]
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(b, h, sp // block_q, sp // block_kv),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sp, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lse_l, dsum_l)[:, :, :s, :]

    # kernel B: note grid order (kv outer, q inner/sequential)
    in_specs_b = [
        pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, ki, qi: (b_, h_, qi, 0)),
        pl.BlockSpec((1, 1, block_kv, d), lambda b_, h_, ki, qi: (b_, h_, ki, 0)),
        pl.BlockSpec((1, 1, block_kv, d), lambda b_, h_, ki, qi: (b_, h_, ki, 0)),
        pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, ki, qi: (b_, h_, qi, 0)),
        pl.BlockSpec((1, 1, block_q, 128), lambda b_, h_, ki, qi: (b_, h_, qi, 0)),
        pl.BlockSpec((1, 1, block_q, 128), lambda b_, h_, ki, qi: (b_, h_, qi, 0)),
    ]
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, **common),
        grid=(b, h, sp // block_kv, sp // block_q),
        in_specs=in_specs_b,
        out_specs=[
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, ki, qi: (b_, h_, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, ki, qi: (b_, h_, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sp, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, sp, d), q.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_kv, d), jnp.float32),
                        pltpu.VMEM((block_kv, d), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lse_l, dsum_l)
    dk = dk[:, :, :s, :].reshape(b, kv_heads, g, s, d).sum(axis=2)
    dv = dv[:, :, :s, :].reshape(b, kv_heads, g, s, d).sum(axis=2)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)
