"""Pure-jnp oracle for the flash-attention kernel (same [B,H,S,D] layout)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q: [B,H,S,D]; k,v: [B,Kv,S,D]."""
    b, h, s, d = q.shape
    kv_heads = k.shape[1]
    if kv_heads != h:
        k = jnp.repeat(k, h // kv_heads, axis=1)
        v = jnp.repeat(v, h // kv_heads, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= (qi - ki) < window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
