from .flash_attention import flash_attention, flash_attention_fwd_lse
from .flash_attention_bwd import flash_attention_bwd
from .ops import attention_op
from .ref import attention_ref
from .vjp import flash_attention_grad

__all__ = ["flash_attention", "flash_attention_fwd_lse", "flash_attention_bwd",
           "flash_attention_grad", "attention_op", "attention_ref"]
