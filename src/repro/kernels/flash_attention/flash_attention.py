"""Pallas TPU flash-attention forward kernel (causal / sliding-window / GQA).

Canonical TPU schedule: grid = (batch, q_heads, q_blocks, kv_blocks) with the
kv dimension innermost — TPU grids execute sequentially, so the online-softmax
accumulators (m, l, acc) live in VMEM scratch and persist across kv steps:

    @ kv == 0:            init scratch
    each kv block:        s = q k^T (MXU), online-softmax update (VPU)
    @ kv == last:         out = acc / l

BlockSpecs stream one [block_q, head_dim] query tile and [block_kv, head_dim]
K/V tiles HBM->VMEM per step; GQA maps query head h to KV head h // group in
the K/V index_map so repeated KV never materializes.  block sizes are MXU
aligned (multiples of 128 where the head_dim allows).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_KV = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, window: int, block_q: int, block_kv: int,
            seq_len: int, lse_ref=None):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)            # [bkv, d]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s *= q_ref.shape[-1] ** -0.5                   # [bq, bkv]

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]                           # [bq]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    p = jnp.where(mask, p, 0.0)
    l_cur = alpha * l_scr[:, 0] + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_cur[:, None], m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_cur[:, None], l_scr.shape)

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.where(l_scr[:, 0] == 0.0, 1.0, l_scr[:, 0])
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)
        if lse_ref is not None:
            lse = m_scr[...] + jnp.log(jnp.maximum(l_scr[...], 1e-30))
            lse_ref[0, 0] = lse.astype(lse_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_kv: int = DEFAULT_BLOCK_KV,
                    interpret: bool = False) -> jax.Array:
    """q: [B, H, S, D]; k, v: [B, Kv, S, D] -> [B, H, S, D]."""
    b, h, s, d = q.shape
    kv_heads = k.shape[1]
    group = h // kv_heads
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    pad_q = (-s) % block_q
    pad_kv = (-s) % block_kv
    if pad_q or pad_kv:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    sq, skv = s + pad_q, s + pad_kv

    grid = (b, h, sq // block_q, skv // block_kv)
    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, window=window,
                          block_q=block_q, block_kv=block_kv, seq_len=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # m
            pltpu.VMEM((block_q, 128), jnp.float32),   # l
            pltpu.VMEM((block_q, d), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :s, :]


def _kernel_with_lse(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                     acc_scr, **kw):
    _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            lse_ref=lse_ref, **kw)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention_fwd_lse(q, k, v, *, causal=True, window=0,
                            block_q=DEFAULT_BLOCK_Q,
                            block_kv=DEFAULT_BLOCK_KV, interpret=False):
    """Forward that also emits the logsumexp residual [B, H, S, 128-lane]
    needed by the backward kernels (custom_vjp path)."""
    b, h, s, d = q.shape
    kv_heads = k.shape[1]
    group = h // kv_heads
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    pad_q = (-s) % block_q
    pad_kv = (-s) % block_kv
    if pad_q or pad_kv:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    sq, skv = s + pad_q, s + pad_kv

    grid = (b, h, sq // block_q, skv // block_kv)
    o, lse = pl.pallas_call(
        functools.partial(_kernel_with_lse, causal=causal, window=window,
                          block_q=block_q, block_kv=block_kv, seq_len=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_q, 128),
                         lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, sq, 128), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o[:, :, :s, :], lse[:, :, :s, 0]
