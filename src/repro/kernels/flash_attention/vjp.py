"""Differentiable flash attention: custom_vjp over the Pallas fwd/bwd
kernels (scores never materialize in either pass)."""

from __future__ import annotations

import functools

import jax

from .flash_attention import flash_attention_fwd_lse
from .flash_attention_bwd import flash_attention_bwd


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_grad(q, k, v, causal=True, window=0, interpret=False):
    """q: [B,H,S,D]; k,v: [B,Kv,S,D] -> [B,H,S,D], differentiable."""
    o, _ = flash_attention_fwd_lse(q, k, v, causal=causal, window=window,
                                   interpret=interpret)
    return o


def _fwd(q, k, v, causal, window, interpret):
    o, lse = flash_attention_fwd_lse(q, k, v, causal=causal, window=window,
                                     interpret=interpret)
    return o, (q, k, v, o, lse)


def _bwd(causal, window, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, causal=causal,
                                     window=window, interpret=interpret)
    return dq, dk, dv


flash_attention_grad.defvjp(_fwd, _bwd)
