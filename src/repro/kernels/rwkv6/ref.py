"""Naive per-token RWKV-6 recurrence — the oracle.

``rwkv6_ref_state`` is the state-in/state-out variant backing chunked
prefill: the caller supplies the state matrix carried across chunk
boundaries and receives the state after the last token, exactly as chunked
attention attends through (and writes back into) the KV cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref_state(r, k, v, logw, u, s0):
    """r,k,v,logw: [BH, S, N]; u: [BH, N]; s0: [BH, N, N] f32 state carried
    in.  Returns (y [BH, S, N], s_out [BH, N, N] f32)."""

    def step(S, inp):
        r_t, k_t, v_t, lw_t = inp                       # [BH, N]
        w_t = jnp.exp(lw_t.astype(jnp.float32))
        kv = k_t[..., :, None] * v_t[..., None, :]      # [BH, N, N]
        y = jnp.einsum("bi,bij->bj", r_t, S + u[..., :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (r, k, v, logw))
    s_out, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), s_out


def rwkv6_ref(r, k, v, logw, u):
    """r,k,v,logw: [BH, S, N]; u: [BH, N] -> y [BH, S, N] (zero init state)."""
    bh, _, n = r.shape
    s0 = jnp.zeros((bh, n, n), jnp.float32)
    return rwkv6_ref_state(r, k, v, logw, u, s0)[0]
