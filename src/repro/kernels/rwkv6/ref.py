"""Naive per-token RWKV-6 recurrence — the oracle."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, logw, u):
    """r,k,v,logw: [BH, S, N]; u: [BH, N] -> y [BH, S, N]."""
    bh, s, n = r.shape

    def step(S, inp):
        r_t, k_t, v_t, lw_t = inp                       # [BH, N]
        w_t = jnp.exp(lw_t.astype(jnp.float32))
        kv = k_t[..., :, None] * v_t[..., None, :]      # [BH, N, N]
        y = jnp.einsum("bi,bij->bj", r_t, S + u[..., :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    S0 = jnp.zeros((bh, n, n), jnp.float32)
    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, logw))
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)
