"""Backend dispatch for the RWKV6 time-mix core."""

from __future__ import annotations

import jax

from .ref import rwkv6_ref
from .rwkv6 import rwkv6_scan


def rwkv6_op(r, k, v, logw, u, *, force: str | None = None):
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "xla")
    if mode == "xla":
        return rwkv6_ref(r, k, v, logw, u)
    return rwkv6_scan(r, k, v, logw, u,
                      interpret=(mode == "pallas_interpret"))
