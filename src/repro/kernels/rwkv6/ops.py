"""Backend dispatch for the RWKV6 time-mix core (``REPRO_RWKV6_IMPL``)."""

from __future__ import annotations

from repro.kernels import resolve_impl

from .ref import rwkv6_ref, rwkv6_ref_state
from .rwkv6 import rwkv6_scan, rwkv6_scan_state

ENV_VAR = "REPRO_RWKV6_IMPL"


def rwkv6_op(r, k, v, logw, u, *, force: str | None = None):
    mode = resolve_impl(force, ENV_VAR)
    if mode == "xla":
        return rwkv6_ref(r, k, v, logw, u)
    return rwkv6_scan(r, k, v, logw, u,
                      interpret=(mode == "pallas_interpret"))


def rwkv6_state_op(r, k, v, logw, u, s0, *, force: str | None = None):
    """State-in/state-out time mix: (y, s_out) with S seeded from ``s0``.

    The chunked-prefill entry point: per-row scan state is carried across
    chunk boundaries by the caller (see kernels/README.md, scan-state ABI)."""
    mode = resolve_impl(force, ENV_VAR)
    if mode == "xla":
        return rwkv6_ref_state(r, k, v, logw, u, s0)
    return rwkv6_scan_state(r, k, v, logw, u, s0,
                            interpret=(mode == "pallas_interpret"))
