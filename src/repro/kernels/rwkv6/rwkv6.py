"""Pallas TPU kernel for the RWKV-6 chunked recurrence (time mix core).

Grid = (batch * heads, time_chunks), chunks innermost/sequential; the running
state matrix S [N, N] persists in VMEM scratch across chunk steps.  Per chunk
of length L the kernel computes (all f32 in VMEM):

    cum_t   = cumsum(log w)                      [L, N]
    y_intra = r_t . sum_{s<t} exp(cum_t - cum_s) k_s v_s^T   (strict lower)
    y_diag  = (r_t * u * k_t) . v_t
    y_cross = (r_t * exp(cum_t)) @ S
    S'      = diag(exp(cum_L)) S + sum_s exp(cum_L - cum_s) (k_s o v_s)

which is exactly ``models.rwkv6.time_mix_chunked``'s math; the oracle in
``ref.py`` is the naive per-token recurrence both are tested against.

``rwkv6_scan_state`` is the state-in/state-out variant: S is seeded from a
caller-provided matrix and the post-sequence state is returned as a second
output — the scan-state ABI chunked prefill threads across per-row chunk
boundaries (see kernels/README.md).  ``rwkv6_scan`` is the zero-init wrapper.

The intra-chunk term contracts over (s, i) per output channel j; with L = 32
and N = 64 the working set is MXU/VPU friendly and S stays resident, so HBM
traffic is just the r/k/v/w chunk streams — the operational-intensity win the
chunked schedule exists for.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

HEAD_DIM = 64
CHUNK = 32


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
            s_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)       # [L, N]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = w_ref[0].astype(jnp.float32)      # log decay, [L, N]
    u = u_ref[0].astype(jnp.float32)       # [1, N] bonus

    cum = jnp.cumsum(lw, axis=0)           # [L, N] inclusive: sum_{u<=t} lw_u
    ecum = cum - lw                        # exclusive: sum_{u<t} lw_u
    A = jnp.exp(ecum)                      # decay applied to the r-side read
    A_total = jnp.exp(cum[-1])             # [N]

    # D[t, s, :] = prod_{s<u<t} w_u = exp(ecum_t - cum_s), strictly lower
    ct = ecum[:, None, :]
    cs = cum[None, :, :]
    strict = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    D = jnp.where(strict[:, :, None], jnp.exp(ct - cs), 0.0)   # [L, L, N]

    # y_intra[t, j] = sum_s sum_i r[t,i] D[t,s,i] k[s,i] v[s,j]
    scores = jnp.einsum("ti,tsi,si->ts", r, D, k)              # [L, L]
    y_intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_diag = jnp.sum(r * u * k, axis=1, keepdims=True) * v     # [L, N]
    y_cross = jax.lax.dot_general(r * A, s_scr[...],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    decay_k = jnp.exp(cum[-1][None, :] - cum) * k              # [L, N]
    s_scr[...] = A_total[:, None] * s_scr[...] + jax.lax.dot_general(
        decay_k, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0] = (y_intra + y_diag + y_cross).astype(y_ref.dtype)
    sout_ref[0] = s_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_state(r: jax.Array, k: jax.Array, v: jax.Array,
                     logw: jax.Array, u: jax.Array, s0: jax.Array, *,
                     chunk: int = CHUNK,
                     interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """r,k,v,logw: [BH, S, N]; u: [BH, N]; s0: [BH, N, N] f32 carried state.
    Returns (y [BH, S, N], s_out [BH, N, N] f32).

    BH = batch * heads flattened; S must be a multiple of ``chunk``."""
    bh, s, n = r.shape
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    grid = (bh, s // chunk)
    u2 = u[:, None, :]
    y, s_out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, n), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, n, n), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n, n), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, n), r.dtype),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u2, s0.astype(jnp.float32))
    return y, s_out


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
               u: jax.Array, *, chunk: int = CHUNK,
               interpret: bool = False) -> jax.Array:
    """Zero-init-state wrapper: r,k,v,logw [BH, S, N]; u [BH, N] -> y."""
    bh, _, n = r.shape
    s0 = jnp.zeros((bh, n, n), jnp.float32)
    return rwkv6_scan_state(r, k, v, logw, u, s0, chunk=chunk,
                            interpret=interpret)[0]
