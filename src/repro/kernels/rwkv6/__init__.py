from .ops import rwkv6_op
from .ref import rwkv6_ref
from .rwkv6 import rwkv6_scan

__all__ = ["rwkv6_op", "rwkv6_ref", "rwkv6_scan"]
