from .ops import rwkv6_op, rwkv6_state_op
from .ref import rwkv6_ref, rwkv6_ref_state
from .rwkv6 import rwkv6_scan, rwkv6_scan_state

__all__ = ["rwkv6_op", "rwkv6_state_op", "rwkv6_ref", "rwkv6_ref_state",
           "rwkv6_scan", "rwkv6_scan_state"]
