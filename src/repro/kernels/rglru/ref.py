"""Naive scan oracle for the RG-LRU recurrence.

``rglru_ref_state`` is the state-in/state-out variant backing chunked
prefill: the hidden state h is seeded from the caller's carried value and
the post-sequence state is returned alongside the per-token outputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref_state(log_a, b, h0):
    """log_a, b: [B, S, F]; h0: [B, F] f32 carried state.
    Returns (h [B, S, F], h_out [B, F] f32)."""

    def step(h, inp):
        la, bb = inp
        h = jnp.exp(la.astype(jnp.float32)) * h + bb.astype(jnp.float32)
        return h, h

    xs = (jnp.moveaxis(log_a, 1, 0), jnp.moveaxis(b, 1, 0))
    h_out, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1).astype(b.dtype), h_out


def rglru_ref(log_a, b):
    """log_a, b: [B, S, F] -> h [B, S, F], h_{-1} = 0."""
    h0 = jnp.zeros(log_a.shape[::2], jnp.float32)  # [B, F]
    return rglru_ref_state(log_a, b, h0)[0]
