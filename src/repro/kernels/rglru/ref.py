"""Naive scan oracle for the RG-LRU recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(log_a, b):
    """log_a, b: [B, S, F] -> h [B, S, F], h_{-1} = 0."""

    def step(h, inp):
        la, bb = inp
        h = jnp.exp(la.astype(jnp.float32)) * h + bb.astype(jnp.float32)
        return h, h

    h0 = jnp.zeros(log_a.shape[::2], jnp.float32)  # [B, F]
    xs = (jnp.moveaxis(log_a, 1, 0), jnp.moveaxis(b, 1, 0))
    _, hs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(hs, 0, 1).astype(b.dtype)
