"""Backend dispatch for the RG-LRU scan."""

from __future__ import annotations

import jax

from .ref import rglru_ref
from .rglru import rglru_scan


def rglru_op(log_a, b, *, force: str | None = None):
    mode = force or ("pallas" if jax.default_backend() == "tpu" else "xla")
    if mode == "xla":
        return rglru_ref(log_a, b)
    return rglru_scan(log_a, b, interpret=(mode == "pallas_interpret"))
