"""Backend dispatch for the RG-LRU scan (``REPRO_RGLRU_IMPL``)."""

from __future__ import annotations

from repro.kernels import resolve_impl

from .ref import rglru_ref, rglru_ref_state
from .rglru import rglru_scan, rglru_scan_state

ENV_VAR = "REPRO_RGLRU_IMPL"


def rglru_op(log_a, b, *, force: str | None = None):
    mode = resolve_impl(force, ENV_VAR)
    if mode == "xla":
        return rglru_ref(log_a, b)
    return rglru_scan(log_a, b, interpret=(mode == "pallas_interpret"))


def rglru_state_op(log_a, b, h0, *, force: str | None = None):
    """State-in/state-out scan: (h, h_out) with the recurrence seeded from
    ``h0``.  The chunked-prefill entry point: per-row scan state is carried
    across chunk boundaries by the caller (kernels/README.md)."""
    mode = resolve_impl(force, ENV_VAR)
    if mode == "xla":
        return rglru_ref_state(log_a, b, h0)
    return rglru_scan_state(log_a, b, h0,
                            interpret=(mode == "pallas_interpret"))
