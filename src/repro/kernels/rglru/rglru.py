"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

    h_t = exp(log_a_t) * h_{t-1} + b_t

Grid = (batch, feature_blocks, time_chunks) with time innermost/sequential;
the carried hidden state for the current (batch, feature-block) persists in
VMEM scratch.  Within a chunk the recurrence unrolls as a fori_loop over
rows — each step is a fused VPU multiply-add over the feature block, with all
chunk data resident in VMEM (one HBM read per element, the minimum).

``rglru_scan_state`` is the state-in/state-out variant: the scratch is
seeded from a caller-provided h0 [B, F] and the post-sequence state comes
back as a second output — the scan-state ABI chunked prefill threads across
per-row chunk boundaries (see kernels/README.md).  ``rglru_scan`` is the
zero-init wrapper.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 128
BLOCK_F = 512


def _kernel(loga_ref, b_ref, h0_ref, h_ref, hout_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[:].astype(jnp.float32)

    log_a = loga_ref[0].astype(jnp.float32)    # [L, F]
    b = b_ref[0].astype(jnp.float32)           # [L, F]

    def step(t, carry):
        h, out = carry
        h = jnp.exp(log_a[t]) * h + b[t]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 0)
        return h, out

    h0 = h_scr[0]
    out0 = jnp.zeros_like(b)
    h_fin, out = jax.lax.fori_loop(0, chunk, step, (h0, out0))
    h_scr[...] = h_fin[None, :]
    h_ref[0] = out.astype(h_ref.dtype)
    hout_ref[...] = h_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "block_f", "interpret"))
def rglru_scan_state(log_a: jax.Array, b: jax.Array, h0: jax.Array, *,
                     chunk: int = CHUNK, block_f: int = BLOCK_F,
                     interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """log_a, b: [B, S, F]; h0: [B, F] f32 carried state.
    Returns (h [B, S, F], h_out [B, F] f32)."""
    bsz, s, f = log_a.shape
    chunk = min(chunk, s)
    block_f = min(block_f, f)
    assert s % chunk == 0 and f % block_f == 0
    grid = (bsz, f // block_f, s // chunk)
    h, h_out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_f), lambda b_, fi, ci: (b_, ci, fi)),
            pl.BlockSpec((1, chunk, block_f), lambda b_, fi, ci: (b_, ci, fi)),
            pl.BlockSpec((1, block_f), lambda b_, fi, ci: (b_, fi)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_f),
                         lambda b_, fi, ci: (b_, ci, fi)),
            pl.BlockSpec((1, block_f), lambda b_, fi, ci: (b_, fi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, f), b.dtype),
            jax.ShapeDtypeStruct((bsz, f), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_f), jnp.float32)],
        interpret=interpret,
    )(log_a, b, h0.astype(jnp.float32))
    return h, h_out


@functools.partial(jax.jit, static_argnames=("chunk", "block_f", "interpret"))
def rglru_scan(log_a: jax.Array, b: jax.Array, *, chunk: int = CHUNK,
               block_f: int = BLOCK_F, interpret: bool = False) -> jax.Array:
    """log_a, b: [B, S, F] -> h: [B, S, F] with h_{-1} = 0 (zero init)."""
    h0 = jnp.zeros(log_a.shape[::2], jnp.float32)
    return rglru_scan_state(log_a, b, h0, chunk=chunk, block_f=block_f,
                            interpret=interpret)[0]
