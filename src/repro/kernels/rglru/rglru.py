"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

    h_t = exp(log_a_t) * h_{t-1} + b_t

Grid = (batch, feature_blocks, time_chunks) with time innermost/sequential;
the carried hidden state for the current (batch, feature-block) persists in
VMEM scratch.  Within a chunk the recurrence unrolls as a fori_loop over
rows — each step is a fused VPU multiply-add over the feature block, with all
chunk data resident in VMEM (one HBM read per element, the minimum).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 128
BLOCK_F = 512


def _kernel(loga_ref, b_ref, h_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    log_a = loga_ref[0].astype(jnp.float32)    # [L, F]
    b = b_ref[0].astype(jnp.float32)           # [L, F]

    def step(t, carry):
        h, out = carry
        h = jnp.exp(log_a[t]) * h + b[t]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 0)
        return h, out

    h0 = h_scr[0]
    out0 = jnp.zeros_like(b)
    h_fin, out = jax.lax.fori_loop(0, chunk, step, (h0, out0))
    h_scr[...] = h_fin[None, :]
    h_ref[0] = out.astype(h_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_f", "interpret"))
def rglru_scan(log_a: jax.Array, b: jax.Array, *, chunk: int = CHUNK,
               block_f: int = BLOCK_F, interpret: bool = False) -> jax.Array:
    """log_a, b: [B, S, F] -> h: [B, S, F] with h_0 = b_0 (zero init)."""
    bsz, s, f = log_a.shape
    chunk = min(chunk, s)
    block_f = min(block_f, f)
    assert s % chunk == 0 and f % block_f == 0
    grid = (bsz, f // block_f, s // chunk)
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_f), lambda b_, fi, ci: (b_, ci, fi)),
            pl.BlockSpec((1, chunk, block_f), lambda b_, fi, ci: (b_, ci, fi)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_f),
                               lambda b_, fi, ci: (b_, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, f), b.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_f), jnp.float32)],
        interpret=interpret,
    )(log_a, b)
    return out
