from .ops import rglru_op, rglru_state_op
from .ref import rglru_ref, rglru_ref_state
from .rglru import rglru_scan, rglru_scan_state

__all__ = ["rglru_op", "rglru_state_op", "rglru_ref", "rglru_ref_state",
           "rglru_scan", "rglru_scan_state"]
