from .ops import rglru_op
from .ref import rglru_ref
from .rglru import rglru_scan

__all__ = ["rglru_op", "rglru_ref", "rglru_scan"]
