"""Logical-axis sharding rules (DP/FSDP/TP/EP/SP) — MaxText-style.

Models annotate tensors with *logical* axis names; this module maps them to
mesh axes for whatever mesh is active.  Parameters carry a parallel tree of
logical-name tuples built at init time; :func:`params_pspecs` turns that into
``PartitionSpec``s (adding ZeRO/FSDP sharding of large replicated dims over
the data axis), and :func:`shard` applies activation constraints in-graph.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# jax moved shard_map out of experimental around 0.4.35/0.5; support both so
# multi-device paths (EP MoE, coordinated controllers) run on either version.
if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # pragma: no cover - exercised on older jax only
    from jax.experimental.shard_map import shard_map

__all__ = [
    "AxisRules", "DEFAULT_RULES", "SERVE_TP_RULES", "use_mesh",
    "current_mesh", "logical_spec", "shard", "params_pspecs",
    "named_sharding", "FSDP_THRESHOLD", "Axes", "A", "shard_map",
]


@dataclasses.dataclass(frozen=True)
class Axes:
    """Logical dim names for one parameter — an opaque pytree LEAF, so a tree
    of ``Axes`` mirrors the params tree structurally."""

    names: tuple

    def __iter__(self):
        return iter(self.names)


def A(*names: str | None) -> Axes:
    return Axes(tuple(names))

# logical axis -> preferred mesh axes (first available wins)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),       # DP: batch over pod x data
    "seq": (),                      # activations: sequence replicated
    "kv_seq": ("model",),           # SP: sequence-sharded decode KV caches
    "embed": (),                    # d_model replicated
    "heads": ("model",),            # TP: attention heads
    "kv_heads": ("model",),
    "ff": ("model",),               # TP: FFN hidden
    "vocab": ("model",),            # TP: embedding/logits vocab dim
    "experts": ("model",),          # EP: MoE expert dim
    "moe_ff": ("data",),            # EP: expert hidden dim (resident 2D)
    "expert_cap": (),
    "fsdp": ("data",),              # ZeRO/FSDP axis for large weights
    "state": (),                    # recurrent state dims
    "ctl": ("data",),               # controller batches (jax_controller)
}

FSDP_THRESHOLD = 2**20  # params larger than 1M elements get FSDP sharding

# Serving tensor-parallel rule overlay: ONLY the attention-head family (and
# MoE experts) shards over the model axis.  Training's default rules also
# split ff/vocab, which changes matmul contraction order (psum of partials)
# and therefore bits; the serve engine's contract is token-identity with
# single-device, so everything except head-parallel attention + EP MoE stays
# replicated and the per-head math is bit-for-bit the single-device program.
SERVE_TP_RULES: dict[str, tuple[str, ...]] = {
    "batch": (),
    "kv_seq": (),
    "ff": (),
    "vocab": (),
    "moe_ff": (),
    "fsdp": (),
    "ctl": (),
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: dict[str, tuple[str, ...]] = dict(DEFAULT_RULES)
        self.fsdp: bool = True


_CTX = _Ctx()
AxisRules = dict


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict | None = None, fsdp: bool = True):
    """Activate a mesh + logical rules for model tracing."""
    prev = (_CTX.mesh, _CTX.rules, _CTX.fsdp)
    _CTX.mesh = mesh
    _CTX.rules = dict(DEFAULT_RULES, **(rules or {}))
    _CTX.fsdp = fsdp
    try:
        with mesh:
            yield mesh
    finally:
        _CTX.mesh, _CTX.rules, _CTX.fsdp = prev


def current_mesh() -> Mesh | None:
    return _CTX.mesh


def _resolve(name: str, taken: set[str], dim_size: int | None = None
             ) -> tuple[str, ...]:
    """Mesh axes for one logical name (skipping axes not in the mesh, axes
    already used by another dim of the same tensor, and — when ``dim_size``
    is known — axes that would not divide the dimension evenly)."""
    mesh = _CTX.mesh
    if mesh is None:
        return ()
    axes = []
    prod = 1
    for ax in _CTX.rules.get(name, ()):
        if ax in mesh.axis_names and ax not in taken:
            if dim_size is not None and dim_size % (prod * mesh.shape[ax]):
                continue
            prod *= mesh.shape[ax]
            axes.append(ax)
            taken.add(ax)
    return tuple(axes)


def logical_spec(*names: str | None) -> P:
    """PartitionSpec for a tensor annotated with logical dim names."""
    taken: set[str] = set()
    parts = []
    for n in names:
        if n is None:
            parts.append(None)
            continue
        axes = _resolve(n, taken)
        parts.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*parts)


def shard(x: jax.Array, *names: str | None) -> jax.Array:
    """Apply a logical sharding constraint (no-op without an active mesh).
    Axes that do not divide the concrete dim evenly are dropped."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    taken: set[str] = set()
    parts = []
    for i, n in enumerate(names):
        if n is None:
            parts.append(None)
            continue
        axes = _resolve(n, taken, x.shape[i])
        parts.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


def named_sharding(*names: str | None) -> NamedSharding:
    mesh = _CTX.mesh
    assert mesh is not None, "named_sharding requires an active mesh"
    return NamedSharding(mesh, logical_spec(*names))


def _leaf_pspec(axes_names: tuple, shape: tuple[int, ...]) -> P:
    """Logical names -> PartitionSpec for one parameter, with FSDP: shard the
    largest still-replicated dim over the data axis for big params."""
    taken: set[str] = set()
    parts: list = []
    for i, n in enumerate(axes_names):
        if n is None:
            parts.append(None)
        else:
            axes = _resolve(n, taken, shape[i])
            parts.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    mesh = _CTX.mesh
    residual_only = _CTX.fsdp == "residual"
    if (mesh is not None and _CTX.fsdp and "data" not in taken
            and "data" in mesh.axis_names
            and not (residual_only and taken)):
        size = 1
        for s in shape:
            size *= s
        if size >= FSDP_THRESHOLD:
            data_size = mesh.shape["data"]
            # biggest unsharded, divisible dim gets the fsdp axis
            cands = [i for i, p in enumerate(parts)
                     if p is None and shape[i] % data_size == 0]
            if cands:
                i = max(cands, key=lambda j: shape[j])
                parts[i] = "data"
    return P(*parts)


def params_pspecs(params, logical_tree):
    """Map a params pytree + parallel tree of :class:`Axes` to PartitionSpecs."""
    return jax.tree.map(
        lambda p, ax: _leaf_pspec(tuple(ax.names), p.shape),
        params, logical_tree,
    )
