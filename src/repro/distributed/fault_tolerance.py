"""Fault-tolerance runtime: heartbeats, straggler detection, preemption
checkpointing, elastic restart.

At 1000+ nodes the failure model is: (a) node loss -> detected by missed
heartbeats -> restart from the latest atomic checkpoint on a (possibly
smaller) mesh; (b) slow nodes -> detected by step-time outliers -> data
pipeline ships backup batches / scheduler reassigns; (c) preemption signal ->
emergency checkpoint before the deadline.  All three are exercised by unit
tests on the single-host substrate; the mechanisms are mesh-size agnostic
because checkpoints are elastic (see ``repro.checkpoint``).
"""

from __future__ import annotations

import signal
import threading
import time

__all__ = ["HeartbeatMonitor", "StragglerDetector", "PreemptionHandler"]


class HeartbeatMonitor:
    """Tracks per-worker liveness; a worker is dead after ``timeout_s``
    without a beat.  ``on_failure(worker)`` fires once per transition."""

    def __init__(self, workers, *, timeout_s: float = 10.0,
                 on_failure=None, clock=time.monotonic) -> None:
        self._clock = clock
        self.timeout_s = timeout_s
        self.on_failure = on_failure
        self._last = {w: clock() for w in workers}
        self._dead: set = set()
        self._lock = threading.Lock()

    def beat(self, worker) -> None:
        with self._lock:
            self._last[worker] = self._clock()
            if worker in self._dead:
                self._dead.discard(worker)   # node rejoined (elastic up)

    def check(self) -> list:
        """Returns newly-dead workers."""
        now = self._clock()
        newly = []
        with self._lock:
            for w, t in self._last.items():
                if w not in self._dead and now - t > self.timeout_s:
                    self._dead.add(w)
                    newly.append(w)
        for w in newly:
            if self.on_failure:
                self.on_failure(w)
        return newly

    @property
    def alive(self) -> list:
        with self._lock:
            return [w for w in self._last if w not in self._dead]


class StragglerDetector:
    """Flags workers whose step time exceeds ``factor`` x the fleet median."""

    def __init__(self, *, factor: float = 2.0, window: int = 16) -> None:
        self.factor = factor
        self.window = window
        self._times: dict = {}

    def record(self, worker, seconds: float) -> None:
        buf = self._times.setdefault(worker, [])
        buf.append(seconds)
        if len(buf) > self.window:
            buf.pop(0)

    def stragglers(self) -> list:
        if not self._times:
            return []
        meds = {w: sorted(v)[len(v) // 2] for w, v in self._times.items() if v}
        fleet = sorted(meds.values())[len(meds) // 2]
        return [w for w, m in meds.items() if m > self.factor * fleet]


class PreemptionHandler:
    """SIGTERM -> set flag; the training loop checkpoints and exits cleanly.
    ``install()`` is idempotent; in tests, call :meth:`trigger` directly."""

    def __init__(self) -> None:
        self._flag = threading.Event()
        self._installed = False

    def install(self) -> None:
        if self._installed:
            return
        try:
            signal.signal(signal.SIGTERM, lambda *_: self._flag.set())
            self._installed = True
        except ValueError:
            pass  # non-main thread (tests)

    def trigger(self) -> None:
        self._flag.set()

    def reset(self) -> None:
        """Clear the flag after the preemption was handled (serve path: the
        engine drained + requeued; a replacement worker — or the same one,
        in tests/chaos runs — resumes from the requeued work)."""
        self._flag.clear()

    @property
    def triggered(self) -> bool:
        return self._flag.is_set()
