"""Distributed-optimization collectives.

* :func:`compressed_psum_grads` — int8 block-quantized gradient all-reduce
  via ``shard_map`` (quantize -> psum int32 -> dequantize), with optional
  error feedback.  Cuts DP all-reduce bytes 4x vs f32 / 2x vs bf16; intended
  for the cross-pod (slowest) axis at 1000+ node scale.
* :func:`sp_decode_combine` — logsumexp combine of per-shard partial decode
  attention (o_i, m_i, l_i): the sequence-parallel KV path (DESIGN.md §6);
  math matches the Pallas decode kernel's scratch accumulators, so a shard's
  kernel output feeds this directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum_grads",
           "sp_decode_combine"]

_BLOCK = 128


def quantize_int8(x: jax.Array, scale: jax.Array | None = None):
    """Blockwise symmetric int8 quantization along the last axis.  Pass a
    precomputed (e.g. globally agreed) ``scale`` to share ranges across
    participants of a compressed collective."""
    orig_shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    if scale is None:
        scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, orig_shape


def dequantize_int8(q: jax.Array, scale: jax.Array, orig_shape) -> jax.Array:
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for s in orig_shape:
        size *= s
    return out[:size].reshape(orig_shape)


def compressed_psum_grads(grads, axis_name: str):
    """All-reduce-mean gradients over ``axis_name`` in int8 (int32 accum).

    Call inside shard_map/psum context.  Scales all-reduce in f32 (tiny:
    1/128 of payload); payload rides int8->int32."""
    n = jax.lax.psum(1.0, axis_name)

    def one(g):
        # 1) agree on a global per-block scale (tiny f32 collective: 1/128
        #    of the payload), 2) int8 payload all-reduce in int32.
        flat = g.astype(jnp.float32).reshape(-1)
        pad = (-flat.size) % _BLOCK
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
        local = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
        glob = jax.lax.pmax(local, axis_name) / 127.0
        glob = jnp.where(glob == 0.0, 1.0, glob)
        q, _, shape = quantize_int8(g, scale=glob)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = dequantize_int8(summed, glob, shape) / n
        return mean.astype(g.dtype)

    return jax.tree.map(one, grads)


def sp_decode_combine(o: jax.Array, m: jax.Array, l: jax.Array,
                      axis_name: str):
    """Combine per-shard partial attention.

    o: [..., H, D] un-normalized accumulator; m: [..., H] running max;
    l: [..., H] running sum.  Returns the exact global attention output."""
    m_glob = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis_name)
    o_glob = jax.lax.psum(o * corr[..., None], axis_name)
    denom = jnp.where(l_glob == 0.0, 1.0, l_glob)
    return o_glob / denom[..., None]
