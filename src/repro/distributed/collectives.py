"""Distributed-optimization collectives + serving tensor-parallel wrappers.

* :func:`compressed_psum_grads` — int8 block-quantized gradient all-reduce
  via ``shard_map`` (quantize -> psum int32 -> dequantize), with optional
  error feedback.  Cuts DP all-reduce bytes 4x vs f32 / 2x vs bf16; intended
  for the cross-pod (slowest) axis at 1000+ node scale.
* :func:`sp_decode_combine` — logsumexp combine of per-shard partial decode
  attention (o_i, m_i, l_i): the sequence-parallel KV path (DESIGN.md §6);
  math matches the Pallas decode kernel's scratch accumulators, so a shard's
  kernel output feeds this directly.
* :func:`tp_segment_attention` / :func:`tp_paged_segment_attention` — the
  serve engine's head-sharded segment-attention: the fused kernels run
  per-shard over a contiguous head chunk on the ``model`` axis, the [P,H,D]
  output is all-gathered back INSIDE the shard body (pure data movement —
  no psum over a contraction — so the result is bit-identical to the
  single-device op), and everything downstream runs replicated.  Falls back
  to the plain op when no serving mesh is active or the head counts do not
  divide the model axis (e.g. MQA kv_heads=1).

``shard_map`` is imported through :mod:`repro.distributed.sharding`'s one
version-compat alias (jax moved it out of experimental around 0.4.35) —
do not duplicate the fallback here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .sharding import current_mesh, shard_map

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum_grads",
           "sp_decode_combine", "tp_segment_attention",
           "tp_paged_segment_attention"]

_BLOCK = 128


def quantize_int8(x: jax.Array, scale: jax.Array | None = None):
    """Blockwise symmetric int8 quantization along the last axis.  Pass a
    precomputed (e.g. globally agreed) ``scale`` to share ranges across
    participants of a compressed collective."""
    orig_shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % _BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    if scale is None:
        scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
        scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, orig_shape


def dequantize_int8(q: jax.Array, scale: jax.Array, orig_shape) -> jax.Array:
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for s in orig_shape:
        size *= s
    return out[:size].reshape(orig_shape)


def compressed_psum_grads(grads, axis_name: str):
    """All-reduce-mean gradients over ``axis_name`` in int8 (int32 accum).

    Call inside shard_map/psum context.  Scales all-reduce in f32 (tiny:
    1/128 of payload); payload rides int8->int32."""
    n = jax.lax.psum(1.0, axis_name)

    def one(g):
        # 1) agree on a global per-block scale (tiny f32 collective: 1/128
        #    of the payload), 2) int8 payload all-reduce in int32.
        flat = g.astype(jnp.float32).reshape(-1)
        pad = (-flat.size) % _BLOCK
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
        local = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
        glob = jax.lax.pmax(local, axis_name) / 127.0
        glob = jnp.where(glob == 0.0, 1.0, glob)
        q, _, shape = quantize_int8(g, scale=glob)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = dequantize_int8(summed, glob, shape) / n
        return mean.astype(g.dtype)

    return jax.tree.map(one, grads)


def _serve_tp_mesh(heads: int, kv_heads: int):
    """The active mesh iff serving TP applies to this op's head counts.

    Requires a live ``use_mesh`` context with a non-trivial ``model`` axis
    that divides BOTH head counts — contiguous head chunks then preserve the
    GQA group mapping (local ``h // (H_loc/Kv_loc)`` equals the global
    grouping), so the per-shard op is the single-device math on a head
    slice.  Anything else returns None and the caller runs unsharded."""
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    s = mesh.shape["model"]
    if s <= 1 or heads % s or kv_heads % s:
        return None
    return mesh


def tp_segment_attention(q, k, v, q_pos, k_pos, q_seg, k_seg, *,
                         window: int = 0):
    """Head-sharded flat segment attention: q [P,H,D]; k,v [N,Kv,D].

    Per-shard the fused op sees a contiguous head chunk [P,H/s,D] x
    [N,Kv/s,D]; the all-gather over ``model`` (axis 1, inside the body)
    rebuilds the full [P,H,D] output on every shard.  ``check_rep=False``:
    Pallas calls carry no replication rule, and the ``data`` axis is
    untouched (all in_specs leave it out, so inputs and output are
    replicated over it by construction)."""
    from repro.kernels.segment_attention import segment_attention_op
    mesh = _serve_tp_mesh(q.shape[1], k.shape[1])
    if mesh is None:
        return segment_attention_op(q, k, v, q_pos, k_pos, q_seg, k_seg,
                                    window=window)

    def body(q_l, k_l, v_l, qp, kp, qs, ks):
        o = segment_attention_op(q_l, k_l, v_l, qp, kp, qs, ks,
                                 window=window)
        return jax.lax.all_gather(o, "model", axis=1, tiled=True)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, "model", None), P(None, "model", None),
                  P(None, "model", None), P(None), P(None), P(None),
                  P(None)),
        out_specs=P(None, None, None),
        check_rep=False)(q, k, v, q_pos, k_pos, q_seg, k_seg)


def tp_paged_segment_attention(q, k_store, v_store, block_tables, q_pos,
                               q_seg, *, window: int = 0):
    """Head-sharded paged segment attention: q [P,H,D]; stores [N,Kv,T,D].

    The block stores shard on the ``Kv`` head dim (axis 1) — the same
    placement the engine pins on the cache arrays, so the gather through
    the block table stays shard-local.  Block *indices* (tables, positions,
    segments) are global and replicated."""
    from repro.kernels.segment_attention import paged_segment_attention_op
    mesh = _serve_tp_mesh(q.shape[1], k_store.shape[1])
    if mesh is None:
        return paged_segment_attention_op(q, k_store, v_store, block_tables,
                                          q_pos, q_seg, window=window)

    def body(q_l, k_l, v_l, bt, qp, qs):
        o = paged_segment_attention_op(q_l, k_l, v_l, bt, qp, qs,
                                       window=window)
        return jax.lax.all_gather(o, "model", axis=1, tiled=True)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, "model", None), P(None, "model", None, None),
                  P(None, "model", None, None), P(None, None), P(None),
                  P(None)),
        out_specs=P(None, None, None),
        check_rep=False)(q, k_store, v_store, block_tables, q_pos, q_seg)


def sp_decode_combine(o: jax.Array, m: jax.Array, l: jax.Array,
                      axis_name: str):
    """Combine per-shard partial attention.

    o: [..., H, D] un-normalized accumulator; m: [..., H] running max;
    l: [..., H] running sum.  Returns the exact global attention output."""
    m_glob = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis_name)
    o_glob = jax.lax.psum(o * corr[..., None], axis_name)
    denom = jnp.where(l_glob == 0.0, 1.0, l_glob)
    return o_glob / denom[..., None]
