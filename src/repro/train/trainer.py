"""Training loop with SmartConf-managed runtime PerfConfs, checkpoint/restart
and fault-tolerance hooks.

PerfConfs wired here (DESIGN.md §4):
  * ``data.prefetch_depth``      — indirect, hard on host RSS (CA6059-like);
  * ``train.ckpt_interval_steps`` — direct, soft on checkpoint overhead
    fraction (HD4995-like: too frequent -> slow steps, too rare -> long
    recovery);
  * ``train.microbatch_tokens``   — compile-time knob: the controller's
    desired value is quantized to a divisor of the batch and takes effect at
    the next re-jit (see optim.accum).

The loop is mesh-agnostic: on the production mesh the step function is the
dry-run-compiled one; on a host mesh (tests/examples) it's the same factory.
"""

from __future__ import annotations

import dataclasses
import os

import jax

from repro.checkpoint import Checkpointer, latest_step, restore
from repro.configs.base import ArchConfig
from repro.core import (ControllerModel, GoalSpec, HBMAccountant, SmartConf,
                        SmartConfIndirect, StepTimer)
from repro.core.smartconf import ConfRegistry
from repro.data import PrefetchPipeline, SyntheticTokens
from repro.distributed.fault_tolerance import PreemptionHandler
from repro.models import zoo
from repro.optim import adamw
from repro.train import train_step as ts

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    workdir: str = "/tmp/repro_train"
    total_steps: int = 100
    ckpt_interval: int = 50
    ckpt_keep: int = 2
    n_micro: int = 1
    remat: str = "dots"
    host_rss_budget: int = 512 * 1024 * 1024
    ckpt_overhead_goal: float = 0.05   # <=5% of wall time writing checkpoints
    seed: int = 0
    batch_size: int = 8
    seq_len: int = 128
    enable_smartconf: bool = True


class Trainer:
    def __init__(self, cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                 tc: TrainerConfig) -> None:
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tc = tc
        self.registry = ConfRegistry()
        self.accountant = HBMAccountant(budget_bytes=tc.host_rss_budget)
        self.accountant.set("runtime", 64 * 1024 * 1024)  # base host footprint

        self.source = SyntheticTokens(cfg.vocab_size, tc.batch_size,
                                      tc.seq_len, seed=tc.seed)
        self.pipeline = PrefetchPipeline(self.source, depth=2,
                                         accountant=self.accountant)
        self.ckpt = Checkpointer(os.path.join(tc.workdir, "ckpt"),
                                 interval_steps=tc.ckpt_interval,
                                 keep_n=tc.ckpt_keep)
        self.timer = StepTimer()
        self.preemption = PreemptionHandler()

        # --- SmartConf controllers --------------------------------------
        self.sc_prefetch = None
        self.sc_ckpt = None
        if tc.enable_smartconf:
            batch_bytes = float(self.source.batch_nbytes())
            self.sc_prefetch = SmartConfIndirect(
                "data.prefetch_depth", metric="host_rss_bytes",
                goal=GoalSpec(float(tc.host_rss_budget), hard=True),
                initial=2.0, registry=self.registry,
                model=ControllerModel(alpha=batch_bytes, lam=0.08,
                                      delta=1.25, conf_min=1.0, conf_max=64))
            self.sc_ckpt = SmartConf(
                "train.ckpt_interval_steps", metric="ckpt_overhead_frac",
                goal=GoalSpec(tc.ckpt_overhead_goal, hard=False,
                              direction="upper"),
                initial=float(tc.ckpt_interval), registry=self.registry,
                # overhead ~ write_time / (interval * step_time): alpha<0
                model=ControllerModel(alpha=-1e-3, lam=0.1, delta=1.3,
                                      conf_min=5.0, conf_max=10000.0))

        # --- model/optimizer state ---------------------------------------
        self.params, _ = zoo.init(cfg, jax.random.key(tc.seed))
        self.opt_state = adamw.init(self.params)
        self.step_fn = jax.jit(ts.make_train_step(
            cfg, opt_cfg, n_micro=tc.n_micro, remat=tc.remat))
        self.step = 0
        self.metrics_log: list[dict] = []
        self._maybe_restore()

    # ------------------------------------------------------------- restart
    def _maybe_restore(self) -> None:
        d = self.ckpt.directory
        if latest_step(d) is None:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        restored, extra, step = restore(d, None, tree)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = int(extra["step"])
        self.source.restore(extra["data"])
        if self.sc_ckpt is not None and "ckpt_interval" in extra:
            self.ckpt.set_interval(int(extra["ckpt_interval"]))
        if "prefetch_depth" in extra:
            self.pipeline.set_depth(int(extra["prefetch_depth"]))

    def _save(self, *, force: bool = False) -> None:
        extra = {"step": self.step, "data": self.source.state(),
                 "ckpt_interval": self.ckpt.interval_steps,
                 "prefetch_depth": self.pipeline.depth}
        self.ckpt.maybe_save(self.step,
                             {"params": self.params, "opt": self.opt_state},
                             extra=extra, force=force)

    # ------------------------------------------------------------ controls
    def _update_controllers(self) -> None:
        if self.sc_prefetch is not None:
            self.sc_prefetch.set_perf(float(self.accountant.total()),
                                      self.pipeline.buffered())
            self.pipeline.set_depth(int(self.sc_prefetch.get_conf()))
        if self.sc_ckpt is not None and self.ckpt.writes:
            step_t = max(self.timer.mean(), 1e-6)
            per_write = self.ckpt.write_seconds / self.ckpt.writes
            overhead = per_write / (self.ckpt.interval_steps * step_t)
            self.sc_ckpt.set_perf(overhead)
            self.ckpt.set_interval(int(self.sc_ckpt.get_conf()))

    # ----------------------------------------------------------------- run
    def run(self, steps: int | None = None) -> list[dict]:
        steps = steps if steps is not None else self.tc.total_steps
        target = self.step + steps
        while self.step < target:
            if self.preemption.triggered:
                self._save(force=True)
                break
            batch = self.pipeline.get()
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            with self.timer:
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
            self.step += 1
            self._update_controllers()
            self._save()
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = self.step
            self.metrics_log.append(rec)
        return self.metrics_log

    def close(self) -> None:
        self.pipeline.close()
        for sc in (self.sc_prefetch, self.sc_ckpt):
            if sc is not None:
                sc.close()
