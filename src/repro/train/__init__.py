from . import train_step

__all__ = ["train_step"]
