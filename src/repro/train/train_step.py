"""Train/prefill/serve step factories with explicit sharding derivation.

These are the functions the dry-run lowers and the trainer executes; the
sharding rules (DESIGN.md §6) live in ``repro.distributed.sharding`` and are
resolved against whatever mesh is active.
"""

from __future__ import annotations


import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import zoo
from repro.optim import accum, adamw

__all__ = [
    "make_train_step", "make_prefill_step", "make_serve_step",
    "batch_pspecs", "cache_pspecs", "state_shardings",
]


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig, *,
                    n_micro: int = 1, remat: str = "dots"):
    def train_step(params, opt_state, batch):
        def loss_f(p, b):
            return zoo.loss_fn(cfg, p, b, remat=remat)
        loss, aux, grads = accum.accumulate_grads(loss_f, params, batch, n_micro)
        new_params, new_opt, metrics = adamw.update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, loss=loss, **aux)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, *, cache_len: int):
    def prefill_step(params, batch):
        return zoo.prefill(cfg, params, batch, cache_len=cache_len)

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, caches, token, pos):
        return zoo.decode_step(cfg, params, caches, token, pos)

    return serve_step


# ---------------------------------------------------------------------------
# sharding derivation
# ---------------------------------------------------------------------------


def _div_axes(size: int, names: tuple[str, ...], mesh) -> tuple[str, ...]:
    """Use the given mesh axes only if ``size`` divides evenly across them."""
    prod = 1
    chosen = []
    for n in names:
        if n in mesh.axis_names:
            prod *= mesh.shape[n]
            chosen.append(n)
    if chosen and size % prod == 0:
        return tuple(chosen)
    return ()


def batch_pspecs(cfg: ArchConfig, shape: ShapeConfig, mesh) -> dict:
    """PartitionSpecs for the input batch of this cell."""
    b = shape.global_batch
    baxes = _div_axes(b, ("pod", "data"), mesh) or None
    if isinstance(baxes, tuple) and len(baxes) == 1:
        baxes = baxes[0]
    specs: dict = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = P(baxes, None)
        if shape.kind == "train":
            specs["labels"] = P(baxes, None)
    else:
        specs["token"] = P(baxes)
        specs["pos"] = P(baxes)
        specs["caches"] = cache_pspecs(
            zoo.init_cache, cfg, b, shape.seq_len, mesh)
    if cfg.frontend == "vision" and shape.kind != "decode":
        specs["patches"] = P(baxes, None, None)
    if cfg.encoder_decoder and shape.kind != "decode":
        specs["frames"] = P(baxes, None, None)
    return specs


def cache_pspecs(init_cache_fn, cfg, batch: int, seq_len: int, mesh):
    """Per-leaf cache specs: batch over (pod, data) when divisible; the KV
    sequence dim over 'model' (SP); recurrent states batch-sharded only."""
    baxes = _div_axes(batch, ("pod", "data"), mesh) or None
    if isinstance(baxes, tuple) and len(baxes) == 1:
        baxes = baxes[0]
    shapes = jax.eval_shape(lambda: init_cache_fn(cfg, batch, seq_len))

    def leaf_spec(x):
        nd = len(x.shape)
        # identify dims: leading may be n_groups (stacked); batch dim equals
        # `batch`; a long dim (> 1024) is the kv-seq dim.
        parts = [None] * nd
        for i, s in enumerate(x.shape):
            if s == batch and parts.count(baxes) == 0 and baxes is not None:
                parts[i] = baxes
            elif s >= 4096 and s % mesh.shape.get("model", 1) == 0 \
                    and "model" not in parts:
                parts[i] = "model"
        return P(*parts)

    return jax.tree.map(leaf_spec, shapes)


def state_shardings(cfg: ArchConfig, mesh, *, fsdp, with_opt: bool):
    """(param ShapeDtypeStructs, param NamedShardings[, opt structs/shardings])."""
    with shd.use_mesh(mesh, fsdp=fsdp):
        aparams, axes = zoo.abstract_params(cfg)
        pspecs = shd.params_pspecs(aparams, axes)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    if not with_opt:
        return aparams, pshard, None, None
    aopt = jax.eval_shape(adamw.init, aparams)
    ospecs = adamw.state_pspecs(pspecs)
    oshard = jax.tree.map(
        lambda s: NamedSharding(mesh, s), ospecs,
        is_leaf=lambda x: isinstance(x, P))
    return aparams, pshard, aopt, oshard
