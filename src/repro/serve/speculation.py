"""Self-speculative drafting for the packed serving stream.

``NGramDrafter`` is a deterministic prompt-lookup / n-gram drafter
(no second model): per slot it maintains the token history — prompt +
every *accepted* output token — and a suffix-keyed table mapping each
n-gram (n in ``[ngram_min, ngram_max]``) to the position right after its
two most recent occurrences.  ``propose(slot, k)`` matches the longest
suffix of the current history against the table and copies up to ``k``
tokens that followed the previous occurrence.  Pure host-side data
structure: no RNG, no device work — identical inputs always produce
identical drafts, which is what makes the engine's acceptance rule
token-identity-preserving end to end.

The engine verifies drafts with greedy acceptance: a drafted token is
kept iff it equals the model's own argmax at that position, so the
drafter is purely a *performance* hint — a bad draft costs verify lanes,
never correctness.

``markov_params`` crafts model weights whose greedy decode follows an
explicit token->token map (blocks zeroed out, the head wired to the
normalized embedding rows).  Benchmarks and tests use it to build
acceptance *regimes* on demand — fully-predictable (repetitive /
code-like) and adversarial (drafts always rejected) workloads — through
the real engine, kernels, and acceptance rule.
"""

from __future__ import annotations

import numpy as np

__all__ = ["NGramDrafter", "markov_params"]


class _SlotHistory:
    """Token history + suffix-keyed n-gram table for one slot."""

    __slots__ = ("toks", "table")

    def __init__(self, ngram_min: int, ngram_max: int) -> None:
        self.toks: list[int] = []
        # per n: key (n-gram tuple) -> (latest end-position, previous one).
        # The entry for the *current* tail always holds the tail itself in
        # slot 0, so ``propose`` reads the previous occurrence from slot 1.
        self.table: dict[int, dict[tuple, tuple[int, int | None]]] = {
            n: {} for n in range(ngram_min, ngram_max + 1)}

    def append(self, tok: int) -> None:
        self.toks.append(tok)
        end = len(self.toks)
        for n, tab in self.table.items():
            if end < n:
                continue
            key = tuple(self.toks[end - n:end])
            old = tab.get(key)
            tab[key] = (end, old[0] if old is not None else None)


class NGramDrafter:
    """Deterministic suffix-match drafter over prompt + accepted output."""

    def __init__(self, ngram_max: int = 3, ngram_min: int = 1) -> None:
        if not 1 <= ngram_min <= ngram_max:
            raise ValueError(f"bad n-gram range [{ngram_min}, {ngram_max}]")
        self.ngram_min = int(ngram_min)
        self.ngram_max = int(ngram_max)
        self._slots: dict[int, _SlotHistory] = {}

    # -- lifecycle (engine slot protocol) ---------------------------------

    def begin(self, slot: int, req) -> None:
        """(Re)seed a slot's history from a request's prompt."""
        h = _SlotHistory(self.ngram_min, self.ngram_max)
        for t in np.asarray(req.prompt).tolist():
            h.append(int(t))
        self._slots[slot] = h

    def extend(self, slot: int, toks) -> None:
        """Record newly *accepted* (emitted) tokens for a slot."""
        h = self._slots.get(slot)
        if h is None:
            return
        for t in np.asarray(toks).tolist():
            h.append(int(t))

    def drop(self, slot: int) -> None:
        """Forget a slot (finish, preemption, requeue)."""
        self._slots.pop(slot, None)

    # -- drafting ----------------------------------------------------------

    def propose(self, slot: int, k: int) -> np.ndarray:
        """Up to ``k`` draft tokens continuing the slot's history.

        Longest-suffix match wins; within one n the most recent previous
        occurrence wins.  Returns an empty array when no suffix of the
        history has occurred before.
        """
        h = self._slots.get(slot)
        if h is None or k <= 0:
            return np.zeros(0, np.int32)
        end = len(h.toks)
        for n in range(self.ngram_max, self.ngram_min - 1, -1):
            if end < n:
                continue
            hit = h.table[n].get(tuple(h.toks[end - n:end]))
            if hit is None:
                continue
            # slot 0 is the tail itself (registered on append); the draft
            # source is the *previous* occurrence
            src = hit[1] if hit[0] == end else hit[0]
            if src is None or src >= end:
                continue
            d = min(k, end - src)
            return np.asarray(h.toks[src:src + d], np.int32)
        return np.zeros(0, np.int32)


# --------------------------------------------------------------------------
# crafted-weight fixture: a model whose greedy decode IS a token map
# --------------------------------------------------------------------------

def markov_params(cfg, params, mapping: dict[int, int]):
    """Craft ``params`` so greedy decode emits ``mapping[last_token]``.

    Every residual-block contribution is zeroed (attention ``wo`` and MLP
    ``w_down``), so the final hidden state of a position is exactly the
    normalized embedding of its token; the (untied) head is then wired so
    ``argmax(logits(t)) == mapping[t]`` for every token in the map.  The
    result runs through the real forward pass / kernels — only the
    *content* of the weights is synthetic.  Requires a dense
    attention+MLP arch with ``tie_embeddings=False``; raises if any
    mapped token's argmax cannot be verified.
    """
    import jax.numpy as jnp
    import jax.tree_util as jtu

    from ..models import layers

    if cfg.tie_embeddings:
        raise ValueError("markov_params needs an untied head")

    flat, treedef = jtu.tree_flatten_with_path(params)
    leaves = []
    for path, leaf in flat:
        last = getattr(path[-1], "key", None)
        if last in ("wo", "w_down"):
            leaf = jnp.zeros_like(leaf)
        leaves.append(leaf)
    out = jtu.tree_unflatten(treedef, leaves)

    emb = jnp.asarray(out["embed"])
    en = np.asarray(layers.apply_norm(
        cfg.norm, {"scale": out["ln_f"]["scale"]}, emb))
    v, d = en.shape
    head = np.zeros((d, v), np.float32)
    for t, j in mapping.items():
        head[:, j] += en[t] / float(en[t] @ en[t])
    logits = en @ head
    bad = [t for t, j in mapping.items() if int(np.argmax(logits[t])) != j]
    if bad:
        raise ValueError(f"embedding cross-talk broke the map at {bad}")
    out = dict(out)
    out["head"] = jnp.asarray(head)
    return out
