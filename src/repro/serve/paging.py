"""Physical block-table allocator behind the paged KV cache.

Where :class:`~repro.serve.kv_cache.KVBlockPool` is a purely logical byte
ledger over a dense ``[max_batch, cache_len]`` cache, this allocator manages
a *real* resource: the identifier space of a physical block store
(``[capacity, kv_heads, block_tokens, head_dim]`` device arrays per
attention layer, owned by the engine).  ``serve.kv_block_budget`` therefore
actuates HBM, not a number.

The allocation surface is the :class:`KVLease` handle API:

  * :meth:`PagedKVAllocator.lease` reserves a per-sequence **block table**
    (physical block ids, drawn LIFO from a free list) covering the
    sequence's full extent — no cache-tree copy, no movement of other
    sequences' blocks (copy-free admission);
  * blocks are **refcounted**: a lease may adopt already-live blocks
    (``shared=``, the prefix cache's sharing path) or :meth:`KVLease.fork`
    an existing lease wholesale — either way the physical block is stored
    once and counted once;
  * the first write into a shared block must go through
    :meth:`KVLease.writable`, which resolves **copy-on-write**: every
    shared block overlapping the write span is re-homed to a fresh block
    and the ``(src, dst)`` pairs are returned for the engine to apply as a
    device-side block copy (``models/transformer.copy_paged_blocks``);
  * :meth:`KVLease.release` decrements; a block returns to the free list
    only when its last reference drops — which is what makes preemption
    COW-safe (a preempted borrower cannot free prefix blocks the cache
    still holds);
  * :meth:`KVLease.trim_front` drops a lease's leading blocks (interior
    ``-1`` table entries are masked by every paged kernel), the block-level
    sliding-window eviction path for all-window archs;
  * :meth:`KVLease.truncate` drops trailing blocks beyond a token extent —
    the speculative-decode finish path, which cuts rejected-draft K/V out
    of the lease before the prefix cache may adopt its blocks;
  * shrinking the budget below occupancy reports ``over_budget`` — the
    engine evicts cold cache prefixes, preempts lowest-priority sequences
    (paper §4.2 temporary-inconsistency semantics), then physically resizes
    the store via :meth:`compact` / :meth:`grow`.  ``remap_hook`` lets a
    block-id holder outside the lease registry (the prefix cache) follow a
    compaction's renumbering.

The accountant entry ``kv_cache`` tracks the *store capacity* — the bytes
the block store actually pins in HBM — so budget cuts move ``hbm_bytes``
itself, not just a ledger.  All bookkeeping is O(blocks touched); a failed
:meth:`lease` / :meth:`KVLease.extend` changes neither tables nor ledger.
"""

from __future__ import annotations

from collections.abc import Callable, Sequence

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.sensors import HBMAccountant
from .kv_cache import kv_bytes_per_token

__all__ = ["KVLease", "PagedKVAllocator"]


class KVLease:
    """A refcounted claim on an ordered list of physical KV blocks.

    ``blocks[i]`` holds the lease's logical tokens ``[i*T, (i+1)*T)``; a
    ``-1`` entry marks a position whose block was trimmed
    (:meth:`trim_front`) — every paged kernel masks it.  The lease owns one
    reference per live block; sharing (``fork`` / the allocator's
    ``shared=`` adoption) adds references, never copies.  All mutation goes
    through the owning allocator so refcounts, the free list, and the HBM
    ledger can never disagree with the tables.
    """

    __slots__ = ("_alloc", "lease_id", "blocks", "tokens", "released")

    def __init__(self, alloc: "PagedKVAllocator", lease_id: int,
                 blocks: list[int], tokens: int) -> None:
        self._alloc = alloc
        self.lease_id = lease_id
        self.blocks = blocks          # -1 = trimmed front position
        self.tokens = tokens          # logical token extent covered
        self.released = False

    # ------------------------------------------------------------- queries
    @property
    def live_blocks(self) -> int:
        return sum(1 for b in self.blocks if b >= 0)

    def table_row(self) -> np.ndarray:
        """[max_blocks_per_seq] int32 physical ids, -1-padded — one row of
        the device block-table operand (trimmed positions stay -1)."""
        row = np.full((self._alloc.max_blocks_per_seq,), -1, np.int32)
        if self.blocks:
            row[:len(self.blocks)] = self.blocks
        return row

    def refcount(self, i: int) -> int:
        """Reference count of the block at table position ``i`` (0 for a
        trimmed position) — test/diagnostic surface."""
        b = self.blocks[i]
        return 0 if b < 0 else self._alloc._refs[b]

    # ------------------------------------------------------------ mutation
    def extend(self, tokens: int) -> bool:
        """Grow to cover ``tokens`` logical tokens (fresh blocks appended);
        False — with no state change — if the budget or free list blocks
        it."""
        return self._alloc._extend(self, tokens)

    def fork(self) -> "KVLease":
        """A new lease sharing every live block (one new reference each).
        Writers must go through :meth:`writable` before touching a shared
        block."""
        return self._alloc._fork(self)

    def writable(self, lo_tok: int, hi_tok: int) -> list[tuple[int, int]]:
        """Make the token span ``[lo_tok, hi_tok)`` safe to write: every
        shared block (refcount > 1) overlapping it is re-homed to a fresh
        private block.  Returns the ``(src, dst)`` physical-id pairs the
        caller must apply as a device block copy *before* writing, or
        ``None`` if the free list cannot supply the copies (no state
        change)."""
        return self._alloc._writable(self, lo_tok, hi_tok)

    def trim_front(self, first_keep_block: int) -> int:
        """Release blocks at table positions ``< first_keep_block``
        (sliding-window eviction); their entries become ``-1``.  Returns
        the number of references dropped."""
        return self._alloc._trim_front(self, first_keep_block)

    def truncate(self, tokens: int) -> int:
        """Shrink the lease to cover at most ``tokens`` logical tokens,
        releasing whole trailing blocks past that extent (the
        speculative-decode finish path: rejected-draft K/V lives past the
        last emitted token and must not survive into the prefix cache).
        Returns the number of references dropped."""
        return self._alloc._truncate(self, tokens)

    def release(self) -> None:
        """Drop the lease's references; idempotent.  Blocks whose count
        hits zero return to the free list (LIFO)."""
        self._alloc._release(self)


class PagedKVAllocator:
    """Refcounting free-list allocator over ``capacity`` physical KV blocks.

    Exposes the budget/occupancy surface the engine's SmartConf wiring
    consumes (``set_budget`` / ``used_blocks`` / ``alloc_failures`` /
    ``over_budget`` / ``frag_tokens``), the :class:`KVLease` handle API
    (``lease`` / ``incref_blocks`` / ``decref_blocks``), and the
    physical-side API (``compact`` / ``grow`` + ``remap_hook``).  The
    :class:`KVLease` handle API is the only allocation surface — the
    seed's seq_id-keyed ``ensure`` / ``free`` / ``table_row`` shim is gone.
    """

    def __init__(self, cfg: ArchConfig, *, block_tokens: int,
                 max_blocks_per_seq: int, capacity_blocks: int,
                 budget_blocks: int | None = None,
                 accountant: HBMAccountant | None = None) -> None:
        self.cfg = cfg
        self.block_tokens = block_tokens
        self.block_bytes = kv_bytes_per_token(cfg) * block_tokens
        self.max_blocks_per_seq = max_blocks_per_seq
        self.accountant = accountant
        self.capacity = int(capacity_blocks)
        # SmartConf budget (logical threshold; capacity tracks it physically)
        self.max_blocks = int(budget_blocks if budget_blocks is not None
                              else capacity_blocks)
        self._free: list[int] = list(range(self.capacity - 1, -1, -1))
        self._refs: list[int] = [0] * self.capacity
        self._leases: dict[int, KVLease] = {}
        self._next_lease = 0
        # blocks referenced from outside the lease registry (the prefix
        # cache) follow a compaction's renumbering through this hook
        self.remap_hook: Callable[[dict[int, int]], None] | None = None
        self.alloc_failures = 0
        self._charge_capacity()

    # ----------------------------------------------------------- accounting
    def _charge_capacity(self) -> None:
        if self.accountant is not None:
            self.accountant.set("kv_cache", self.capacity * self.block_bytes)

    @property
    def used_blocks(self) -> int:
        """Physical blocks holding live data.  A block shared by N leases
        (or N-1 leases + the prefix cache) counts ONCE — sharing is the
        capacity multiplier."""
        return self.capacity - len(self._free)

    @property
    def used_bytes(self) -> int:
        return self.used_blocks * self.block_bytes

    @property
    def live_seqs(self) -> int:
        return len(self._leases)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def over_budget(self) -> bool:
        """Occupancy above the SmartConf budget (tolerated, §4.2) — the
        engine's eviction/preemption trigger."""
        return self.used_blocks > self.max_blocks

    @property
    def frag_tokens(self) -> int:
        """Allocated-but-unused tail tokens across live leases (internal
        fragmentation of the last block plus up-front reservation).
        Trimmed positions carry no allocation, so they contribute none."""
        t = self.block_tokens
        total = 0
        for ls in self._leases.values():
            trimmed = len(ls.blocks) - ls.live_blocks
            total += max(0, ls.live_blocks * t - (ls.tokens - trimmed * t))
        return total

    # --------------------------------------------------------------- budget
    def set_budget(self, max_blocks: int) -> None:
        """Threshold update only; physical enforcement (cache eviction,
        preemption, store resize) is the engine's job because it owns
        slots, the cache tree, and the device arrays."""
        self.max_blocks = max(1, int(max_blocks))

    # ------------------------------------------------------------ refcounts
    def incref_blocks(self, blocks: Sequence[int]) -> None:
        """Add one reference per block id (the prefix cache's adoption
        path; ids must already be live)."""
        for b in blocks:
            if self._refs[b] <= 0:
                raise ValueError(f"incref of dead block {b}")
            self._refs[b] += 1

    def decref_blocks(self, blocks: Sequence[int]) -> int:
        """Drop one reference per block id; blocks hitting zero return to
        the free list.  Returns how many became free."""
        freed = 0
        for b in reversed(list(blocks)):   # LIFO: keep low ids warm
            self._refs[b] -= 1
            if self._refs[b] == 0:
                self._free.append(b)
                freed += 1
            elif self._refs[b] < 0:
                raise ValueError(f"refcount underflow on block {b}")
        return freed

    # ------------------------------------------------------------ lease API
    def lease(self, tokens: int,
              shared: Sequence[int] | None = None) -> KVLease | None:
        """A new lease covering ``tokens`` logical tokens.  ``shared``
        (optional) is an ordered prefix of already-live block ids to adopt
        — they are incref'd, not copied, and do not consume budget again.
        Returns ``None`` (with no state change, counted in
        ``alloc_failures``) if the budget or free list cannot supply the
        non-shared remainder."""
        tokens = min(tokens, self.max_blocks_per_seq * self.block_tokens)
        need = (tokens + self.block_tokens - 1) // self.block_tokens
        adopt = list(shared) if shared else []
        if len(adopt) > need:
            adopt = adopt[:need]
        fresh = need - len(adopt)
        if (self.used_blocks + fresh > self.max_blocks
                or fresh > len(self._free)):
            self.alloc_failures += 1
            return None
        self.incref_blocks(adopt)
        blocks = adopt + [self._alloc_block() for _ in range(fresh)]
        ls = KVLease(self, self._next_lease, blocks, tokens)
        self._next_lease += 1
        self._leases[ls.lease_id] = ls
        return ls

    def _alloc_block(self) -> int:
        b = self._free.pop()
        self._refs[b] = 1
        return b

    def _extend(self, ls: KVLease, tokens: int) -> bool:
        if ls.released:
            raise ValueError("extend on released lease")
        tokens = min(tokens, self.max_blocks_per_seq * self.block_tokens)
        need = (tokens + self.block_tokens - 1) // self.block_tokens
        delta = need - len(ls.blocks)
        if delta <= 0:
            ls.tokens = max(ls.tokens, tokens)
            return True
        if (self.used_blocks + delta > self.max_blocks
                or delta > len(self._free)):
            self.alloc_failures += 1
            return False
        ls.blocks.extend(self._alloc_block() for _ in range(delta))
        ls.tokens = max(ls.tokens, tokens)
        return True

    def _fork(self, ls: KVLease) -> KVLease:
        if ls.released:
            raise ValueError("fork of released lease")
        self.incref_blocks([b for b in ls.blocks if b >= 0])
        child = KVLease(self, self._next_lease, list(ls.blocks), ls.tokens)
        self._next_lease += 1
        self._leases[child.lease_id] = child
        return child

    def _writable(self, ls: KVLease, lo_tok: int,
                  hi_tok: int) -> list[tuple[int, int]] | None:
        if ls.released:
            raise ValueError("writable on released lease")
        t = self.block_tokens
        lo = max(0, lo_tok) // t
        hi = min(len(ls.blocks), (max(lo_tok, hi_tok) + t - 1) // t)
        cow = [i for i in range(lo, hi)
               if ls.blocks[i] >= 0 and self._refs[ls.blocks[i]] > 1]
        if not cow:
            return []
        if len(cow) > len(self._free):
            self.alloc_failures += 1
            return None
        pairs = []
        for i in cow:
            src = ls.blocks[i]
            dst = self._alloc_block()
            self._refs[src] -= 1          # shared: never hits zero here
            ls.blocks[i] = dst
            pairs.append((src, dst))
        return pairs

    def _trim_front(self, ls: KVLease, first_keep_block: int) -> int:
        if ls.released:
            raise ValueError("trim_front on released lease")
        drop = [b for b in ls.blocks[:first_keep_block] if b >= 0]
        if not drop:
            return 0
        for i in range(min(first_keep_block, len(ls.blocks))):
            ls.blocks[i] = -1
        self.decref_blocks(drop)
        return len(drop)

    def _truncate(self, ls: KVLease, tokens: int) -> int:
        if ls.released:
            raise ValueError("truncate on released lease")
        tokens = max(0, int(tokens))
        keep = (tokens + self.block_tokens - 1) // self.block_tokens
        drop = [b for b in ls.blocks[keep:] if b >= 0]
        del ls.blocks[keep:]
        ls.tokens = min(ls.tokens, tokens)
        if drop:
            self.decref_blocks(drop)
        return len(drop)

    def _release(self, ls: KVLease) -> None:
        if ls.released:
            return
        ls.released = True
        self._leases.pop(ls.lease_id, None)
        self.decref_blocks([b for b in ls.blocks if b >= 0])

    # ------------------------------------------------------ physical resize
    def compact(self, new_capacity: int) -> np.ndarray:
        """Shrink to ``new_capacity`` blocks.  Live blocks are renumbered
        densely into ``[0, used_blocks)`` — each block once, however many
        references it holds (lease tables updated in place; external
        holders via ``remap_hook``); returns ``keep`` — old physical ids,
        one per new slot — for the engine to gather the store arrays with
        (``new_store = old_store[keep]``)."""
        if not self.used_blocks <= new_capacity <= self.capacity:
            raise ValueError(
                f"compact({new_capacity}) with used={self.used_blocks} "
                f"capacity={self.capacity}")
        keep = np.zeros((new_capacity,), np.int32)   # unused slots -> old 0
        mapping: dict[int, int] = {}
        nxt = 0
        refs = [0] * int(new_capacity)

        def renumber(old: int) -> int:
            nonlocal nxt
            new = mapping.get(old)
            if new is None:
                new = mapping[old] = nxt
                keep[new] = old
                nxt += 1
            return new

        for lease_id in sorted(self._leases):
            ls = self._leases[lease_id]
            for j, old in enumerate(ls.blocks):
                if old >= 0:
                    ls.blocks[j] = renumber(old)
        # blocks held only outside the lease registry (the prefix cache)
        for old, r in enumerate(self._refs):
            if r > 0 and old not in mapping:
                renumber(old)
        if self.remap_hook is not None:
            self.remap_hook(dict(mapping))
        for old, new in mapping.items():
            refs[new] = self._refs[old]
        self.capacity = int(new_capacity)
        self._refs = refs
        self._free = list(range(new_capacity - 1, nxt - 1, -1))
        self._charge_capacity()
        return keep

    def grow(self, new_capacity: int) -> int:
        """Extend the id space; returns the number of blocks added.  The
        engine zero-pads the store arrays to match."""
        if new_capacity < self.capacity:
            raise ValueError(f"grow({new_capacity}) below {self.capacity}")
        added = int(new_capacity) - self.capacity
        self._free[:0] = range(int(new_capacity) - 1, self.capacity - 1, -1)
        self._refs.extend([0] * added)
        self.capacity = int(new_capacity)
        self._charge_capacity()
        return added
