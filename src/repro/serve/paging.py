"""Physical block-table allocator behind the paged KV cache.

Where :class:`~repro.serve.kv_cache.KVBlockPool` is a purely logical byte
ledger over a dense ``[max_batch, cache_len]`` cache, this allocator manages
a *real* resource: the identifier space of a physical block store
(``[capacity, kv_heads, block_tokens, head_dim]`` device arrays per
attention layer, owned by the engine).  ``serve.kv_block_budget`` therefore
actuates HBM, not a number:

  * admission reserves a per-sequence **block table** (physical block ids,
    drawn LIFO from a free list) covering the sequence's full extent — no
    cache-tree copy, no movement of other sequences' blocks (copy-free
    admission);
  * ``free`` returns the ids; the next admission reuses them;
  * shrinking the budget below occupancy reports ``over_budget`` — the
    engine preempts lowest-priority sequences back to the queue (paper §4.2
    temporary-inconsistency semantics) and then physically resizes the
    store via :meth:`compact` / :meth:`grow`.

The accountant entry ``kv_cache`` tracks the *store capacity* — the bytes
the block store actually pins in HBM — so budget cuts move ``hbm_bytes``
itself, not just a ledger.  All bookkeeping is O(blocks touched); a failed
:meth:`ensure` changes neither the tables nor the ledger.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.sensors import HBMAccountant
from .kv_cache import kv_bytes_per_token

__all__ = ["PagedKVAllocator"]


class PagedKVAllocator:
    """Free-list allocator over ``capacity`` physical KV blocks.

    Exposes the same budget/occupancy surface as ``KVBlockPool``
    (``ensure`` / ``free`` / ``set_budget`` / ``used_blocks`` /
    ``alloc_failures`` / ``over_budget`` / ``frag_tokens``) so the engine's
    SmartConf wiring is mode-agnostic, plus the physical-side API
    (``table_row`` / ``compact`` / ``grow``).
    """

    def __init__(self, cfg: ArchConfig, *, block_tokens: int,
                 max_blocks_per_seq: int, capacity_blocks: int,
                 budget_blocks: int | None = None,
                 accountant: HBMAccountant | None = None) -> None:
        self.cfg = cfg
        self.block_tokens = block_tokens
        self.block_bytes = kv_bytes_per_token(cfg) * block_tokens
        self.max_blocks_per_seq = max_blocks_per_seq
        self.accountant = accountant
        self.capacity = int(capacity_blocks)
        # SmartConf budget (logical threshold; capacity tracks it physically)
        self.max_blocks = int(budget_blocks if budget_blocks is not None
                              else capacity_blocks)
        self._free: list[int] = list(range(self.capacity - 1, -1, -1))
        self._tables: dict[int, list[int]] = {}
        self._tokens: dict[int, int] = {}
        self.used_blocks = 0
        self.alloc_failures = 0
        self._charge_capacity()

    # ----------------------------------------------------------- accounting
    def _charge_capacity(self) -> None:
        if self.accountant is not None:
            self.accountant.set("kv_cache", self.capacity * self.block_bytes)

    @property
    def used_bytes(self) -> int:
        return self.used_blocks * self.block_bytes

    @property
    def live_seqs(self) -> int:
        return len(self._tables)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def over_budget(self) -> bool:
        """Occupancy above the SmartConf budget (tolerated, §4.2) — the
        engine's preemption trigger."""
        return self.used_blocks > self.max_blocks

    @property
    def frag_tokens(self) -> int:
        """Allocated-but-unused tail tokens across live sequences (internal
        fragmentation of the last block plus up-front reservation)."""
        return sum(len(t) * self.block_tokens - self._tokens[s]
                   for s, t in self._tables.items())

    # --------------------------------------------------------------- budget
    def set_budget(self, max_blocks: int) -> None:
        """Threshold update only; physical enforcement (preemption + store
        resize) is the engine's job because it owns slots and device arrays."""
        self.max_blocks = max(1, int(max_blocks))

    # ----------------------------------------------------------- allocation
    def ensure(self, seq_id: int, tokens: int) -> bool:
        """Grow ``seq_id``'s table to cover ``tokens`` logical tokens; False
        (with no state change) if the budget or the free list blocks it."""
        tokens = min(tokens, self.max_blocks_per_seq * self.block_tokens)
        need = (tokens + self.block_tokens - 1) // self.block_tokens
        table = self._tables.get(seq_id)
        have = len(table) if table is not None else 0
        delta = need - have
        if delta <= 0:
            self._tokens[seq_id] = max(self._tokens.get(seq_id, 0), tokens)
            return True
        if (self.used_blocks + delta > self.max_blocks
                or delta > len(self._free)):
            self.alloc_failures += 1
            return False
        if table is None:
            table = self._tables[seq_id] = []
        table.extend(self._free.pop() for _ in range(delta))
        self.used_blocks += delta
        self._tokens[seq_id] = max(self._tokens.get(seq_id, 0), tokens)
        return True

    def free(self, seq_id: int) -> None:
        table = self._tables.pop(seq_id, None)
        self._tokens.pop(seq_id, None)
        if table is None:
            return
        self.used_blocks -= len(table)
        self._free.extend(reversed(table))   # LIFO reuse keeps ids warm

    def table_row(self, seq_id: int) -> np.ndarray:
        """[max_blocks_per_seq] int32 physical ids, -1-padded — one row of
        the device block-table operand."""
        row = np.full((self.max_blocks_per_seq,), -1, np.int32)
        table = self._tables.get(seq_id)
        if table:
            row[:len(table)] = table
        return row

    # ------------------------------------------------------ physical resize
    def compact(self, new_capacity: int) -> np.ndarray:
        """Shrink to ``new_capacity`` blocks.  Live blocks are renumbered
        densely into ``[0, used_blocks)`` (tables updated in place); returns
        ``keep`` — old physical ids, one per new slot — for the engine to
        gather the store arrays with (``new_store = old_store[keep]``)."""
        if not self.used_blocks <= new_capacity <= self.capacity:
            raise ValueError(
                f"compact({new_capacity}) with used={self.used_blocks} "
                f"capacity={self.capacity}")
        keep = np.zeros((new_capacity,), np.int32)   # unused slots -> old 0
        nxt = 0
        for seq_id in sorted(self._tables):
            table = self._tables[seq_id]
            for j, old in enumerate(table):
                keep[nxt] = old
                table[j] = nxt
                nxt += 1
        self.capacity = int(new_capacity)
        self._free = list(range(new_capacity - 1, nxt - 1, -1))
        self._charge_capacity()
        return keep

    def grow(self, new_capacity: int) -> int:
        """Extend the id space; returns the number of blocks added.  The
        engine zero-pads the store arrays to match."""
        if new_capacity < self.capacity:
            raise ValueError(f"grow({new_capacity}) below {self.capacity}")
        added = int(new_capacity) - self.capacity
        self._free[:0] = range(int(new_capacity) - 1, self.capacity - 1, -1)
        self.capacity = int(new_capacity)
        self._charge_capacity()
        return added
