"""SmartConf-routed data-parallel replica serving.

Layer 3 of mesh serving: :class:`ReplicaRouter` fronts N independent
:class:`~repro.serve.engine.ServeEngine` replicas behind the ONE driver
surface ``OpenLoopDriver`` already speaks (``note_arrival`` / ``submit`` /
``tick`` / ``charge_tick_cost`` plus the summary properties), so every
existing harness — open-loop traffic, chaos, telemetry, the SLO bench —
composes with replication unchanged.

Dispatch is **weighted least-loaded**: a new request goes to the live
replica minimizing ``(pending_tokens + 1) / weight``.  With equal weights
that is plain least-loaded; the weights are where the paper's control loop
enters.  Each replica ``i`` carries a direct PerfConf
``route.replica_weights[i]`` on that replica's TTFT-p99 (hard goal =
``slo.ttft_s``): a replica whose tail latency blows the SLO — a straggler
device, a chaos storm, a noisy co-tenant — has its weight driven down, so
new work drains toward healthy replicas *while the SLO pressure lasts* and
recovers when it clears.  A static split cannot do both sides of that
trade-off, which is exactly the §6 regime-shift argument at replica
granularity.  The sensor is the router's own censored read (max of the
replica's controller TTFT-p99 and its head-of-line wait), so a *stalled*
replica — one that is not even ticking — still shows rising pressure; the
read passes through the router's ``sensor_tap`` (chaos NaN/spike/dropout
injection) and the SmartConf guardrails absorb whatever comes back, with
per-weight last-known-good fallback after repeated insanity.

Replica loss composes with :class:`~repro.distributed.fault_tolerance.
PreemptionHandler`: when a replica's preemption flag trips, the router
runs its drain tick (the engine requeues in-flight work itself), then
**takes** the parked requests off the dead replica (:meth:`ServeEngine.
take_drained` — off its ledger too, so a rejoin cannot double-serve) and
resubmits them to the survivors.  When the flag clears the replica rejoins
the dispatch set and its weight controller resumes from wherever the
error history left it.

Virtual-time cost: replicas tick concurrently in a real deployment, so the
merged per-tick stats carry the **max-cost** replica's work fields (what
the driver's :class:`~repro.serve.traffic.TickCostModel` charges — the
slowest replica sets the tick's wall time) while throughput/bookkeeping
fields sum across replicas.
"""

from __future__ import annotations

import time
from typing import Callable, Sequence

from repro.core import ControllerModel, GoalSpec, Guardrails, SmartConf
from repro.core.smartconf import ConfRegistry
from .engine import Request, ServeEngine, TICK_STATS_KEYS
from .options import SLOSpec
from .traffic import TickCostModel

__all__ = ["ReplicaRouter"]

# merged-stats policy: these fields describe the tick's *compute cost* and
# come from the max-cost replica (concurrent replicas: the slowest one sets
# the tick's wall time); everything countable sums; the rest is max/any.
_COST_KEYS = ("pad_fraction", "dispatches", "prefill_tokens",
              "prefill_issued_tokens", "decode_slots", "spec_lanes",
              "spec_depth", "accept_rate", "kv_cache_share")
_SUM_KEYS = ("queued", "waiting", "running", "finished", "tokens", "hbm",
             "packed_segments", "decode_tokens", "kv_used_blocks",
             "kv_budget_blocks", "kv_capacity_blocks", "kv_frag_tokens",
             "preemptions", "rejected", "slo_good_tokens", "slo_miss_tokens",
             "prefix_hit_tokens", "prefix_cache_blocks")


class ReplicaRouter:
    """Weighted-least-loaded dispatch over N ServeEngine replicas.

    Parameters
    ----------
    engines:
        The replicas.  Each keeps its own queues, KV store, controllers
        and telemetry; the router never reaches into a tick.
    slo:
        TTFT goal for the per-replica weight controllers.  ``None`` (or
        ``adaptive=False``) freezes every weight at 1.0 — the static
        least-loaded baseline the bench compares against.
    stall:
        Optional chaos hook ``stall(tick) -> replica index | None``: the
        returned replica skips its tick this round (a stalled worker —
        queue builds, TTFT rises, the adaptive weights route around it).
    weights:
        Initial (and, when not adaptive, permanent) per-replica weights.
    """

    def __init__(self, engines: Sequence[ServeEngine], *,
                 clock: Callable[[], float] = time.monotonic,
                 slo: SLOSpec | None = None,
                 adaptive: bool = True,
                 weights: Sequence[float] | None = None,
                 weight_max: float = 8.0,
                 registry: ConfRegistry | None = None,
                 telemetry=None,
                 cost_model: TickCostModel | None = None,
                 stall: Callable[[int], int | None] | None = None) -> None:
        if not engines:
            raise ValueError("ReplicaRouter needs at least one engine")
        self.engines = list(engines)
        self.clock = clock
        self.slo = slo
        self.stall = stall
        self.cost_model = cost_model or TickCostModel()
        self.registry = registry or ConfRegistry()
        self.telemetry = telemetry
        self.sensor_tap: Callable[[str, float], float] | None = None
        n = len(self.engines)
        self.weights = [float(w) for w in weights] if weights is not None \
            else [1.0] * n
        if len(self.weights) != n:
            raise ValueError(f"{len(self.weights)} weights for {n} replicas")
        self.adaptive = bool(adaptive and slo is not None)
        self._sc_weights: list[SmartConf | None] = [None] * n
        if self.adaptive:
            rails = Guardrails(perf_lo=0.0, perf_hi=3600.0,
                               max_step=weight_max / 4.0)
            for i in range(n):
                # alpha > 0: more weight -> more traffic -> higher TTFT,
                # so a replica past the (hard) SLO goal sheds weight and a
                # healthy one earns it back.  Continuous in
                # [0.05, weight_max]: a replica never reaches exactly 0
                # (the controller keeps a probe trickle to see recovery).
                self._sc_weights[i] = SmartConf(
                    f"route.replica_weights[{i}]", metric="ttft_p99_s",
                    goal=GoalSpec(float(slo.ttft_s), hard=True),
                    initial=self.weights[i], registry=self.registry,
                    guardrails=rails,
                    model=ControllerModel(alpha=0.5 * float(slo.ttft_s),
                                          lam=0.1, delta=1.3,
                                          conf_min=0.05,
                                          conf_max=float(weight_max),
                                          integer=False))
            if telemetry is not None:
                for sc in self._sc_weights:
                    sc.attach_audit(telemetry.audit)
        self._down: set[int] = set()
        self._parked: list[Request] = []    # drained with no live survivor
        self._route: dict[int, int] = {}    # req_id -> replica (note_arrival)
        self._ticked: list[bool] = [False] * n
        self.ticks_run = 0
        self.reroutes = 0                   # requests moved off dead replicas
        self.stalled_ticks = 0

    # ------------------------------------------------------------ dispatch
    def _live(self) -> list[int]:
        return [i for i in range(len(self.engines)) if i not in self._down]

    @staticmethod
    def _pending_tokens(eng: ServeEngine) -> int:
        """Token-denominated load: everything admitted but not finished."""
        load = 0
        for req in list(eng.waiting) + list(eng.queued):
            load += len(req.prompt) + req.max_new_tokens
        for reqs in (eng.prefilling, eng.running):
            for req in reqs.values():
                load += (len(req.prompt) - req.prefilled
                         + req.max_new_tokens - req.gen_count)
        return load

    def _accepting(self) -> list[int]:
        """Dispatchable replicas: live AND past any post-recovery drain
        (a rejoined engine refuses submissions until its first tick)."""
        return [i for i in self._live() if self.engines[i].accepting]

    def _pick(self) -> int | None:
        ready = self._accepting()
        if not ready:
            return None
        return min(ready, key=lambda i: (self._pending_tokens(self.engines[i])
                                         + 1.0) / max(self.weights[i], 1e-9))

    def note_arrival(self, req: Request) -> None:
        """Route at arrival time (the driver stamps arrivals before
        submitting) so the telemetry span opens on the replica that will
        actually serve the request."""
        i = self._pick()
        if i is None:
            return
        self._route[req.req_id] = i
        self.engines[i].note_arrival(req)

    def submit(self, req: Request):
        i = self._route.pop(req.req_id, None)
        if i is None or i in self._down or not self.engines[i].accepting:
            i = self._pick()
        if i is None:       # every replica down: park until one rejoins
            self._parked.append(req)
            return True
        return self.engines[i].submit(req)

    # ----------------------------------------------------------- sensing
    def _sense(self, name: str, value: float) -> float:
        """The one road a router sensor reading takes to a weight
        controller — through the chaos tap when installed, exactly like
        the engine's ``_sense``."""
        tap = self.sensor_tap
        return tap(name, value) if tap is not None else value

    def _replica_ttft(self, eng: ServeEngine) -> float:
        """Censored TTFT pressure: the controller p99 OR the head-of-line
        wait, whichever is worse.  A stalled replica stops ticking (its
        own sensors freeze), but its queue head keeps aging — this read
        rises anyway, which is what lets the weights route around a
        replica that cannot even report."""
        now = self.clock()
        wait = 0.0
        head = (eng.queued[0] if eng.queued
                else (eng.waiting[0] if eng.waiting else None))
        if head is not None:
            epoch = head.queued_t if head.queued_t is not None \
                else head.submitted_t
            wait = max(0.0, now - epoch)
        return max(eng.ttft_ctrl.p99(), wait)

    def _update_weights(self) -> None:
        if not self.adaptive:
            return
        for i in self._live():
            sc = self._sc_weights[i]
            sc.set_perf(self._sense(f"route.replica{i}.ttft_p99_s",
                                    self._replica_ttft(self.engines[i])))
            self.weights[i] = float(sc.get_conf())

    @property
    def sensor_faults(self) -> int:
        return sum(sc.sensor_faults for sc in self._sc_weights
                   if sc is not None)

    # ------------------------------------------------------------ one tick
    def tick(self) -> dict:
        if self.telemetry is not None:
            self.telemetry.audit.tick = self.ticks_run
        # replica loss first: a freshly-tripped replica drains itself on
        # its own tick, then the router takes the parked work to survivors
        for i, eng in enumerate(self.engines):
            if eng.preemption.triggered and i not in self._down:
                self._down.add(i)
                eng.tick()                       # the engine's drain tick
                moved = eng.take_drained()
                self.reroutes += len(moved)
                self._parked.extend(moved)
            elif not eng.preemption.triggered and i in self._down:
                self._down.discard(i)            # rejoin the dispatch set
        if self._parked and self._accepting():
            parked, self._parked = self._parked, []
            for req in parked:
                self.submit(req)
        self._update_weights()
        skip = self.stall(self.ticks_run) if self.stall is not None else None
        per, self._ticked = [], [False] * len(self.engines)
        for i in self._live():
            if i == skip:
                self.stalled_ticks += 1
                continue
            per.append(self.engines[i].tick())
            self._ticked[i] = True
        self.ticks_run += 1
        return self._merge(per)

    def _merge(self, per: list[dict]) -> dict:
        out = dict.fromkeys(TICK_STATS_KEYS, 0)
        out["tick"] = self.ticks_run - 1
        if not per:
            # every replica down or stalled: an idle router tick
            out["draining"] = bool(self._down)
            out["tp_shards"] = max(e.tp_shards for e in self.engines)
            out["admit_tier_max"] = 0
            return out
        cost = max(per, key=self.cost_model.cost)
        for k in _COST_KEYS:
            out[k] = cost[k]
        for k in _SUM_KEYS:
            out[k] = sum(p[k] for p in per)
        out["kv_over_budget"] = any(p["kv_over_budget"] for p in per)
        out["draining"] = any(p["draining"] for p in per) or bool(self._down)
        out["admit_tier_max"] = max(p["admit_tier_max"] for p in per)
        out["tp_shards"] = max(p["tp_shards"] for p in per)
        return out

    def charge_tick_cost(self, dt: float, *, decoded: bool = False) -> None:
        """Virtual-time feedback fans out to every replica that ticked:
        the merged cost is the tick's wall time for all of them."""
        for i, ticked in enumerate(self._ticked):
            if ticked:
                eng = self.engines[i]
                eng.charge_tick_cost(
                    dt, decoded=decoded and bool(eng.running))

    def note_chaos(self, name: str) -> None:
        for i in self._live():
            self.engines[i].note_chaos(name)
            break

    # --------------------------------------------------- driver summary API
    def _concat(self, attr: str) -> list:
        out = []
        for eng in self.engines:
            v = getattr(eng, attr)
            out.extend(v.values() if isinstance(v, dict) else v)
        return out

    @property
    def waiting(self):
        return self._concat("waiting") + self._parked

    @property
    def queued(self):
        return self._concat("queued")

    @property
    def prefilling(self):
        return self._concat("prefilling")

    @property
    def running(self):
        return self._concat("running")

    @property
    def finished(self):
        return self._concat("finished")

    @property
    def rejected(self) -> int:
        return sum(e.rejected for e in self.engines)

    @property
    def reject_counts(self):
        counts = type(self.engines[0].reject_counts)()
        for eng in self.engines:
            counts.update(eng.reject_counts)
        return counts

    @property
    def preemptions(self) -> int:
        return sum(e.preemptions for e in self.engines)

    @property
    def recompute_tokens(self) -> int:
        return sum(e.recompute_tokens for e in self.engines)

    @property
    def slo_good_requests(self) -> int:
        return sum(e.slo_good_requests for e in self.engines)

    @property
    def slo_miss_requests(self) -> int:
        return sum(e.slo_miss_requests for e in self.engines)

    @property
    def slo_good_tokens(self) -> int:
        return sum(e.slo_good_tokens for e in self.engines)

    @property
    def slo_miss_tokens(self) -> int:
        return sum(e.slo_miss_tokens for e in self.engines)

    @property
    def goodput_tokens(self) -> int:
        return self.slo_good_tokens

    @property
    def admit_tier_max(self) -> int:
        return max(e.admit_tier_max for e in self.engines)

    def close(self) -> None:
        for sc in self._sc_weights:
            if sc is not None:
                sc.close()
        for eng in self.engines:
            eng.close()
