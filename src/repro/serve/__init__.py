from .engine import (Request, RejectReason, SLOSpec, ServeEngine,
                     TICK_STATS_KEYS)
from .kv_cache import KVBlockPool, kv_bytes_per_token
from .paging import PagedKVAllocator
from .traffic import (OpenLoopDriver, TickCostModel, TierSpec, TraceConfig,
                      TraceEvent, VirtualClock, as_requests, concat_traces,
                      synthesize_trace)
from .chaos import ChaosMonkey, ChaosSpec

__all__ = ["Request", "RejectReason", "SLOSpec", "ServeEngine",
           "TICK_STATS_KEYS",
           "KVBlockPool", "PagedKVAllocator", "kv_bytes_per_token",
           "OpenLoopDriver", "TickCostModel", "TierSpec", "TraceConfig",
           "TraceEvent", "VirtualClock", "as_requests", "concat_traces",
           "synthesize_trace",
           "ChaosMonkey", "ChaosSpec"]
