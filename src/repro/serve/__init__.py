from .engine import Request, ServeEngine
from .kv_cache import KVBlockPool, kv_bytes_per_token
from .paging import PagedKVAllocator

__all__ = ["Request", "ServeEngine", "KVBlockPool", "PagedKVAllocator",
           "kv_bytes_per_token"]
