from .engine import (Admission, Request, RejectReason, SLOSpec, ServeEngine,
                     ServeOptions, TICK_STATS_KEYS)
from .kv_cache import DenseKVLease, KVBlockPool, kv_bytes_per_token
from .paging import KVLease, PagedKVAllocator
from .prefix_cache import PrefixCache
from .traffic import (OpenLoopDriver, TickCostModel, TierSpec, TraceConfig,
                      TraceEvent, VirtualClock, as_requests, concat_traces,
                      synthesize_trace)
from .chaos import ChaosMonkey, ChaosSpec
from .block_store import CacheShardingPlan, build_serve_mesh, parse_mesh_spec
from .router import ReplicaRouter

__all__ = ["Admission", "Request", "RejectReason", "SLOSpec", "ServeEngine",
           "ServeOptions", "TICK_STATS_KEYS",
           "DenseKVLease", "KVBlockPool", "KVLease", "PagedKVAllocator",
           "PrefixCache", "kv_bytes_per_token",
           "OpenLoopDriver", "TickCostModel", "TierSpec", "TraceConfig",
           "TraceEvent", "VirtualClock", "as_requests", "concat_traces",
           "synthesize_trace",
           "ChaosMonkey", "ChaosSpec",
           "CacheShardingPlan", "ReplicaRouter", "build_serve_mesh",
           "parse_mesh_spec"]
