from .engine import Request, ServeEngine
from .kv_cache import KVBlockPool, kv_bytes_per_token

__all__ = ["Request", "ServeEngine", "KVBlockPool", "kv_bytes_per_token"]
