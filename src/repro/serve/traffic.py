"""Open-loop multi-tenant traffic for the serving engine.

Closed-loop load generators (N clients, think time) self-throttle under
overload: when the server slows down, the offered load drops with it, and
tail latency looks artificially healthy.  Real serving traffic is
*open-loop* — arrivals keep coming at the trace rate whether or not the
server keeps up — which is exactly the regime where SmartConf's
SLO-actuated admission control (``serve.admit_tier_max``) has to earn its
keep.

This module provides:

* :func:`synthesize_trace` — deterministic arrival traces from a seeded
  RNG: homogeneous Poisson, bursty (on/off modulated Poisson) and diurnal
  (sinusoidal rate) processes, with heavy-tailed (bounded-Pareto) prompt
  and output lengths and multi-tenant priority tiers carrying per-tier
  deadlines.
* :class:`VirtualClock` — the injected clock that makes the whole
  harness deterministic: the driver owns time, the engine just reads it.
* :class:`OpenLoopDriver` — replays a trace against a
  :class:`~repro.serve.engine.ServeEngine` on the virtual clock.  Because
  the clock is frozen *within* a tick, the driver charges each tick with
  a simple cost model (base + per-prefill-lane + per-decode-token
  seconds) and records that cost into the engine's latency sensors so the
  SmartConf controllers observe the same virtual time the requests do.

Everything is seeded; two runs with the same config produce bit-identical
traces and tick sequences.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import numpy as np

from .engine import Request, ServeEngine

__all__ = [
    "TierSpec",
    "TraceConfig",
    "TraceEvent",
    "VirtualClock",
    "synthesize_trace",
    "concat_traces",
    "as_requests",
    "OpenLoopDriver",
]


# --------------------------------------------------------------------------
# trace synthesis
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One tenant class.  ``share`` is the fraction of arrivals; tiers are
    shed highest-``tier``-number first under brownout (0 = most important).
    ``deadline_s`` is the end-to-end completion deadline stamped on each
    request of this tier (``None`` = no deadline)."""

    tier: int
    share: float
    deadline_s: float | None = None


DEFAULT_TIERS = (
    TierSpec(0, 0.25, deadline_s=30.0),   # interactive / paid
    TierSpec(1, 0.35, deadline_s=60.0),   # standard
    TierSpec(2, 0.40, deadline_s=None),   # batch / best-effort
)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Arrival-process + workload-shape parameters for one trace."""

    process: str = "poisson"        # "poisson" | "bursty" | "diurnal"
    rate_rps: float = 20.0          # mean arrival rate (requests/s)
    horizon_s: float = 10.0
    seed: int = 0
    t_start: float = 0.0
    # bursty: on/off modulated Poisson.  During the "on" fraction of each
    # period the rate is ``burst_factor`` x the off rate; the mean over a
    # full period equals ``rate_rps``.
    burst_factor: float = 6.0
    burst_period_s: float = 4.0
    burst_duty: float = 0.25
    # diurnal: rate(t) = rate_rps * (1 + amplitude * sin(2 pi t / period))
    diurnal_period_s: float = 10.0
    diurnal_amplitude: float = 0.8
    # heavy-tailed lengths: bounded Pareto on [lo, hi], tail index `alpha`
    # (smaller alpha = heavier tail).
    prompt_lo: int = 4
    prompt_hi: int = 48
    prompt_alpha: float = 1.3
    new_lo: int = 2
    new_hi: int = 16
    new_alpha: float = 1.6
    tiers: tuple[TierSpec, ...] = DEFAULT_TIERS
    # shared-prefix tenancy (system prompts / few-shot preambles): a
    # ``prefix_share`` fraction of arrivals is assigned to one of
    # ``prefix_groups`` groups; every request in a group opens with the
    # same ``prefix_len`` tokens (materialised deterministically per
    # (seed, group) in :func:`as_requests`) — the workload the engine's
    # radix prefix cache exists for.  0 groups (default) disables it.
    prefix_groups: int = 0
    prefix_len: int = 0
    prefix_share: float = 1.0


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One arrival: absolute virtual time + the workload shape."""

    t: float
    req_id: int
    tier: int
    deadline_s: float | None
    prompt_len: int
    max_new_tokens: int
    # shared-prefix group this arrival belongs to (-1 = none); its prompt's
    # first ``prefix_len`` tokens are the group's common preamble
    prefix_group: int = -1
    prefix_len: int = 0


def _rate_at(cfg: TraceConfig, t: float) -> float:
    if cfg.process == "poisson":
        return cfg.rate_rps
    if cfg.process == "bursty":
        duty = min(max(cfg.burst_duty, 1e-6), 1.0)
        # mean = duty * on + (1 - duty) * off = rate_rps, on = factor * off
        off = cfg.rate_rps / (duty * cfg.burst_factor + (1.0 - duty))
        phase = (t % cfg.burst_period_s) / cfg.burst_period_s
        return cfg.burst_factor * off if phase < duty else off
    if cfg.process == "diurnal":
        amp = min(max(cfg.diurnal_amplitude, 0.0), 1.0)
        return cfg.rate_rps * (
            1.0 + amp * math.sin(2.0 * math.pi * t / cfg.diurnal_period_s))
    raise ValueError(f"unknown arrival process: {cfg.process!r}")


def _peak_rate(cfg: TraceConfig) -> float:
    if cfg.process == "bursty":
        duty = min(max(cfg.burst_duty, 1e-6), 1.0)
        off = cfg.rate_rps / (duty * cfg.burst_factor + (1.0 - duty))
        return cfg.burst_factor * off
    if cfg.process == "diurnal":
        return cfg.rate_rps * (1.0 + min(max(cfg.diurnal_amplitude, 0.0), 1.0))
    return cfg.rate_rps


def _bounded_pareto(rng: np.random.Generator, lo: int, hi: int,
                    alpha: float, n: int) -> np.ndarray:
    """Inverse-CDF sampling of a Pareto truncated to [lo, hi]."""
    lo_f, hi_f = float(lo), float(max(hi, lo + 1))
    u = rng.uniform(size=n)
    ratio = (lo_f / hi_f) ** alpha
    x = lo_f / (1.0 - u * (1.0 - ratio)) ** (1.0 / alpha)
    return np.clip(x.astype(np.int64), lo, hi)


def synthesize_trace(cfg: TraceConfig) -> list[TraceEvent]:
    """Deterministic non-homogeneous Poisson trace via thinning."""
    rng = np.random.default_rng(cfg.seed)
    peak = max(_peak_rate(cfg), 1e-9)
    shares = np.asarray([t.share for t in cfg.tiers], dtype=np.float64)
    shares = shares / shares.sum()

    times: list[float] = []
    t = cfg.t_start
    end = cfg.t_start + cfg.horizon_s
    while True:
        t += float(rng.exponential(1.0 / peak))
        if t >= end:
            break
        if rng.uniform() * peak <= _rate_at(cfg, t - cfg.t_start):
            times.append(t)

    n = len(times)
    tier_idx = rng.choice(len(cfg.tiers), size=n, p=shares) if n else []
    plens = _bounded_pareto(rng, cfg.prompt_lo, cfg.prompt_hi,
                            cfg.prompt_alpha, n)
    nlens = _bounded_pareto(rng, cfg.new_lo, cfg.new_hi, cfg.new_alpha, n)
    groups = np.full(n, -1, np.int64)
    if cfg.prefix_groups > 0 and cfg.prefix_len > 0 and n:
        mask = rng.uniform(size=n) < cfg.prefix_share
        groups[mask] = rng.choice(cfg.prefix_groups, size=int(mask.sum()))
        # a grouped prompt must extend past its preamble by at least one
        # token (the engine always prefills >= 1 token to sample from)
        plens = np.where(groups >= 0,
                         np.maximum(plens, cfg.prefix_len + 1), plens)

    events = []
    for i, ti in enumerate(times):
        spec = cfg.tiers[int(tier_idx[i])]
        grp = int(groups[i])
        events.append(TraceEvent(
            t=ti, req_id=i, tier=spec.tier, deadline_s=spec.deadline_s,
            prompt_len=int(plens[i]), max_new_tokens=int(nlens[i]),
            prefix_group=grp, prefix_len=cfg.prefix_len if grp >= 0 else 0))
    return events


def concat_traces(*segments: Sequence[TraceEvent]) -> list[TraceEvent]:
    """Merge trace segments into one time-sorted trace with globally unique
    request ids.  Build each segment with its own :class:`TraceConfig`
    (offset via ``t_start``) to model a *regime shift* — e.g. a calm
    morning phase followed by a sustained storm — which is the workload a
    static configuration provably cannot match on both sides."""
    events = sorted((e for seg in segments for e in seg), key=lambda e: e.t)
    return [dataclasses.replace(e, req_id=i) for i, e in enumerate(events)]


def as_requests(events: Sequence[TraceEvent], *, vocab: int,
                seed: int = 0, id_base: int = 0,
                ) -> list[tuple[float, Request]]:
    """Materialise trace events into (arrival_time, Request) pairs with
    random token ids.  Token 0 (EOS in the toy tokenizer) is excluded so
    generation length is governed by ``max_new_tokens``, not luck.
    Shared-prefix events (``prefix_group >= 0``) open with their group's
    preamble, generated once per (seed, group) — every member of a group
    carries bit-identical leading tokens across the whole trace."""
    rng = np.random.default_rng(seed)
    prefixes: dict[int, np.ndarray] = {}
    out = []
    for ev in events:
        if ev.prefix_group >= 0 and 0 < ev.prefix_len < ev.prompt_len:
            pre = prefixes.get(ev.prefix_group)
            if pre is None:
                grng = np.random.default_rng((seed, ev.prefix_group))
                pre = grng.integers(1, vocab, size=ev.prefix_len,
                                    dtype=np.int32)
                prefixes[ev.prefix_group] = pre
            tail = rng.integers(1, vocab, size=ev.prompt_len - ev.prefix_len,
                                dtype=np.int32)
            toks = np.concatenate([pre, tail])
        else:
            toks = rng.integers(1, vocab, size=ev.prompt_len, dtype=np.int32)
        out.append((ev.t, Request(
            req_id=id_base + ev.req_id, prompt=toks,
            max_new_tokens=ev.max_new_tokens, tier=ev.tier,
            deadline_s=ev.deadline_s)))
    return out


# --------------------------------------------------------------------------
# virtual time
# --------------------------------------------------------------------------

class VirtualClock:
    """A clock the driver advances explicitly.  Inject as
    ``ServeEngine(..., clock=vc)`` — within one ``tick()`` the reading is
    constant, so all intra-tick latency spans the engine measures are 0;
    the driver charges tick cost afterwards (see :class:`OpenLoopDriver`)."""

    def __init__(self, start: float = 0.0) -> None:
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        self.now += float(dt)
        return self.now


@dataclasses.dataclass(frozen=True)
class TickCostModel:
    """Virtual seconds charged per engine tick.  Prefill is charged per
    *issued* lane slot (padding costs compute too); decode per decoding
    SLOT (the KV-read unit: with speculation one slot can emit several
    tokens per dispatch, but reads its history once), plus a cheap
    per-draft-lane verify charge — this is what makes speculation's
    economics real in virtual time: accepted drafts amortize the slot
    cost, rejected ones still pay their verify lanes."""

    base_s: float = 2e-3
    prefill_token_s: float = 5e-5
    decode_token_s: float = 8e-4
    spec_lane_s: float = 1e-4

    def cost(self, stats: dict) -> float:
        issued = stats.get("prefill_issued_tokens", stats.get(
            "prefill_tokens", 0))
        # decode_slots fell out of the frozen stats schema only with the
        # speculation PR; older dicts fall back to decode_tokens (equal
        # whenever speculation is off)
        slots = stats.get("decode_slots", stats.get("decode_tokens", 0))
        return (self.base_s
                + self.prefill_token_s * float(issued)
                + self.decode_token_s * float(slots)
                + self.spec_lane_s * float(stats.get("spec_lanes", 0)))


# --------------------------------------------------------------------------
# open-loop driver
# --------------------------------------------------------------------------

class OpenLoopDriver:
    """Replays an arrival list against a ServeEngine on a VirtualClock.

    Per tick: submit every arrival whose time is due, fire the chaos hook
    (if any), run ``engine.tick()``, advance the clock by the tick cost
    model (+ any chaos slow-tick penalty), and feed the cost into the
    engine's ``tick_latency`` / ``decode_latency`` sensors so SmartConf's
    ``decode_p99_s`` goal reads virtual — not wall-clock — time.

    Exceptions escaping ``engine.tick()`` are caught, counted in
    ``unhandled`` and abort the run; the SLO bench gates on this count
    being zero.
    """

    def __init__(self, engine: ServeEngine,
                 arrivals: Sequence[tuple[float, Request]], *,
                 clock: VirtualClock,
                 cost: TickCostModel | None = None,
                 chaos: "Callable[[OpenLoopDriver, int], float] | None" = None,
                 drain_s: float = 120.0,
                 max_ticks: int = 200_000) -> None:
        self.engine = engine
        self.arrivals = sorted(arrivals, key=lambda p: p[0])
        self.clock = clock
        self.cost = cost or TickCostModel()
        self.chaos = chaos
        self.drain_s = float(drain_s)
        self.max_ticks = int(max_ticks)
        self.ticks = 0
        self.submitted = 0
        self.unhandled: list[str] = []

    # -- helpers -----------------------------------------------------------

    def _engine_busy(self) -> bool:
        eng = self.engine
        return bool(eng.waiting or eng.queued or eng.prefilling or eng.running)

    def _submit_due(self) -> None:
        while (self.submitted < len(self.arrivals)
               and self.arrivals[self.submitted][0] <= self.clock.now):
            req = self.arrivals[self.submitted][1]
            # stamp the arrival on the trace timeline (and open the
            # request's lifetime span) BEFORE submit, so a door rejection's
            # span-close has its matching open
            self.engine.note_arrival(req)
            self.engine.submit(req)
            self.submitted += 1

    # -- main loop ---------------------------------------------------------

    def run(self) -> dict:
        eng = self.engine
        t0 = self.clock.now
        last_t = self.arrivals[-1][0] if self.arrivals else t0
        t_stop = last_t + self.drain_s

        while self.ticks < self.max_ticks:
            if self.submitted < len(self.arrivals):
                # jump idle gaps between arrivals
                nxt = self.arrivals[self.submitted][0]
                if not self._engine_busy() and nxt > self.clock.now:
                    self.clock.advance(nxt - self.clock.now)
                self._submit_due()
            elif not self._engine_busy():
                break   # trace exhausted and engine idle: done
            if self.clock.now > t_stop:
                break   # bounded drain (livelock / stuck-preemption guard)

            extra_s = 0.0
            if self.chaos is not None:
                extra_s = float(self.chaos(self, self.ticks) or 0.0)
            try:
                stats = eng.tick()
            except Exception as exc:  # noqa: BLE001 - the whole point
                self.unhandled.append(f"{type(exc).__name__}: {exc}")
                break
            dt = self.cost.cost(stats) + extra_s
            self.clock.advance(dt)
            # intra-tick spans were 0 on the frozen clock; charge them now
            # so the controllers' decode_p99_s sensor (and the telemetry
            # latency histograms) see virtual time.
            eng.charge_tick_cost(dt,
                                 decoded=bool(stats.get("decode_tokens", 0)))
            self.ticks += 1

        return self.summary(elapsed_s=self.clock.now - t0)

    # -- reporting ---------------------------------------------------------

    def summary(self, *, elapsed_s: float) -> dict:
        eng = self.engine
        elapsed = max(elapsed_s, 1e-9)
        by_tier_good: dict[int, int] = {}
        by_tier_fin: dict[int, int] = {}
        for req in eng.finished:
            toks = len(req.generated)
            by_tier_fin[req.tier] = by_tier_fin.get(req.tier, 0) + toks
            if req.slo_ok:
                by_tier_good[req.tier] = by_tier_good.get(req.tier, 0) + toks
        total_tokens = eng.slo_good_tokens + eng.slo_miss_tokens
        return {
            "ticks": self.ticks,
            "elapsed_s": elapsed,
            "submitted": self.submitted,
            "finished": len(eng.finished),
            "rejected": eng.rejected,
            "reject_counts": {str(k): v for k, v in eng.reject_counts.items()},
            "preemptions": eng.preemptions,
            "recompute_tokens": eng.recompute_tokens,
            "slo_good_requests": eng.slo_good_requests,
            "slo_miss_requests": eng.slo_miss_requests,
            "slo_good_tokens": eng.slo_good_tokens,
            "slo_miss_tokens": eng.slo_miss_tokens,
            "goodput_tps": eng.slo_good_tokens / elapsed,
            "throughput_tps": total_tokens / elapsed,
            "goodput_tokens_by_tier": by_tier_good,
            "finished_tokens_by_tier": by_tier_fin,
            "admit_tier_max": eng.admit_tier_max,
            "unhandled": list(self.unhandled),
        }
