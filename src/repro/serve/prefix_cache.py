"""Radix (prefix-trie) cache over refcounted paged KV blocks.

Thousands of concurrent requests share system prompts and few-shot
preambles; this tree remembers the KV blocks of recently-served prompt
prefixes so a new request whose prompt shares a cached prefix is admitted
with those tokens already "prefilled" — the engine skips straight to the
uncovered suffix.  The cache's share of the block budget is exactly the
kind of workload-dependent knob the paper's control loop exists for
(``serve.kv_cache_share``).

Structure and invariants:

  * Every tree node's **edge is block-aligned**: its token length is a
    multiple of ``block_tokens`` (T) and it owns exactly ``len(edge)//T``
    block ids, one tree-held reference each
    (``PagedKVAllocator.incref_blocks``).  Insertion only ever adds the
    *full-block* prefix of a finished prompt (``floor(len)/T*T`` tokens),
    so a tree-held block is never written again by the request that
    inserted it (decode and partial-tail writes land strictly beyond it).
  * **Lookup is token-granular**: a prompt may match mid-edge (and
    therefore mid-block).  The match is capped at ``len(prompt) - 1`` so
    the engine always prefills at least one token (it needs logits to
    sample from).  A mid-block match means the borrower shares the
    boundary block and must copy-on-write it before writing its own
    suffix (``KVLease.writable``) — sub-block sharing stays exact because
    paged attention is write-then-gather and causal masking hides the
    donor's bytes past the matched point until they are overwritten.
  * Divergence **splits round down** to a block boundary, so two sibling
    edges may share a token prefix shorter than T; lookup compares against
    every child and takes the longest match.
  * Eviction is **LRU leaf drop**: the coldest leaf's references are
    released; a block returns to the allocator's free list only when no
    lease still uses it.  ``enforce(budget)`` keeps the tree's held blocks
    inside the SmartConf-actuated cache share.
  * ``remap`` follows a store compaction's renumbering (installed as the
    allocator's ``remap_hook`` by the engine).
"""

from __future__ import annotations

import numpy as np

from .paging import PagedKVAllocator

__all__ = ["PrefixCache"]


def _common_prefix(a: np.ndarray, b: np.ndarray) -> int:
    n = min(len(a), len(b))
    if n == 0:
        return 0
    neq = a[:n] != b[:n]
    return int(np.argmax(neq)) if neq.any() else n


class _Node:
    __slots__ = ("edge", "blocks", "children", "parent", "last_used")

    def __init__(self, edge: np.ndarray, blocks: list[int],
                 parent: "_Node | None") -> None:
        self.edge = edge
        self.blocks = blocks
        self.children: list[_Node] = []
        self.parent = parent
        self.last_used = 0


class PrefixCache:
    def __init__(self, alloc: PagedKVAllocator) -> None:
        self.alloc = alloc
        self.block_tokens = alloc.block_tokens
        self.root = _Node(np.zeros((0,), np.int32), [], None)
        self.blocks_held = 0      # tree-held references (each block once)
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.evicted_blocks = 0

    # -------------------------------------------------------------- lookup
    def lookup(self, prompt: np.ndarray,
               now: int) -> tuple[int, list[int]]:
        """Longest cached prefix of ``prompt``: returns ``(match_tokens,
        blocks)`` where ``blocks`` are the ``ceil(match/T)`` physical ids
        covering it (the last one possibly partial — COW boundary).  The
        match is capped at ``len(prompt) - 1``.  Touches the path's LRU
        stamps; does NOT take references — the caller adopts the blocks
        into a lease (``PagedKVAllocator.lease(shared=...)``) in the same
        scheduling step."""
        self.lookups += 1
        t = self.block_tokens
        node, off = self.root, 0
        blocks: list[int] = []
        node.last_used = now
        while off < len(prompt):
            best, best_c = None, 0
            for ch in node.children:
                c = _common_prefix(prompt[off:], ch.edge)
                if c > best_c:
                    best, best_c = ch, c
            if best is None or best_c == 0:
                break
            best.last_used = now
            if best_c < len(best.edge):
                blocks.extend(best.blocks[:(best_c + t - 1) // t])
                off += best_c
                break
            blocks.extend(best.blocks)
            off += best_c
            node = best
        match = min(off, len(prompt) - 1)
        blocks = blocks[:(match + t - 1) // t]
        if match > 0:
            self.hits += 1
            self.hit_tokens += match
        return match, blocks

    def probe(self, prompt: np.ndarray) -> int:
        """Advisory match length only: how many of ``prompt``'s tokens a
        ``lookup`` *right now* would cover.  Mutates nothing (no LRU touch,
        no stats) — used by ``ServeEngine.submit`` to report the prospective
        hit in the :class:`Admission` receipt without perturbing eviction
        order; the authoritative (counted) lookup happens at scheduling."""
        node, off = self.root, 0
        while off < len(prompt):
            best, best_c = None, 0
            for ch in node.children:
                c = _common_prefix(prompt[off:], ch.edge)
                if c > best_c:
                    best, best_c = ch, c
            if best is None or best_c == 0:
                break
            off += best_c
            if best_c < len(best.edge):
                break
            node = best
        return min(off, len(prompt) - 1) if len(prompt) else 0

    # -------------------------------------------------------------- insert
    def insert(self, prompt: np.ndarray, lease_blocks: list[int],
               now: int) -> int:
        """Insert the full-block prefix of ``prompt`` (its KV lives in
        ``lease_blocks``, positionally).  Regions the tree already covers
        are left alone (the existing copies stay canonical); only the
        uncovered block-aligned suffix is adopted (one tree reference per
        block).  Returns the number of blocks newly held."""
        t = self.block_tokens
        n = (len(prompt) // t) * t
        node, off = self.root, 0
        node.last_used = now
        while off < n:
            best, best_c = None, 0
            for ch in node.children:
                c = _common_prefix(prompt[off:n], ch.edge)
                if c > best_c:
                    best, best_c = ch, c
            if best is None or best_c == 0:
                return self._add_child(node, prompt[off:n],
                                       lease_blocks[off // t: n // t], now)
            split = (best_c // t) * t
            if best_c == len(best.edge):
                best.last_used = now
                node, off = best, off + best_c
                continue
            if split == 0:
                # diverges inside the child's first block: a sibling that
                # shares < T leading tokens (lookup takes the longest match)
                return self._add_child(node, prompt[off:n],
                                       lease_blocks[off // t: n // t], now)
            # split the child at the block boundary below the divergence
            upper = _Node(best.edge[:split], best.blocks[:split // t], node)
            upper.last_used = now
            lower = best
            lower.edge = lower.edge[split:]
            lower.blocks = lower.blocks[split // t:]
            lower.parent = upper
            upper.children.append(lower)
            node.children[node.children.index(best)] = upper
            node, off = upper, off + split
        return 0

    def _add_child(self, node: _Node, edge: np.ndarray,
                   blocks: list[int], now: int) -> int:
        if len(edge) == 0:
            return 0
        assert len(edge) % self.block_tokens == 0
        assert len(blocks) == len(edge) // self.block_tokens
        self.alloc.incref_blocks(blocks)
        child = _Node(np.asarray(edge, np.int32).copy(), list(blocks), node)
        child.last_used = now
        node.children.append(child)
        self.blocks_held += len(blocks)
        return len(blocks)

    # ------------------------------------------------------------ eviction
    def _leaves(self) -> list[_Node]:
        out, stack = [], list(self.root.children)
        while stack:
            nd = stack.pop()
            if nd.children:
                stack.extend(nd.children)
            else:
                out.append(nd)
        return out

    def evict_lru_leaf(self) -> int:
        """Drop the coldest leaf; returns the tree references released
        (0 when the tree is empty)."""
        leaves = self._leaves()
        if not leaves:
            return 0
        victim = min(leaves, key=lambda nd: nd.last_used)
        victim.parent.children.remove(victim)
        self.alloc.decref_blocks(victim.blocks)
        n = len(victim.blocks)
        self.blocks_held -= n
        self.evicted_blocks += n
        return n

    def enforce(self, budget_blocks: int) -> int:
        """LRU-evict leaves until the tree holds at most
        ``budget_blocks``; returns references released."""
        released = 0
        while self.blocks_held > max(0, int(budget_blocks)):
            n = self.evict_lru_leaf()
            if n == 0:
                break
            released += n
        return released

    def clear(self) -> int:
        return self.enforce(0)

    # ----------------------------------------------------------- remapping
    def remap(self, mapping: dict[int, int]) -> None:
        """Follow a store compaction's renumbering (the allocator's
        ``remap_hook``)."""
        stack = [self.root]
        while stack:
            nd = stack.pop()
            nd.blocks = [mapping[b] for b in nd.blocks]
            stack.extend(nd.children)

    # --------------------------------------------------------------- stats
    @property
    def hit_rate(self) -> float:
        """Lifetime fraction of lookups that matched a cached prefix
        (diagnostic; the controller reads the engine's windowed
        token-weighted sensor)."""
        return 0.0 if self.lookups == 0 else self.hits / self.lookups
