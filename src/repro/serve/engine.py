"""Continuous-batching serve engine with SmartConf-governed admission.

This is the framework's HB3813/HB6728 (paper §6.2, Fig. 6/8): two PerfConfs
share the hard ``hbm_bytes`` constraint —

  * ``serve.max_queue_tokens``  (indirect; deputy = tokens waiting in the
    admission queue) — a larger queue absorbs request bursts but queued
    prompts hold host/device memory;
  * ``serve.kv_block_budget``   (indirect; deputy = live KV blocks) — more
    resident sequences increase decode batch efficiency but eat HBM.

Both are ``super_hard`` on the same metric, so their controllers split the
error via the §5.4 interaction factor (N = 2).  A third, soft PerfConf
``serve.prefill_chunk_tokens`` bounds decode-latency interference from long
prefills (HB2149-style trade-off) by capping how many prompt tokens one
prefill call may process before decode runs again.

Hot path (one `tick`):
  admission -> scheduling (slot + KV allocation) -> model compute:
  **unified** (packed mode: ONE ``step_packed`` dispatch carrying prefill
  chunks AND every running slot's decode token as a length-1 segment) or
  **split** (bucketed/legacy: one prefill call + one fused decode step) ->
  completion/free -> controller updates.

Hot-path design (the serving-perf tentpole):
  * **Unified prefill+decode ticks** (``prefill_mode="packed"``, the
    default for every text arch) — each tick fills a single
    ``[1, width]`` ragged stream with prefill chunks from as many requests
    as fit under the ``serve.prefill_chunk_tokens`` budget PLUS one
    length-1 decode segment per running slot, all in admission order: the
    steady-state tick costs ONE compiled dispatch instead of two.
    (Decode-only ticks — the drain tail, where the split path never paid a
    second dispatch — route to the specialized decode program: still one
    dispatch, at that program's exact cost.)
    Per-token ``slot_id`` / ``position`` arrays plus per-slot segment
    boundaries carry the ragged structure; attention masks by segment id
    so no request sees another (a decode segment sees exactly its own
    history — the decode-attention predicate), and K/V scatter routes each
    token to its slot's dense ring row or paged block (``step_packed``).
    Sampling happens for every segment that completed a row this tick —
    prefill-finishers and decoders alike — with a ``_gen_buf`` scatter by
    slot.  Decode tokens are mandatory riders (the split path decodes
    every running slot each tick, so parity demands the same here); they
    count against the literal token budget, with prefill floored at one
    token per tick so it can never be fully starved.  The knob is
    therefore the *literal* per-tick token budget, the jit cache shrinks
    to one packed shape under saturated demand (drain-tail ticks bucket
    down, so worst case O(log cache_len) vs the bucketed path's
    per-(bucket, slot-count) spread), and ``pad_fraction`` — dead lanes
    per issued prefill lane — is observable per tick, so the SmartConf
    deputy for the knob tracks the work actually done.  Attention runs on
    the fused ``kernels/segment_attention`` family (online softmax over
    K/V tiles, predicate fused into the tile mask), so the packed stream
    never materializes the ``[P, B*N]`` score matrix that used to cap
    ``packed_width``.
  * **Length-bucketed prefill** (``prefill_mode="bucketed"``) — prompt
    chunks are padded to power-of-two buckets and batched across slots
    into a single ``prefill_chunk`` call at engine batch width, so the jit
    cache holds one entry per *bucket* instead of one per distinct prompt
    length.  Kept as the comparison baseline: its per-tick token cost is
    quantized to ``bucket x n_slots``, which is exactly the deputy drift
    packing removes.
  * **Real chunked prefill** — at most ``prefill_chunk`` prompt tokens are
    prefilled per tick; long prompts spread over several ticks interleaved
    with decode, so the SmartConf soft knob actuates observable behavior.
  * **Cache donation / in-place writes** — prefill and decode steps donate
    the fused KV cache (and the device-side token buffers), and chunked
    prefill scatters K/V straight into the donated cache; the legacy
    one-shot path merges per slot via ``dynamic_update_slice`` rather than
    copying the whole tree.
  * **Deferred host sync** — sampled tokens stay on device between ticks
    (token ring in ``_gen_buf``); the host reads a sequence back exactly
    once, at its completion boundary.

KV residency (the paged-KV tentpole):
  * **Paged KV cache** — for attention-only archs the per-slot dense
    ``[max_batch, cache_len]`` cache is replaced by per-layer physical
    block stores ``[capacity, Kv, T, D]`` addressed through per-sequence
    block tables (``serve/paging.py`` free-list allocator +
    ``kernels/paged_attention`` Pallas decode kernel).  Admission reserves
    table entries only — no cache-tree copy; ``serve.kv_block_budget``
    bounds the *physical* store, so budget cuts below occupancy preempt the
    lowest-priority sequence back to the queue (recompute on re-admission)
    and shrink the store arrays, actually releasing HBM rather than only
    moving the ledger.  Paged KV covers every arch whose blocks are all
    attention kinds — including MoE (only attention K/V is paged); archs
    with recurrent blocks (O(1) state, nothing to page) and the modality
    frontends keep the dense path (``kv_mode="auto"``).

Universal chunked prefill: every text-only family serves the packed (and
bucketed) path — attention kinds via position/segment masking, recurrent
kinds (rwkv6/rglru) by threading scan state across chunk boundaries through
the state-in/state-out kernel variants, and MoE via pad-aware router
capacity — so ``serve.prefill_chunk_tokens`` actuates uniformly across the
zoo.  Only the vision/encoder-decoder frontends (unpadded modality
prefixes) keep the exact one-shot path under ``prefill_mode="auto"``, and
that fallback warns loudly; requesting ``packed`` or ``bucketed`` for them
raises.  ``REPRO_PREFILL_MODE`` overrides what ``auto`` resolves to (the CI
matrix leg), and ``one_shot`` is accepted as an alias for ``legacy``.
"""

from __future__ import annotations

import collections
import dataclasses
import enum
import os
import time
import warnings
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (ControllerModel, GoalSpec, Guardrails, HBMAccountant,
                        LatencySensor, SmartConfIndirect, SmartConf,
                        ThroughputSensor)
from repro.core.smartconf import ConfRegistry
from repro.core.telemetry import Telemetry, Tracer
from repro.distributed.fault_tolerance import PreemptionHandler
from repro.distributed.sharding import SERVE_TP_RULES, use_mesh
from repro.kernels.decode_attention import padded_cache_len
from repro.models import zoo
from .block_store import CacheShardingPlan, build_serve_mesh
from .kv_cache import KVBlockPool, QUEUE_TOKEN_BYTES
from .options import ServeOptions, SLOSpec
from .paging import PagedKVAllocator
from .prefix_cache import PrefixCache
from .speculation import NGramDrafter

__all__ = ["Admission", "Request", "RejectReason", "SLOSpec", "ServeEngine",
           "ServeOptions", "TICK_STATS_KEYS"]

_MIN_BUCKET = 16

# The frozen TickStats schema: every dict `tick()` / `_stats()` returns has
# exactly these keys, in exactly this order.  Telemetry, the open-loop
# driver's cost model, the benches, and the CI JSON gates all consume this
# dict — a key rename or reorder is a cross-layer breaking change, so the
# schema is explicit and regression-tested (tests/test_telemetry.py)
# instead of incidentally stable.  Add new keys at the end.
TICK_STATS_KEYS: tuple[str, ...] = (
    "tick",                     # engine tick ordinal (ticks_run at entry)
    "queued", "waiting", "running", "finished", "hbm", "tokens",
    "pad_fraction", "packed_segments", "dispatches",
    "prefill_tokens", "prefill_issued_tokens", "decode_tokens",
    "kv_used_blocks", "kv_budget_blocks", "kv_capacity_blocks",
    "kv_over_budget", "kv_frag_tokens",
    "preemptions", "admit_tier_max", "rejected", "draining",
    "slo_good_tokens", "slo_miss_tokens",
    # appended (prefix cache PR): reclaimed prefill tokens this tick, the
    # radix tree's held blocks, and the live cache share of the budget
    "prefix_hit_tokens", "prefix_cache_blocks", "kv_cache_share",
    # appended (speculative-decode PR): live draft depth, this tick's
    # accept rate, draft verify lanes issued (the stream width speculation
    # added), and decoding slots (the per-tick KV-read unit now that one
    # slot can emit several tokens per dispatch)
    "spec_depth", "accept_rate", "spec_lanes", "decode_slots",
    # appended (mesh-serving PR): model-axis shard count of this engine's
    # tick dispatch (1 = single-device) — lets the router and the CI gates
    # tell a TP tick from a plain one without poking engine internals
    "tp_shards",
)

# rejections in one tick at or past this count dump the flight recorder:
# a typed-rejection storm is exactly the "why did the engine shed all of
# that" moment the last-N-ticks sensor ring exists to answer
_REJECT_STORM_PER_TICK = 3


class RejectReason(str, enum.Enum):
    """Why the engine refused (or gave up on) a request — the typed reason
    the overload/robustness contract promises instead of a crash or a
    silent scheduler spin.  See serve/README.md for the full semantics."""

    EMPTY_PROMPT = "empty_prompt"          # nothing to prefill
    PROMPT_TOO_LONG = "prompt_too_long"    # prompt+new tokens exceed cache_len
    KV_FOOTPRINT = "kv_footprint"          # KV need exceeds the block budget
    DEADLINE_EXPIRED = "deadline_expired"  # deadline passed while waiting
    BROWNOUT_SHED = "brownout_shed"        # browned out past the TTFT SLO
    DRAINING = "draining"                  # worker preemption in progress

    def __str__(self) -> str:              # counters key on the short name
        return self.value


@dataclasses.dataclass(frozen=True)
class Admission:
    """Typed result of :meth:`ServeEngine.submit`.

    Callers used to null-check a bare ``RejectReason | None``; this carries
    the decision (``accepted`` — also the truth value), the typed
    ``reason`` when refused, and two advisory facts about the accepted
    request: ``prefix_hit_tokens`` (prompt tokens the radix cache could
    currently serve — the actual grant happens at schedule time, so this
    is a hint, not a promise) and ``footprint_blocks`` (KV blocks the
    request will need resident)."""

    accepted: bool
    reason: RejectReason | None = None
    prefix_hit_tokens: int = 0
    footprint_blocks: int = 0

    def __bool__(self) -> bool:
        return self.accepted


def _one_shot_reason(cfg: ArchConfig) -> str:
    """Why this arch cannot leave the one-shot prefill path (the only
    remaining families after universal chunked prefill are the modality
    frontends, whose unpadded prefixes have no chunk representation)."""
    if cfg.encoder_decoder:
        return "the encoder-decoder frontend"
    if cfg.frontend == "vision":
        return "the vision-prefix frontend"
    return f"block pattern {cfg.block_pattern}"


def _bucket(n: int) -> int:
    """Smallest power-of-two >= n (floored at _MIN_BUCKET): the padded
    prefill width, so the jit cache is keyed by O(log max_len) shapes."""
    return max(_MIN_BUCKET, 1 << (max(1, n) - 1).bit_length())


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int
    tier: int = 0               # priority tier; 0 = highest, shed last
    deadline_s: float | None = None  # completion deadline (from submit)
    prompt_bytes: int = 0
    submitted_t: float = 0.0
    queued_t: float | None = None    # first admission past the tier gate
    first_token_t: float | None = None
    done_t: float | None = None
    generated: list = dataclasses.field(default_factory=list)
    slot: int | None = None
    prefilled: int = 0          # prompt tokens already prefilled (chunking)
    prefill_chunks: int = 0     # chunk calls this request's prefill spanned
    gen_count: int = 0          # tokens generated (device-resident until done)
    admit_seq: int = 0          # scheduling order; highest = first preempted
    preempted: int = 0          # times this request was kicked back to queue
    reject_reason: RejectReason | None = None
    slo_ok: bool | None = None  # set at completion: counted toward goodput?
    lease: object | None = None  # KVLease/DenseKVLease while scheduled
    prefix_hit: int = 0         # prompt tokens served from the radix cache


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *,
                 options: ServeOptions | None = None,
                 registry: ConfRegistry | None = None,
                 preemption: PreemptionHandler | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 telemetry: Telemetry | None = None, **kwargs) -> None:
        # config lives in ServeOptions (the typed bag; resolve() is the one
        # env-reading point).  The legacy keyword surface still works: bare
        # kwargs build a ServeOptions here, so ServeEngine(cfg, params,
        # max_batch=8, kv_mode="paged") and ServeEngine(cfg, params,
        # options=ServeOptions(...)) are the same engine.
        if options is None:
            options = ServeOptions(**kwargs)
        elif kwargs:
            raise TypeError(
                "pass configuration via options=ServeOptions(...) OR bare "
                f"kwargs, not both (got {sorted(kwargs)})")
        opts = self.options = options.resolve()
        max_batch = opts.max_batch
        hbm_budget_bytes = opts.hbm_budget_bytes
        block_tokens = opts.block_tokens
        enable_smartconf = opts.enable_smartconf
        latency_goal_s = opts.latency_goal_s
        prefill_mode, kv_mode = opts.prefill_mode, opts.kv_mode
        slo, num_tiers = opts.slo, opts.num_tiers
        admit_tier_max = opts.admit_tier_max
        env_forced = opts.prefill_env_forced
        if telemetry is None:
            telemetry = opts.telemetry

        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        # dense decode tiles the KV axis by block_kv: a cache_len that is
        # not a tile multiple would re-pad K/V with jnp.pad on every decode
        # call, so round the allocation up once here instead
        self.cache_len = cache_len = padded_cache_len(opts.cache_len)
        self.clock = clock

        if prefill_mode not in ("auto", "packed", "bucketed", "legacy"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if (prefill_mode in ("packed", "bucketed")
                and not zoo.supports_chunked_prefill(cfg)):
            if not env_forced:
                raise ValueError(
                    f"{cfg.name}: {_one_shot_reason(cfg)} cannot serve "
                    f"{prefill_mode} (chunked) prefill; only "
                    "prefill_mode='legacy' (one-shot) is available for this "
                    "family")
            prefill_mode = "auto"
        if prefill_mode == "auto":
            if zoo.supports_chunked_prefill(cfg):
                prefill_mode = "packed"
            else:
                # every text-only family (attention, recurrent, MoE) serves
                # the fast path now; falling back is exceptional, so say it
                # loudly — the serve.prefill_chunk_tokens knob will NOT
                # actuate here
                warnings.warn(
                    f"{cfg.name}: {_one_shot_reason(cfg)} keeps the one-shot "
                    "legacy prefill path; serve.prefill_chunk_tokens will "
                    "not actuate for this engine", RuntimeWarning,
                    stacklevel=2)
                prefill_mode = "legacy"
        self.prefill_impl = prefill_mode
        self.fused_prefill = prefill_mode != "legacy"
        # the packed stream's width cap: under saturated demand every tick
        # issues this one shape; the live serve.prefill_chunk_tokens value
        # caps how many real tokens ride in it each tick
        self.packed_width = cache_len

        if kv_mode not in ("auto", "paged", "dense"):
            raise ValueError(f"unknown kv_mode {kv_mode!r}")
        if kv_mode == "paged" and not (zoo.supports_paged_kv(cfg)
                                       and self.fused_prefill):
            raise ValueError(
                f"{cfg.name}: paged KV requires an attention-only block "
                "pattern and chunked prefill (prefill_mode != 'legacy')")
        self.paged = kv_mode == "paged" or (
            kv_mode == "auto" and self.fused_prefill
            and zoo.supports_paged_kv(cfg))

        # ------------------------------------- self-speculative decode
        # rides the unified packed stream: each running slot's segment is
        # [pending token, draft...] and the SAME compiled dispatch that
        # prefills chunks verifies every draft position.  Engines without
        # the packed path cannot speculate; an explicit request raises, the
        # env-forced CI leg silently degrades to k=0.
        spec_depth = int(opts.spec_depth)
        if spec_depth > 0 and self.prefill_impl != "packed":
            if opts.spec_env_forced:
                spec_depth = 0
            else:
                raise ValueError(
                    f"{cfg.name}: speculative decode rides the packed "
                    f"stream; prefill_impl={self.prefill_impl!r} cannot "
                    "serve it")
        self.spec_depth_max = max(1, int(opts.spec_depth_max))
        self.spec_enabled = spec_depth > 0
        self.spec_depth = min(spec_depth, self.spec_depth_max) \
            if self.spec_enabled else 0
        self._spec_len_max = self.spec_depth_max + 1   # 1 pending + k drafts
        self._drafter = NGramDrafter() if self.spec_enabled else None
        self.spec_proposed = 0          # drafted tokens verified, lifetime
        self.spec_accepted = 0          # drafted tokens accepted, lifetime
        self._tick_spec_proposed = 0
        self._tick_spec_accepted = 0
        self._tick_spec_lanes = 0       # draft verify lanes issued
        self._tick_decode_slots = 0
        # windowed accept-rate: the sc_spec controller sensor (accepted,
        # proposed) pairs, token-weighted like the prefix-cache hit window
        self._accept_window: collections.deque[tuple[int, int]] = \
            collections.deque(maxlen=slo.window if slo is not None else 64)

        # --------------------------------- mesh serving (TP packed ticks)
        # the one compiled tick dispatch runs under shard_map on a
        # (data, model) host mesh: attention heads + the block stores' Kv
        # dim shard over `model`, everything else replicates (see
        # block_store.CacheShardingPlan + distributed/collectives TP
        # wrappers).  Infeasible explicit requests raise; env-forced ones
        # (REPRO_SERVE_MESH, the CI leg) degrade to single-device loudly.
        self.mesh = None
        self._cache_plan = None
        if opts.mesh is not None:
            self.mesh = build_serve_mesh(
                opts.mesh, heads=cfg.num_heads, kv_heads=cfg.num_kv_heads,
                prefill_impl=self.prefill_impl,
                env_forced=opts.mesh_env_forced)
        self.tp_shards = (int(self.mesh.shape["model"])
                          if self.mesh is not None else 1)

        self.accountant = HBMAccountant(budget_bytes=hbm_budget_bytes)
        weight_bytes = sum(np.prod(x.shape) * x.dtype.itemsize
                           for x in jax.tree.leaves(params))
        self.accountant.set("weights", int(weight_bytes))

        self.blocks_per_seq = -(-cache_len // block_tokens)
        if self.paged:
            # under an HBM goal the store starts at one sequence's worth and
            # grows on demand inside the accountant's headroom, so the ledger
            # (= physical store bytes) never front-runs the budget
            full = max_batch * self.blocks_per_seq
            tight = enable_smartconf and hbm_budget_bytes
            self.pool = PagedKVAllocator(
                cfg, block_tokens=block_tokens,
                max_blocks_per_seq=self.blocks_per_seq,
                capacity_blocks=self.blocks_per_seq if tight else full,
                budget_blocks=full, accountant=self.accountant)
        else:
            self.pool = KVBlockPool(cfg, block_tokens=block_tokens,
                                    max_blocks=2**30,
                                    accountant=self.accountant)
        self.registry = registry or ConfRegistry()

        # ------------------------------------------- radix prefix cache
        # opt-in; needs the refcounted paged allocator (leases + COW)
        if opts.prefix_cache and not self.paged:
            raise ValueError(
                f"{cfg.name}: prefix_cache requires paged KV "
                "(kv_mode='paged' on an attention-only arch)")
        self._prefix_cache = PrefixCache(self.pool) if opts.prefix_cache \
            else None
        if self._prefix_cache is not None:
            self.pool.remap_hook = self._prefix_cache.remap
        self.kv_cache_share = float(opts.kv_cache_share)
        self.prefix_hit_tokens_total = 0   # reclaimed prefill tokens
        self.cow_copied_blocks = 0
        self._tick_prefix_hit = 0
        # windowed token-weighted hit rate: the sc_cache controller sensor
        self._hit_window: collections.deque[tuple[int, int]] = \
            collections.deque(maxlen=slo.window if slo is not None else 64)
        # block-level sliding-window eviction: only when EVERY attention
        # layer is windowed (a single global layer needs the whole history
        # resident) and the prefix cache is off (trimmed blocks cannot be
        # shared — the two policies are mutually exclusive by construction)
        kinds = {k.split("+")[0] for k in cfg.block_pattern}
        self._window_evict = (self.paged and opts.window_evict
                              and self._prefix_cache is None
                              and kinds <= {"swa", "local"}
                              and bool(cfg.window))

        # engine state
        self.waiting: collections.deque[Request] = collections.deque()
        self.queued: collections.deque[Request] = collections.deque()
        self.queued_tokens = 0
        self.prefilling: dict[int, Request] = {}
        self.running: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.rejected = 0
        self.shed: list[Request] = []   # typed-rejected requests, in order
        self.reject_counts: collections.Counter = collections.Counter()
        self.preemptions = 0
        self.recompute_tokens = 0       # prefilled work thrown away by
        #                                 preemption (bounded-recompute gauge)
        self._admit_counter = 0
        self._free_slots = collections.deque(range(max_batch))
        self.prefill_calls = 0
        self._prefill_shapes: set[int] = set()
        # model-dispatch accounting: every jitted model call (prefill,
        # decode, or unified step) counts one dispatch; the unified packed
        # path collapses the steady-state tick to exactly one
        self.model_dispatches = 0
        self._tick_dispatches = 0
        self._decode_dispatched = False
        # prefill padding telemetry (the serve.prefill_chunk_tokens deputy):
        # issued = token-positions the prefill calls computed, live = real
        # prompt tokens among them; pad_fraction = 1 - live/issued
        self.prefill_issued_tokens = 0
        self.prefill_live_tokens = 0
        self._tick_issued = 0
        self._tick_live = 0
        self._tick_packed_segments = 0
        self._tick_decode = 0

        # device-resident hot state (one fused batch across slots); the
        # host only keeps positions/counters, never token values
        if self.paged:
            self.caches = zoo.init_paged_cache(cfg, self.pool.capacity,
                                               block_tokens)
            self._bt_np = np.full((max_batch, self.blocks_per_seq), -1,
                                  np.int32)
            self._bt_dev = jnp.asarray(self._bt_np)
            self._bt_dirty = False
        else:
            # windowed dense rings need headroom for in-flight draft K/V:
            # a rejected draft's stale entries must age out of the window
            # before they can alias a live position
            self.caches = zoo.init_cache(
                cfg, max_batch, cache_len,
                ring_margin=self.spec_depth_max if self.spec_enabled else 0)
        self.slot_pos = np.full((max_batch,), -1, np.int64)
        self._slot_tok = jnp.zeros((max_batch,), jnp.int32)
        self._gen_buf = jnp.zeros((max_batch, cache_len), jnp.int32)
        if self.mesh is not None:
            # pin the K/V planes on their Kv-dim model-axis placement once;
            # the step fns re-assert it on their (donated) cache outputs so
            # it survives every tick, and the eager resize paths re-place
            self._cache_plan = CacheShardingPlan(self.mesh, paged=self.paged)
            self.caches = self._cache_plan.place(self.caches)
        plan = self._cache_plan

        def _pin(c, tok, gbuf):
            # inside-jit epilogue: cache placement survives donation, and
            # the token rings stay replicated instead of drifting to
            # whatever layout XLA picked this compile
            if plan is None:
                return c, tok, gbuf
            return plan.constrain(c), plan.replicate(tok), \
                plan.replicate(gbuf)

        def decode_fn(p, c, tok, pos, active, gbuf, gidx, bt):
            logits, c = zoo.decode_step(cfg, p, c, tok, pos, active=active,
                                        block_tables=bt)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tok = jnp.where(active, nxt, tok)
            gbuf = gbuf.at[jnp.arange(tok.shape[0]), gidx].set(
                nxt, mode="drop")
            c, tok, gbuf = _pin(c, tok, gbuf)
            return tok, c, gbuf

        def prefill_chunk_fn(p, c, tokens, start, lengths, done, tok, gbuf,
                             bt):
            logits, c = zoo.prefill_chunk(cfg, p, c, tokens, start, lengths,
                                          block_tables=bt)
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            tok = jnp.where(done, first, tok)
            slot0 = jnp.where(done, 0, gbuf.shape[1])
            gbuf = gbuf.at[jnp.arange(tok.shape[0]), slot0].set(
                first, mode="drop")
            c, tok, gbuf = _pin(c, tok, gbuf)
            return c, tok, gbuf

        def step_unified_fn(p, c, tokens, slot_id, pos, start, seg_len,
                            is_dec, sample, gidx, tok, gbuf, bt):
            # decode segments carry placeholder tokens in the host-built
            # stream; fill them from the device-resident token ring so the
            # deferred-host-sync invariant survives unification
            safe = jnp.clip(slot_id, 0, max_batch - 1)
            tokens = jnp.where(is_dec[None, :], tok[safe][None, :], tokens)
            logits, c = zoo.step_packed(cfg, p, c, tokens, slot_id, pos,
                                        start, seg_len, block_tables=bt)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            # sample every segment that completed a row this tick:
            # prefill-finishers (gidx == 0) and decoders (gidx == gen_count)
            tok = jnp.where(sample, nxt, tok)
            gbuf = gbuf.at[jnp.arange(tok.shape[0]), gidx].set(
                nxt, mode="drop")
            c, tok, gbuf = _pin(c, tok, gbuf)
            return c, tok, gbuf

        def step_spec_fn(p, c, tokens, slot_id, pos, start, seg_len, is_dec,
                         spec_rows, sample, gidx, spec_idx, draft_len, tok,
                         gbuf, bt):
            # the pending token of each spec segment (stream offset
            # spec_idx[:, 0]) is device-resident; drafts ride host-side
            safe = jnp.clip(slot_id, 0, max_batch - 1)
            tokens = jnp.where(is_dec[None, :], tok[safe][None, :], tokens)
            accept, toks, c = zoo.step_spec(cfg, p, c, tokens, slot_id, pos,
                                            start, seg_len, spec_rows,
                                            spec_idx, draft_len,
                                            block_tables=bt)
            # emit the accepted prefix plus the model's own next token:
            # toks[b, :accept[b]+1] lands at gidx[b]..gidx[b]+accept[b]
            rows = jnp.arange(max_batch)
            offs = jnp.arange(spec_idx.shape[1], dtype=jnp.int32)[None, :]
            write = (offs <= accept[:, None]) & sample[:, None]
            cols = jnp.where(write, gidx[:, None] + offs, gbuf.shape[1])
            gbuf = gbuf.at[rows[:, None], cols].set(toks, mode="drop")
            tok = jnp.where(sample, toks[rows, accept], tok)
            c, tok, gbuf = _pin(c, tok, gbuf)
            return c, tok, gbuf, accept, toks

        def merge_fn(full, one, slot):
            def merge(f, o):
                axis = None
                for i, (fs, os) in enumerate(zip(f.shape, o.shape)):
                    if os == 1 and fs == self.max_batch:
                        axis = i
                        break
                    if fs != os:
                        return f  # shape mismatch (e.g. enc_out cache len)
                if axis is None:
                    return f
                starts = tuple(slot if i == axis else 0
                               for i in range(f.ndim))
                return jax.lax.dynamic_update_slice(
                    f, o.astype(f.dtype), starts)
            return jax.tree.map(merge, full, one)

        # donated args: the fused cache + device token buffers are consumed
        # and returned every call, so XLA reuses their buffers in place
        self._decode = jax.jit(decode_fn, donate_argnums=(1, 2, 5))
        self._prefill_chunk = jax.jit(prefill_chunk_fn,
                                      donate_argnums=(1, 6, 7))
        self._step_unified = jax.jit(step_unified_fn,
                                     donate_argnums=(1, 10, 11))
        self._step_spec = jax.jit(step_spec_fn, donate_argnums=(1, 13, 14))
        self._prefill = jax.jit(
            lambda p, b: zoo.prefill(cfg, p, b, cache_len=cache_len))
        self._merge = jax.jit(merge_fn, donate_argnums=(0,))
        # COW resolution: whole-block device copies applied before a lease
        # writes into a block it shares with the prefix cache (pair lists
        # are padded to power-of-two lengths, so compiles stay O(log))
        def copy_blocks_fn(c, s, d):
            c = zoo.copy_paged_blocks(c, s, d)
            return c if plan is None else plan.constrain(c)

        self._copy_blocks = jax.jit(
            copy_blocks_fn, donate_argnums=(0,)) if self.paged else None

        # sensors (share the injected clock so tests can be deterministic).
        # tick_latency spans the WHOLE tick (admit + schedule + compute +
        # bookkeeping); decode_latency records only the model-compute span
        # of ticks that advanced at least one decoding slot — the latency a
        # decode token actually waited for, which is what the sc_chunk
        # controller must attribute to its own knob (a long prefill sharing
        # the tick inflates it; host-side admission work does not).
        # Under an SLO the latency windows shrink to slo.window so the
        # brownout controller reads the current load regime, not a stale
        # mix across a traffic shift.
        slo_window = slo.window if slo is not None else 512
        self.tick_latency = LatencySensor(clock=clock)
        self.decode_latency = LatencySensor(window=slo_window, clock=clock)
        self.ttft = LatencySensor(window=slo_window, clock=clock)
        # controller-facing TTFT, measured from ADMISSION ELIGIBILITY (the
        # tick the request first cleared the tier gate into the token
        # queue), not from submit().  The brownout gate's own parking delay
        # must never feed back into the signal that opens/closes the gate:
        # with submit-relative TTFT, every parked request re-admitted after
        # a burst carries a blown sample, p99 stays pinned above the goal,
        # and the gate latches shut (observed: goodput collapse).  True
        # client TTFT (self.ttft) still decides goodput.
        self.ttft_ctrl = LatencySensor(window=slo_window, clock=clock)
        self.throughput = ThroughputSensor(window_seconds=5.0, clock=clock)

        # SLO / multi-tenant overload state (serve/README.md): tiered
        # admission with graceful brownout, per-request deadlines, and
        # goodput-under-SLO accounting at completion
        self.slo = slo
        self.num_tiers = max(1, int(num_tiers))
        self.admit_tier_max = (self.num_tiers - 1 if admit_tier_max is None
                               else int(admit_tier_max))
        self.slo_good_requests = 0
        self.slo_miss_requests = 0
        self.slo_good_tokens = 0
        self.slo_miss_tokens = 0
        # chaos hook: every sensor reading the controllers consume passes
        # through the tap (fault injection corrupts here; the SmartConf
        # guardrails are what must absorb it)
        self.sensor_tap: Callable[[str, float], float] | None = \
            opts.sensor_tap
        # worker-preemption wiring (distributed.fault_tolerance): on
        # trigger the engine drains — requeues every in-flight request and
        # refuses new work with a typed reason — instead of crashing
        self.preemption = preemption if preemption is not None \
            else PreemptionHandler()
        self._draining = False
        self._closed = False

        # SmartConf PerfConfs
        self.enable_smartconf = enable_smartconf
        self.max_queue_tokens = 4 * cache_len
        self.prefill_chunk = cache_len
        self.sc_queue = None
        self.sc_kv = None
        self.sc_chunk = None
        self.sc_admit = None
        self.sc_cache = None
        self.sc_spec = None
        # the decode-latency goal is shared: sc_chunk targets it directly,
        # and the sc_spec knob is SUBORDINATE to it (accept-rate is a soft
        # goal; a blown decode p99 overrides and shrinks the draft depth)
        self._decode_goal = latency_goal_s if latency_goal_s is not None \
            else (slo.decode_s if slo is not None else None)
        # sensor-sanity guardrails for every serve controller: a dropped-out
        # or chaos-corrupted sensor (NaN, negative, physically impossible
        # spike) must never reach Eq. 2 — after 3 consecutive insane
        # readings the knob pins to its last-known-good value
        byte_rails = Guardrails(perf_lo=0.0, perf_hi=1e15)
        lat_rails = Guardrails(perf_lo=0.0, perf_hi=3600.0)
        if enable_smartconf and hbm_budget_bytes:
            goal = GoalSpec(float(hbm_budget_bytes), hard=True,
                            super_hard=True)
            self.sc_queue = SmartConfIndirect(
                "serve.max_queue_tokens", metric="hbm_bytes", goal=goal,
                initial=0.0, registry=self.registry, guardrails=byte_rails,
                model=ControllerModel(alpha=float(QUEUE_TOKEN_BYTES),
                                      lam=0.05, delta=1.15, conf_min=0.0,
                                      conf_max=1e9))
            # attention-free archs have block_bytes == 0 (O(1) state); floor
            # the gain so the controller degrades to a no-op instead of a
            # divide-by-zero
            self.sc_kv = SmartConfIndirect(
                "serve.kv_block_budget", metric="hbm_bytes", goal=goal,
                initial=1.0, registry=self.registry,
                guardrails=dataclasses.replace(byte_rails),
                model=ControllerModel(alpha=float(max(1, self.pool.block_bytes)),
                                      lam=0.05, delta=1.15, conf_min=1.0,
                                      conf_max=1e9))
            decode_goal = self._decode_goal
            if decode_goal is not None:
                # alpha: prefill seconds per token, measured lazily; start
                # 1e-4.  The slew clamp bounds one actuation to a quarter of
                # the knob range: a single insane error cannot slam the
                # chunk budget across its whole span in one interval.
                self.sc_chunk = SmartConf(
                    "serve.prefill_chunk_tokens", metric="decode_p99_s",
                    goal=GoalSpec(decode_goal, hard=False),
                    initial=float(cache_len), registry=self.registry,
                    guardrails=dataclasses.replace(
                        lat_rails, max_step=max(float(block_tokens),
                                                cache_len / 4.0)),
                    model=ControllerModel(alpha=1e-4, lam=0.1, delta=1.3,
                                          conf_min=float(block_tokens),
                                          conf_max=float(cache_len)))
        if enable_smartconf and slo is not None and admit_tier_max is None:
            # graceful-brownout controller: admit_tier_max is a direct
            # PerfConf on TTFT-p99 — overload pushes p99 past the (hard)
            # SLO goal, the two-pole controller sheds the lowest tiers
            # first (conf drops), and calm traffic re-opens them.  alpha =
            # one tier's worth of TTFT per step, in goal units: admitting
            # one more tier is modeled to add ~0.5 x the SLO bound to p99.
            self.sc_admit = SmartConf(
                "serve.admit_tier_max", metric="ttft_p99_s",
                goal=GoalSpec(float(slo.ttft_s), hard=True),
                initial=float(self.num_tiers - 1), registry=self.registry,
                guardrails=dataclasses.replace(lat_rails),
                model=ControllerModel(alpha=0.5 * float(slo.ttft_s),
                                      lam=0.1, delta=1.3, conf_min=0.0,
                                      conf_max=float(self.num_tiers - 1)))
        if enable_smartconf and self._prefix_cache is not None:
            # cache-share controller: serve.kv_cache_share is a direct
            # PerfConf on the windowed token-weighted prefix hit rate with
            # a LOWER-direction goal (the hit rate should stay above it).
            # alpha > 0: granting the cache a larger share of the block
            # budget retains more prefixes and raises the hit rate.  The
            # guardrails pin the sensor to [0, 1] (a rate) and slew-clamp
            # one actuation to a tenth of the knob span; the knob itself is
            # continuous (integer=False) in [0.05, 0.9] — the cache never
            # starves resident sequences entirely, and never vanishes so
            # abruptly the hit-rate sensor loses its signal.
            self.sc_cache = SmartConf(
                "serve.kv_cache_share", metric="prefix_hit_rate",
                goal=GoalSpec(float(opts.prefix_hit_rate_goal),
                              direction="lower"),
                initial=self.kv_cache_share, registry=self.registry,
                guardrails=Guardrails(perf_lo=0.0, perf_hi=1.0,
                                      max_step=0.1),
                model=ControllerModel(alpha=1.0, lam=0.05, delta=1.2,
                                      conf_min=0.05, conf_max=0.9,
                                      integer=False))
        if enable_smartconf and self.spec_enabled and opts.spec_adaptive:
            # draft-depth controller: serve.spec_depth is a direct PerfConf
            # on the windowed accept rate with a LOWER-direction soft goal
            # (the rate should stay above the setpoint).  alpha < 0 — the
            # sign-correct gain for an inversely-related pair: deepening the
            # draft DROPS the accept rate (late draft positions are less
            # predictable), so a rate above goal opens headroom to deepen
            # and a rate below it shallows.  The guardrails pin the sensor
            # to [0, 1] and slew-clamp one actuation to 2 depth steps; the
            # knob is integer in [1, spec_depth_max] — depth 0 is an
            # operator choice (spec off), never a controller state, so the
            # accept-rate sensor always keeps its signal.
            self.sc_spec = SmartConf(
                "serve.spec_depth", metric="accept_rate",
                goal=GoalSpec(float(opts.accept_rate_goal),
                              direction="lower"),
                initial=float(self.spec_depth), registry=self.registry,
                guardrails=Guardrails(perf_lo=0.0, perf_hi=1.0,
                                      max_step=2.0),
                model=ControllerModel(alpha=-0.08, lam=0.1, delta=1.3,
                                      conf_min=1.0,
                                      conf_max=float(self.spec_depth_max)))

        # ------------------------------------------------------- telemetry
        # Off by default, and free when off: a disabled (or absent) hub
        # collapses to self._tel = None, so the hot path pays exactly one
        # `is not None` test per instrumentation point — the disabled path
        # IS the pre-telemetry path (bench_overhead gates <1% in CI).
        # REPRO_TELEMETRY=1 force-enables it for the CI telemetry leg
        # without touching call sites (same pattern as REPRO_PREFILL_MODE).
        self.ticks_run = 0
        if telemetry is None and opts.telemetry_env:
            telemetry = Telemetry(enabled=True, clock=clock)
        self._tel = telemetry if (telemetry is not None
                                  and telemetry.enabled) else None
        self._tick_readings: dict[str, tuple[float, float]] = {}
        if self._tel is not None:
            # pre-create the hot-path instruments so ticks never take the
            # registry's get-or-create branch
            m = self._tel.metrics
            self._tel_h_tick = m.histogram("serve.tick_latency_s")
            self._tel_h_decode = m.histogram("serve.decode_latency_s")
            self._tel_h_ttft = m.histogram("serve.ttft_s")
            self._tel_c_ticks = m.counter("serve.ticks")
            self._tel_c_tokens = m.counter("serve.tokens")
            self._tel_c_spec_prop = m.counter("serve.spec.proposed")
            self._tel_c_spec_acc = m.counter("serve.spec.accepted")
            self._tel_h_spec = m.histogram("serve.spec.accepted_len")
            for reason in RejectReason:
                m.counter(f"serve.reject.{reason}")
            self._tick_rejects0 = 0
            self._tel_faults_seen = 0
            self._tel_fallback_seen: set[str] = set()
            for sc in (self.sc_queue, self.sc_kv, self.sc_chunk,
                       self.sc_admit, self.sc_cache, self.sc_spec):
                if sc is not None:
                    sc.attach_audit(self._tel.audit)

    # ------------------------------------------------------------------ API
    def _reject(self, req: Request, reason: RejectReason) -> RejectReason:
        """Typed rejection: the request is recorded (``shed``), counted,
        and stamped with the reason — never an exception mid-tick."""
        req.reject_reason = reason
        req.done_t = self.clock()
        self.rejected += 1
        self.reject_counts[str(reason)] += 1
        self.shed.append(req)
        if self._tel is not None:
            self._tel.metrics.counter(f"serve.reject.{reason}").inc()
            self._tel.tracer.async_end(
                "request", req.req_id, args={"rejected": str(reason)})
        return reason

    def submit(self, req: Request) -> Admission:
        """Validate + enqueue; returns a typed :class:`Admission` receipt
        (truthy on acceptance, carrying the reject reason otherwise, plus
        the request's block footprint and — when the prefix cache is on —
        an advisory count of prompt tokens a cache hit would cover right
        now).  Invalid work is rejected *here*, at the door — an empty
        prompt, a prompt that cannot fit the KV ring, or a footprint no
        block budget could ever hold would otherwise crash (or silently
        spin) the scheduler mid-tick."""
        req.prompt_bytes = len(req.prompt) * QUEUE_TOKEN_BYTES
        req.submitted_t = self.clock()
        fp = self._footprint_blocks(req)
        if self._draining or self.preemption.triggered:
            return Admission(False, self._reject(req, RejectReason.DRAINING),
                             footprint_blocks=fp)
        if len(req.prompt) == 0:
            return Admission(False,
                             self._reject(req, RejectReason.EMPTY_PROMPT),
                             footprint_blocks=fp)
        npatch = self.cfg.num_patches if self.cfg.frontend == "vision" else 0
        total = npatch + len(req.prompt) + req.max_new_tokens
        if total > self.cache_len:
            # beyond cache_len the KV ring wraps (prompt history or sampled
            # tokens silently fall out) — shed loudly instead
            return Admission(False,
                             self._reject(req, RejectReason.PROMPT_TOO_LONG),
                             footprint_blocks=fp)
        if fp > self._kv_budget_ceiling():
            # no admission order could ever schedule this request under the
            # block budget: refusing now beats queueing it to spin forever
            return Admission(False,
                             self._reject(req, RejectReason.KV_FOOTPRINT),
                             footprint_blocks=fp)
        hit = (self._prefix_cache.probe(req.prompt)
               if self._prefix_cache is not None else 0)
        self.waiting.append(req)
        return Admission(True, None, prefix_hit_tokens=hit,
                         footprint_blocks=fp)

    def _footprint_blocks(self, req: Request) -> int:
        """KV blocks the request needs resident while running."""
        npatch = self.cfg.num_patches if self.cfg.frontend == "vision" else 0
        need = min(npatch + len(req.prompt) + req.max_new_tokens,
                   self.cache_len)
        return -(-need // self.pool.block_tokens)

    def _kv_budget_ceiling(self) -> int:
        """Largest block budget a request could ever see: the live budget
        for static engines, the structural store ceiling when SmartConf owns
        (and may later raise) the budget."""
        if self.sc_kv is not None:
            return self.max_batch * self.blocks_per_seq
        return self.pool.max_blocks

    def hbm_bytes(self) -> int:
        return self.accountant.total()

    def kv_shard_bytes(self) -> int:
        """Per-device bytes of the resident KV cache tree — the mesh-aware
        HBM gauge.  Without a mesh this is the whole tree; with one, the
        K/V planes divide by the model-axis size, so for a paged store
        (K/V planes only) ``kv_shard_bytes() * tp_shards`` reproduces the
        single-device total exactly."""
        if self._cache_plan is not None:
            return self._cache_plan.shard_bytes(self.caches)
        return sum(int(a.size) * a.dtype.itemsize
                   for a in jax.tree.leaves(self.caches))

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill/packed-stream programs compiled so far: one per
        packed stream width (unified), per padded bucket width (bucketed),
        or per distinct prompt length (legacy).  Tracked by input shape on
        the engine side (the jitted callables are per-engine lambdas, so
        shape count == jit cache size) to avoid depending on private jax
        cache introspection."""
        return len(self._prefill_shapes)

    @property
    def model_programs(self) -> int:
        """Total distinct compiled model programs serving the hot loop:
        the prefill/packed-stream shapes plus the standalone decode
        program.  Split-path engines (bucketed/legacy) dispatch the decode
        program every running tick; a unified packed engine compiles it
        only once drain (decode-only) ticks occur — mixed ticks fuse
        decode into the stream dispatch."""
        return len(self._prefill_shapes) + (1 if self._decode_dispatched
                                            else 0)

    # ------------------------------------------------------------- one tick
    def tick(self) -> dict:
        if self.mesh is None:
            return self._tick_impl()
        # the serving mesh + rule overlay are active for the WHOLE tick:
        # every trace made this tick (step fns, COW copies, resizes) sees
        # current_mesh(), so the attention wrappers engage shard_map and
        # only head-parallel work shards (SERVE_TP_RULES nulls the
        # training-only ff/vocab rules that would change contraction order)
        with use_mesh(self.mesh, rules=SERVE_TP_RULES, fsdp=False):
            return self._tick_impl()

    def _tick_impl(self) -> dict:
        t0 = self.clock()
        self._tick_issued = self._tick_live = 0
        self._tick_packed_segments = 0
        self._tick_dispatches = 0
        self._tick_decode = 0
        self._tick_prefix_hit = 0
        self._tick_spec_proposed = self._tick_spec_accepted = 0
        self._tick_spec_lanes = self._tick_decode_slots = 0
        tel = self._tel
        if tel is not None:
            tel.audit.tick = self.ticks_run
            tel.tracer.begin_tick(self.ticks_run)
            self._tick_readings = {}
            self._tick_rejects0 = self.rejected
        if self.preemption.triggered:
            # worker preemption: drain once (requeue every in-flight
            # request, copy-free), then idle — never crash mid-tick.  The
            # queue survives for a handoff or an in-place resume.
            if not self._draining:
                self._drain_for_preemption()
            self.tick_latency.record(self.clock() - t0)
            stats = self._stats(0)
            self.ticks_run += 1
            if tel is not None:
                tel.tracer.phase("drain")
                self._tel_finish_tick(stats, self.clock() - t0)
            return stats
        self._draining = False          # preemption cleared: resume serving
        if tel is not None:
            tel.tracer.phase("control")
        self._update_controllers()
        self._shed_expired()
        if tel is not None:
            tel.tracer.phase("admit")
        self._admit()
        if tel is not None:
            tel.tracer.phase("schedule")
        self._schedule()
        if self.spec_enabled:
            n_tokens = self._tick_spec()
        elif self.prefill_impl == "packed":
            n_tokens = self._tick_unified()
        else:
            if tel is not None:
                tel.tracer.phase("pack")
            self._prefill_tick()
            if tel is not None:
                tel.tracer.phase("dispatch")
            n_tokens = self._decode_tick()
        if tel is not None:
            tel.tracer.phase("finish")
        self._finish()
        if self._window_evict:
            self._trim_windows()
        self.tick_latency.record(self.clock() - t0)
        stats = self._stats(n_tokens)
        self.ticks_run += 1
        if tel is not None:
            self._tel_finish_tick(stats, self.clock() - t0)
        return stats

    def _stats(self, n_tokens: int) -> dict:
        # NOTE: keys and their order are the frozen TickStats schema
        # (TICK_STATS_KEYS, regression-tested) — extend at the end only.
        return {
            "tick": self.ticks_run,
            "queued": len(self.queued),
            "waiting": len(self.waiting),
            "running": len(self.running) + len(self.prefilling),
            "finished": len(self.finished), "hbm": self.hbm_bytes(),
            "tokens": n_tokens,
            # prefill-knob deputy sensors: the fraction of this tick's
            # issued prefill tokens that were dead padding, and how many
            # request segments shared the tick's prefill call(s) (packed:
            # several per call even when their natural buckets differ)
            "pad_fraction": (1.0 - self._tick_live / self._tick_issued
                             if self._tick_issued else 0.0),
            "packed_segments": self._tick_packed_segments,
            # jitted model calls this tick: the unified packed path costs
            # exactly one; split paths cost up to two (prefill + decode)
            "dispatches": self._tick_dispatches,
            # work mix this tick (the open-loop harness's virtual cost
            # model charges prefill lanes — padding included, it costs
            # compute — and decode tokens separately)
            "prefill_tokens": self._tick_live,
            "prefill_issued_tokens": self._tick_issued,
            "decode_tokens": self._tick_decode,
            # pool-pressure sensors (budget-vs-occupancy, bench_serving)
            "kv_used_blocks": self.pool.used_blocks,
            "kv_budget_blocks": self.pool.max_blocks,
            "kv_capacity_blocks": getattr(self.pool, "capacity",
                                          self.pool.max_blocks),
            "kv_over_budget": self.pool.over_budget,
            "kv_frag_tokens": self.pool.frag_tokens,
            "preemptions": self.preemptions,
            # SLO / overload sensors (serve/README.md)
            "admit_tier_max": self.admit_tier_max,
            "rejected": self.rejected,
            "draining": self._draining,
            "slo_good_tokens": self.slo_good_tokens,
            "slo_miss_tokens": self.slo_miss_tokens,
            # prefix-cache sensors (radix tree over refcounted blocks)
            "prefix_hit_tokens": self._tick_prefix_hit,
            "prefix_cache_blocks": (self._prefix_cache.blocks_held
                                    if self._prefix_cache is not None
                                    else 0),
            "kv_cache_share": self.kv_cache_share,
            # speculative-decode sensors (draft-and-verify on the packed
            # stream); decode_slots is the per-tick KV-read unit the cost
            # model charges now that decode_tokens can exceed it
            "spec_depth": self.spec_depth,
            "accept_rate": (self._tick_spec_accepted
                            / self._tick_spec_proposed
                            if self._tick_spec_proposed else 0.0),
            "spec_lanes": self._tick_spec_lanes,
            "decode_slots": self._tick_decode_slots,
            # mesh-serving sensor: model-axis shards behind this tick
            "tp_shards": self.tp_shards,
        }

    def run(self, ticks: int) -> list[dict]:
        return [self.tick() for _ in range(ticks)]

    # ----------------------------------------------------------- telemetry
    def _tel_finish_tick(self, stats: dict, wall_dt: float) -> None:
        """Per-tick telemetry epilogue (only reached when enabled): close
        the tick span, fold the stats into the metrics, snapshot the
        sensor readings into the flight-recorder ring, and dump the ring
        on any guardrail fault, fallback engagement, or rejection storm."""
        tel = self._tel
        tick = stats["tick"]
        tel.tracer.end_tick(args={
            "tokens": stats["tokens"], "queued": stats["queued"],
            "running": stats["running"], "rejected": stats["rejected"],
            "admit_tier_max": stats["admit_tier_max"],
            "draining": stats["draining"]})
        self._tel_c_ticks.inc()
        self._tel_c_tokens.inc(stats["tokens"])
        if wall_dt > 0.0:
            # wall span of the tick body; under a VirtualClock this is 0
            # (the clock is frozen within a tick) and the open-loop driver
            # charges the virtual cost through charge_tick_cost instead
            self._tel_h_tick.record(wall_dt)
        m = tel.metrics
        m.gauge("serve.hbm_bytes").set(float(stats["hbm"]))
        m.gauge("serve.admit_tier_max").set(float(stats["admit_tier_max"]))
        m.gauge("serve.kv_used_blocks").set(float(stats["kv_used_blocks"]))
        m.gauge("serve.queued_tokens").set(float(self.queued_tokens))
        tel.flight.record(tick, dict(self._tick_readings))
        faults = 0
        for sc in (self.sc_queue, self.sc_kv, self.sc_chunk, self.sc_admit,
                   self.sc_cache, self.sc_spec):
            if sc is None:
                continue
            faults += sc.sensor_faults
            if sc.sensor_failed:
                if sc.conf_name not in self._tel_fallback_seen:
                    self._tel_fallback_seen.add(sc.conf_name)
                    tel.flight.dump(f"fallback:{sc.conf_name}", tick)
            else:
                self._tel_fallback_seen.discard(sc.conf_name)
        if faults > self._tel_faults_seen:
            self._tel_faults_seen = faults
            tel.flight.dump("guardrail_fault", tick)
        if self.rejected - self._tick_rejects0 >= _REJECT_STORM_PER_TICK:
            tel.flight.dump("rejection_storm", tick)

    def note_chaos(self, name: str) -> None:
        """Chaos-injection stamp (called by ChaosMonkey): the fault lands
        on the trace timeline next to the tick it hit, counts in the
        metrics, and dumps the flight recorder — fault <-> controller
        response causality in one artifact set."""
        if self._tel is None:
            return
        tel = self._tel
        tel.tracer.instant(f"chaos:{name}", tid=Tracer.TID_CHAOS,
                           args={"tick": self.ticks_run})
        tel.metrics.counter(f"chaos.{name.split(':', 1)[0]}").inc()
        tel.flight.dump(f"chaos:{name.split(':', 1)[0]}", self.ticks_run)

    def note_arrival(self, req: Request) -> None:
        """Driver-side arrival stamp: an instant on the driver track plus
        the open end of the request's async lifetime span (closed at
        finish or rejection)."""
        if self._tel is None:
            return
        trc = self._tel.tracer
        trc.instant("arrival", tid=Tracer.TID_DRIVER,
                    args={"req": req.req_id, "tier": req.tier})
        trc.async_begin("request", req.req_id,
                        args={"tier": req.tier,
                              "prompt_len": int(len(req.prompt)),
                              "deadline_s": req.deadline_s})

    def charge_tick_cost(self, dt: float, *, decoded: bool = False) -> None:
        """Virtual-time cost feedback from the open-loop driver: the clock
        is frozen within a tick, so the driver charges the modeled tick
        cost into the latency sensors (and telemetry histograms) after the
        fact — the controllers and the trace see the same virtual time the
        requests experience."""
        self.tick_latency.record(dt)
        if decoded:
            self.decode_latency.record(dt)
        if self._tel is not None:
            self._tel_h_tick.record(dt)
            if decoded:
                self._tel_h_decode.record(dt)

    # ------------------------------------------------------------ internals
    def _sense(self, name: str, value: float) -> float:
        """Controller-facing sensor read — the ONE road a reading takes to
        a controller.  Routed through the chaos tap when one is installed
        (fault injection corrupts readings here; the SmartConf guardrails
        must absorb whatever comes back) and recorded raw+tapped into the
        flight recorder's per-tick snapshot, so chaos, the controllers,
        and the flight recorder all observe the identical stream.  Every
        reading a controller consumes must pass through here — including
        the indirect confs' deputies."""
        tap = self.sensor_tap
        out = tap(name, value) if tap is not None else value
        if self._tel is not None:
            self._tick_readings[name] = (value, out)
        return out

    def _update_controllers(self) -> None:
        if not self.enable_smartconf:
            return
        if self.sc_queue is not None:
            hbm = self._sense("hbm_bytes", float(self.hbm_bytes()))
            self.sc_queue.set_perf(
                hbm, self._sense("queued_tokens", float(self.queued_tokens)))
            self.max_queue_tokens = max(0, int(self.sc_queue.get_conf()))
            self.sc_kv.set_perf(
                hbm,
                self._sense("kv_used_blocks", float(self.pool.used_blocks)))
            self.pool.set_budget(max(1, int(self.sc_kv.get_conf())))
            if self.paged and self.pool.over_budget:
                # the budget bit below occupancy: make the cut physical
                self._enforce_kv_budget()
            if self.sc_chunk is not None:
                self.sc_chunk.set_perf(
                    self._sense("decode_p99_s", self.decode_latency.p99()))
                self.prefill_chunk = max(1, int(self.sc_chunk.get_conf()))
        if self.sc_admit is not None:
            # per-tick censored observation: the head-of-line request's
            # eventual TTFT is at least its current wait; an empty queue
            # contributes zero.  Without this the sensor FREEZES when the
            # gate closes (nothing finishes -> no samples -> p99 pinned at
            # the burst-era value) and the brownout latches shut while the
            # engine idles; with it the window drains in ~window ticks of
            # calm and the gate re-opens.
            now = self.clock()
            if self.queued:
                head = self.queued[0]
                epoch = head.queued_t if head.queued_t is not None \
                    else head.submitted_t
                self.ttft_ctrl.record(max(0.0, now - epoch))
            else:
                self.ttft_ctrl.record(0.0)
            self.sc_admit.set_perf(
                self._sense("ttft_p99_s", self.ttft_ctrl.p99()))
            self.admit_tier_max = int(self.sc_admit.get_conf())
        if self.sc_cache is not None and self._hit_window:
            # token-weighted hit rate over the recent admission window:
            # raw per-lookup hit counts overweight short prompts, and the
            # reclaimed capacity the share buys is proportional to tokens.
            # No admissions yet -> no observation -> no actuation (a cold
            # window is not evidence the share is wrong)
            hw = self._hit_window
            rate = sum(h for h, _ in hw) / max(1, sum(p for _, p in hw))
            self.sc_cache.set_perf(self._sense("prefix_hit_rate", rate))
            self.kv_cache_share = float(self.sc_cache.get_conf())
            self._prefix_cache.enforce(
                int(self.kv_cache_share * self.pool.max_blocks))
        if self.sc_spec is not None and self._accept_window:
            # windowed accept rate drives the depth; no drafts verified yet
            # -> no observation -> no actuation.  The accept-rate goal is
            # SOFT and subordinate: when decode p99 blows its (engine-wide)
            # goal, verifying lanes are what the tick can shed fastest, so
            # the depth steps down one regardless of what Eq. 2 wants.
            aw = self._accept_window
            rate = sum(a for a, _ in aw) / max(1, sum(p for _, p in aw))
            self.sc_spec.set_perf(self._sense("accept_rate", rate))
            depth = int(self.sc_spec.get_conf())
            if (self._decode_goal is not None
                    and self.decode_latency.p99() > self._decode_goal):
                depth = min(depth, max(1, self.spec_depth - 1))
            self.spec_depth = max(1, min(depth, self.spec_depth_max))

    def _stamp_first_token(self, req: Request, now: float) -> None:
        """One TTFT sample per request, at the first compute response
        (preempted requests keep their original stamp).  Two sensors: the
        client-true TTFT (from submit; decides goodput) and the
        controller-facing TTFT (from first admission past the tier gate;
        feeds sc_admit — see the ttft_ctrl construction note)."""
        if req.first_token_t is not None:
            return
        req.first_token_t = now
        self.ttft.record(now - req.submitted_t)
        epoch = req.queued_t if req.queued_t is not None else req.submitted_t
        self.ttft_ctrl.record(now - epoch)
        if self._tel is not None:
            self._tel_h_ttft.record(now - req.submitted_t)

    def _shed_expired(self) -> None:
        """Deadline-expired requests still waiting in line are shed with a
        typed reason: serving them would burn capacity on tokens no client
        is waiting for (zero goodput), which is exactly what an overloaded
        engine cannot afford."""
        now = self.clock()

        def expired(req: Request) -> bool:
            return (req.deadline_s is not None
                    and now - req.submitted_t > req.deadline_s)

        if any(expired(r) for r in self.waiting):
            keep: collections.deque[Request] = collections.deque()
            for req in self.waiting:
                if expired(req):
                    self._reject(req, RejectReason.DEADLINE_EXPIRED)
                else:
                    keep.append(req)
            self.waiting = keep
        if any(expired(r) for r in self.queued):
            keep = collections.deque()
            for req in self.queued:
                if expired(req):
                    self.queued_tokens -= len(req.prompt)
                    self.accountant.credit("queue", req.prompt_bytes)
                    self._reject(req, RejectReason.DEADLINE_EXPIRED)
                else:
                    keep.append(req)
            self.queued = keep

    def _admit(self) -> None:
        """FIFO admission gated by the brownout tier: requests above
        ``admit_tier_max`` stay in the waiting line while their TTFT SLO is
        still winnable (requeue — the brownout may lift) without blocking
        eligible tiers behind them (no head-of-line starvation across
        tiers).  Once a browned-out request's TTFT SLO is already blown it
        is *shed* with a typed reason: serving it late is zero goodput that
        would queue ahead of fresh, still-winnable traffic when the gate
        re-opens — the client gets a fast typed rejection instead of a slow
        useless answer."""
        # the gate applies to the already-admitted queue too: when it
        # drops, queued requests above it (not yet prefilling — no KV to
        # drop) are pushed back to the *front* of the waiting line in
        # admission order.  Without this, the gulp admitted during the
        # controller's reaction lag at a load shift (or an off-burst
        # re-open) sits in the queue ahead of premium traffic and blows
        # the very TTFT the gate closed to protect.
        if any(r.tier > self.admit_tier_max for r in self.queued):
            keep: collections.deque[Request] = collections.deque()
            back: list[Request] = []
            for req in self.queued:
                if req.tier > self.admit_tier_max:
                    self.queued_tokens -= len(req.prompt)
                    self.accountant.credit("queue", req.prompt_bytes)
                    back.append(req)
                else:
                    keep.append(req)
            self.queued = keep
            self.waiting.extendleft(reversed(back))
        browned: collections.deque[Request] = collections.deque()
        now = self.clock()
        while self.waiting:
            req = self.waiting.popleft()
            if req.tier > self.admit_tier_max:
                if (self.slo is not None
                        and now - req.submitted_t > self.slo.ttft_s):
                    self._reject(req, RejectReason.BROWNOUT_SHED)
                else:
                    browned.append(req)     # shed lowest tiers first: wait
                continue
            if self.queued_tokens + len(req.prompt) > self.max_queue_tokens:
                browned.append(req)         # queue full: FIFO order holds
                break
            if req.queued_t is None:
                req.queued_t = now          # the ttft_ctrl epoch (once)
            self.queued.append(req)
            self.queued_tokens += len(req.prompt)
            self.accountant.charge("queue", req.prompt_bytes)
        browned.extend(self.waiting)
        self.waiting = browned

    def _schedule(self) -> None:
        while self.queued and self._free_slots:
            req = self.queued[0]
            total = len(req.prompt) + req.max_new_tokens
            need = min(total, self.cache_len)
            if self._footprint_blocks(req) > self.pool.max_blocks:
                # the budget (possibly cut mid-run, below this request's
                # remaining footprint) can NEVER hold it: park it out of
                # the schedule with a typed reason instead of the
                # preempt-readmit-recompute livelock a blind retry becomes
                self.queued.popleft()
                self.queued_tokens -= len(req.prompt)
                self.accountant.credit("queue", req.prompt_bytes)
                self._reject(req, RejectReason.KV_FOOTPRINT)
                continue
            lease, hit = self._lease_for(req, need)
            if lease is None:
                break  # KV budget exhausted; stay queued
            self.queued.popleft()
            self.queued_tokens -= len(req.prompt)
            self.accountant.credit("queue", req.prompt_bytes)
            req.slot = self._free_slots.popleft()
            req.admit_seq = self._admit_counter
            self._admit_counter += 1
            req.lease = lease
            req.prefix_hit = hit
            req.prefilled = hit      # cached prefix: skip to the suffix
            if hit:
                self.prefix_hit_tokens_total += hit
                self._tick_prefix_hit += hit
            if self._prefix_cache is not None:
                self._hit_window.append((hit, len(req.prompt)))
            if self.paged:
                self._bt_np[req.slot] = lease.table_row()
                self._bt_dirty = True
            if self.fused_prefill:
                self.prefilling[req.slot] = req
            else:
                self._do_prefill_legacy(req)
                self.running[req.slot] = req

    def _lease_for(self, req: Request,
                   need: int) -> tuple[object | None, int]:
        """Acquire the request's KV lease, adopting any cached prefix and
        materializing the COW boundary copy.  On allocation failure the
        coldest cached prefix is evicted and the acquisition retried — cold
        cache yields before live traffic waits (and long before anything is
        preempted).  Returns ``(lease, prefix_hit_tokens)`` or
        ``(None, 0)`` when the budget genuinely cannot hold the request."""
        cache = self._prefix_cache
        T = self.pool.block_tokens
        while True:
            if cache is not None:
                hit, shared = cache.lookup(req.prompt, self.ticks_run)
            else:
                hit, shared = 0, []
            fresh = -(-need // T) - len(shared)
            if self.paged and self.pool.free_blocks < fresh:
                # store smaller than demand (start-small under an HBM goal,
                # or shrunk by an earlier cut): grow it first so a free-list
                # miss is never miscounted as an allocation failure
                self._grow_store_for(fresh * T)
            lease = self.pool.lease(need, shared=shared or None)
            if lease is not None:
                pairs = lease.writable(hit, need) if hit else []
                if pairs is not None:
                    if pairs:
                        self._apply_cow(pairs)
                    return lease, hit
                lease.release()    # COW target blocks unavailable: retry
            if cache is None or cache.evict_lru_leaf() == 0:
                return None, 0

    def _apply_cow(self, pairs: list[tuple[int, int]]) -> None:
        """Materialize copy-on-write: one fused gather/scatter duplicates
        each shared source block into its private replacement *before* this
        tick's writes touch the lease.  The pair list is padded to its
        power-of-two bucket by REPEATING the last real pair — a duplicated
        copy writes identical bytes and is shape-stable, whereas a (0, 0)
        filler could collide with a real destination block."""
        n = len(pairs)
        pad = pairs + [pairs[-1]] * (_bucket(n) - n)
        src = jnp.asarray(np.asarray([p[0] for p in pad], np.int32))
        dst = jnp.asarray(np.asarray([p[1] for p in pad], np.int32))
        self.caches = self._copy_blocks(self.caches, src, dst)
        self.cow_copied_blocks += n

    # --------------------------------------------- paged KV: physical budget
    def _bt(self) -> jnp.ndarray:
        """Device block-table operand, refreshed lazily after table edits."""
        if self._bt_dirty:
            self._bt_dev = jnp.asarray(self._bt_np)
            self._bt_dirty = False
        return self._bt_dev

    def set_kv_budget(self, blocks: int) -> None:
        """Manual ``serve.kv_block_budget`` actuation (benchmarks / ops):
        preempts past occupancy and physically resizes the block store."""
        self.pool.set_budget(blocks)
        if self.paged:
            self._enforce_kv_budget()

    def _enforce_kv_budget(self) -> None:
        # a budget cut lands on the cache first: cold cached prefixes are
        # speculative capacity and yield before any live work is undone
        cache = self._prefix_cache
        while (cache is not None and self.pool.over_budget
               and cache.blocks_held > 0):
            if cache.evict_lru_leaf() == 0:
                break
        while self.pool.over_budget and (self.running or self.prefilling):
            self._preempt_lowest_priority()
        bps = self.blocks_per_seq
        target = min(-(-max(1, self.pool.max_blocks) // bps) * bps,
                     self.max_batch * bps)
        target = max(target, bps, self.pool.used_blocks)
        if target < self.pool.capacity:
            keep = jnp.asarray(self.pool.compact(target))
            self.caches = zoo.map_paged_caches(
                self.caches, lambda a, ax: jnp.take(a, keep, axis=ax))
            if self._cache_plan is not None:
                # the eager gather re-laid the stores out; re-pin the Kv-dim
                # placement before the next compiled tick consumes them
                self.caches = self._cache_plan.place(self.caches)
            for reqs in (self.prefilling, self.running):
                for slot, req in reqs.items():
                    self._bt_np[slot] = req.lease.table_row()
            self._bt_dirty = True

    def _grow_store_for(self, tokens: int) -> bool:
        need = -(-tokens // self.pool.block_tokens)
        full = self.max_batch * self.blocks_per_seq
        if (self.pool.used_blocks + need > self.pool.max_blocks
                or need > self.blocks_per_seq):
            return False   # genuinely over budget, not just store-limited
        bps = self.blocks_per_seq
        target = min(-(-(self.pool.used_blocks + need) // bps) * bps, full)
        if target <= self.pool.capacity:
            return False   # store large enough; ensure failed on budget
        head = self.accountant.headroom()
        if head is not None and (
                (target - self.pool.capacity) * self.pool.block_bytes > head):
            return False   # growing the store would blow the hard HBM goal
        added = self.pool.grow(target)

        def pad(a, ax):
            shape = list(a.shape)
            shape[ax] = added
            return jnp.concatenate([a, jnp.zeros(shape, a.dtype)], axis=ax)

        self.caches = zoo.map_paged_caches(self.caches, pad)
        if self._cache_plan is not None:
            self.caches = self._cache_plan.place(self.caches)
        return True

    def _preempt_lowest_priority(self) -> None:
        """Kick the lowest-priority sequence back to the queue — highest
        tier number first (brownout order: shed the cheapest tenants
        before premium traffic), newest-admitted within a tier
        (recompute-on-readmission, paper §4.2: the cut is enforced by
        temporarily undoing work, never by corrupting state)."""
        cands = list(self.prefilling.items()) + list(self.running.items())
        if not cands:
            return
        slot, req = max(cands, key=lambda sr: (sr[1].tier, sr[1].admit_seq))
        self._requeue_slot(slot, req)
        self.preemptions += 1
        if self._tel is not None:
            self._tel.tracer.instant(
                "preempt", args={"req": req.req_id, "tier": req.tier,
                                 "tick": self.ticks_run})
            self._tel.metrics.counter("serve.preemptions").inc()

    def _requeue_slot(self, slot: int, req: Request) -> None:
        """Undo a slot's in-flight work back to the queue head (state reset
        to prefilled=0: recompute on readmission, counted)."""
        self.prefilling.pop(slot, None)
        self.running.pop(slot, None)
        if self._drafter is not None:
            self._drafter.drop(slot)
        if req.lease is not None:
            # COW-safe: release only drops THIS lease's references — blocks
            # the radix tree still holds stay resident for future hits
            req.lease.release()
            req.lease = None
        self._free_slots.append(slot)
        self.slot_pos[slot] = -1
        if self.paged:
            self._bt_np[slot] = -1
            self._bt_dirty = True
        req.slot = None
        # cache-covered tokens were never computed, so they are not
        # recompute debt; the suffix and generated tokens are
        self.recompute_tokens += (req.prefilled - req.prefix_hit
                                  + req.gen_count)
        req.prefilled = 0
        req.prefix_hit = 0
        req.gen_count = 0
        req.generated = []
        req.preempted += 1
        self.queued.appendleft(req)
        self.queued_tokens += len(req.prompt)
        self.accountant.charge("queue", req.prompt_bytes)

    # ------------------------------------------------- worker preemption
    def _drain_for_preemption(self) -> None:
        """The serve-path answer to ``PreemptionHandler.trigger``: every
        in-flight request is requeued (newest first, so the queue keeps
        admission order), new submissions bounce with a typed reason, and
        ticks idle until the signal clears.  Nothing is lost: the queue is
        the elastic-restart handoff state."""
        in_flight = sorted(
            list(self.prefilling.items()) + list(self.running.items()),
            key=lambda sr: sr[1].admit_seq, reverse=True)
        for slot, req in in_flight:
            self._requeue_slot(slot, req)
            self.preemptions += 1
        self._draining = True
        if self._tel is not None:
            self._tel.tracer.instant(
                "worker_preemption_drain",
                args={"requeued": len(in_flight), "tick": self.ticks_run})
            self._tel.metrics.counter("serve.preemptions").inc(
                len(in_flight))

    def drained_requests(self) -> list[Request]:
        """Requests parked by a drain (queued + waiting, admission order):
        what a replacement worker resubmits after an elastic restart."""
        return list(self.queued) + list(self.waiting)

    @property
    def accepting(self) -> bool:
        """Whether ``submit`` would pass the drain gate right now: False
        from the preemption trigger until the first post-recovery tick
        clears the drain.  The replica router dispatches only to accepting
        engines, so a request is never burned on the typed ``draining``
        rejection another replica could have served."""
        return not (self._draining or self.preemption.triggered)

    def take_drained(self) -> list[Request]:
        """Hand off every parked request: the returned requests leave this
        engine's queues AND its memory ledger entirely.  The replica
        router calls this on a preempted replica after its drain tick —
        survivors resubmit the work, so a later rejoin of this engine must
        not also serve it (``drained_requests`` alone would double-serve)."""
        out = self.drained_requests()
        self.queued.clear()
        self.waiting.clear()
        self.queued_tokens = 0
        self.accountant.set("queue", 0)
        return out

    # ------------------------------------------------------------- prefill
    def _prefill_tick(self) -> None:
        if not self.prefilling:
            return
        self._prefill_tick_bucketed()

    def _record_prefill_pad(self, issued: int, live: int, segments: int):
        """Accumulates per tick: legacy mode prefills once per admitted
        request, so a tick can record several calls."""
        self.prefill_issued_tokens += issued
        self.prefill_live_tokens += live
        self._tick_issued += issued
        self._tick_live += live
        self._tick_packed_segments += segments

    @property
    def pad_fraction(self) -> float:
        """Cumulative padded-but-dead fraction of all prefill tokens issued:
        the gap between what ``serve.prefill_chunk_tokens`` claims to spend
        and the prompt tokens actually advanced (near-zero under packing).
        An engine that has issued zero prefill tokens has no padding to
        report — 0.0, not the 1.0 the old ``1 - 0/max(1, 0)`` produced."""
        if self.prefill_issued_tokens == 0:
            return 0.0
        return 1.0 - self.prefill_live_tokens / self.prefill_issued_tokens

    # --------------------------------------- unified prefill+decode stream
    def _tick_unified(self) -> int:
        """ONE ``step_packed`` dispatch advances the whole engine: prefill
        chunks from as many prefilling requests as fit under the live
        ``serve.prefill_chunk_tokens`` budget PLUS one length-1 decode
        segment per running slot, all packed into a single ``[1, width]``
        ragged stream in admission order.

        Decode tokens are mandatory riders — the split path decodes every
        running slot each tick, so token parity demands the same here —
        and they count against the literal token budget; prefill keeps a
        floor of one token per tick so a full decode batch can never
        starve it into livelock.  The stream width is the power-of-two
        bucket of the packed token count: whenever demand saturates the
        budget (the steady state under load) every tick reuses ONE
        compiled shape, and drain-tail ticks shrink to narrow shapes
        instead of issuing a mostly-dead full-width stream.  Returns the
        number of tokens generated this tick (decoders + prefill
        finishers, each of which samples from the same dispatch).

        A tick with no prefill work has nothing to fuse: it routes to the
        specialized decode program instead of padding decode tokens into a
        mostly-dead stream — still one dispatch (the split path never paid
        two on decode-only ticks either), at the decode program's exact
        cost.  The unified stream owns every tick where prefill and decode
        overlap, which is where the split path paid its second dispatch."""
        if not self.prefilling:
            if self._tel is not None:
                self._tel.tracer.phase("dispatch")
            return self._decode_tick()
        if self._tel is not None:
            self._tel.tracer.phase("pack")
        n_dec = len(self.running)
        budget = max(1, min(int(self.prefill_chunk), self.packed_width))
        demand = sum(len(r.prompt) - r.prefilled
                     for r in self.prefilling.values())
        pre_budget = min(max(1, budget - n_dec), demand)
        # the engine's one documented width cap still applies: a saturated
        # stream on a non-power-of-two cache_len must issue packed_width
        # lanes, not the next power of two's permanently-dead padding
        width = min(_bucket(pre_budget + n_dec), self.packed_width)
        width = max(width, pre_budget + n_dec)   # never truncate the stream
        tokens = np.zeros((1, width), np.int32)
        slot_id = np.full((width,), -1, np.int32)
        posw = np.zeros((width,), np.int32)
        start = np.zeros((self.max_batch,), np.int32)
        seg_len = np.zeros((self.max_batch,), np.int32)
        is_dec = np.zeros((width,), bool)
        sample = np.zeros((self.max_batch,), bool)
        gidx = np.full((self.max_batch,), self.cache_len, np.int32)
        done = np.zeros((self.max_batch,), bool)
        cursor = 0
        packed: list[tuple[int, Request, int]] = []
        for slot, req in sorted(self.prefilling.items(),
                                key=lambda sr: sr[1].admit_seq):
            if cursor >= pre_budget:
                break   # later arrivals re-pack from `prefilled` next tick
            n = min(len(req.prompt) - req.prefilled, pre_budget - cursor)
            tokens[0, cursor:cursor + n] = \
                req.prompt[req.prefilled:req.prefilled + n]
            slot_id[cursor:cursor + n] = slot
            posw[cursor:cursor + n] = np.arange(req.prefilled,
                                                req.prefilled + n)
            start[slot] = req.prefilled
            seg_len[slot] = n
            if req.prefilled + n >= len(req.prompt):
                done[slot] = sample[slot] = True
                gidx[slot] = 0               # first token -> gen ring head
            packed.append((slot, req, n))
            cursor += n
        pre_cursor = cursor
        decoders: list[tuple[int, Request]] = []
        for slot, req in sorted(self.running.items(),
                                key=lambda sr: sr[1].admit_seq):
            # the decode token itself lives on device (_slot_tok); the
            # stream carries a placeholder the jitted step fills in
            slot_id[cursor] = slot
            posw[cursor] = int(self.slot_pos[slot])
            is_dec[cursor] = True
            start[slot] = int(self.slot_pos[slot])
            seg_len[slot] = 1
            sample[slot] = True
            gidx[slot] = min(req.gen_count, self.cache_len)  # ==len => drop
            decoders.append((slot, req))
            cursor += 1
        t_disp = self.clock()
        if self._tel is not None:
            self._tel.tracer.phase("dispatch")
        self.caches, self._slot_tok, self._gen_buf = self._step_unified(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(slot_id), jnp.asarray(posw), jnp.asarray(start),
            jnp.asarray(seg_len), jnp.asarray(is_dec), jnp.asarray(sample),
            jnp.asarray(gidx), self._slot_tok, self._gen_buf,
            self._bt() if self.paged else None)
        self.model_dispatches += 1
        self._tick_dispatches += 1
        self._prefill_shapes.add(width)        # O(1): one packed shape
        if packed:
            self.prefill_calls += 1
            # the prefill-knob deputy counts prefill lanes only: decode
            # riders are always live and not governed by the knob
            self._record_prefill_pad(width - n_dec, pre_cursor, len(packed))
        self._tick_packed_segments += n_dec
        if n_dec or done.any():
            # a sampled token is a completion boundary: wait for the device
            # (no host transfer) so TTFT/decode latency reflect compute,
            # not async dispatch depth
            self._slot_tok.block_until_ready()
        if self._tel is not None:
            self._tel.tracer.phase("sample")
        if n_dec:
            dt = self.clock() - t_disp
            self.decode_latency.record(dt)
            if self._tel is not None and dt > 0.0:
                self._tel_h_decode.record(dt)
        now = self.clock()
        for slot, req, n in packed:
            req.prefilled += n
            req.prefill_chunks += 1
            if done[slot]:
                req.gen_count = 1            # first token is on device
                self._stamp_first_token(req, now)
                self.slot_pos[slot] = len(req.prompt)
                self.running[slot] = self.prefilling.pop(slot)
                self._cache_insert(req)
        for slot, req in decoders:
            self.slot_pos[slot] += 1
            req.gen_count += 1
        self._tick_decode = n_dec
        self._tick_decode_slots = n_dec
        n_tokens = n_dec + int(done.sum())
        if n_tokens:
            self.throughput.record(n_tokens)
        return n_tokens

    # --------------------------------- speculative prefill+decode stream
    def _tick_spec(self) -> int:
        """:meth:`_tick_unified` with draft-and-verify decode segments.

        Each running slot's mandatory decode rider grows from one lane to
        ``1 + d``: the device-resident pending token followed by ``d``
        host-drafted continuations (``NGramDrafter``, deterministic), all
        verified by per-offset argmax inside the SAME compiled dispatch
        that advances prefill chunks.  Greedy acceptance keeps the longest
        matching draft prefix plus the model's own next token, so a slot
        emits ``accept + 1`` tokens per dispatch — token-identical to
        ``accept + 1`` sequential non-speculative ticks by construction
        (``models/transformer.step_spec``), and ``spec_depth == 0`` is
        exactly the unified path.  Draft lanes ride the same width budget
        prefill does; the per-slot clamp keeps every draft inside the
        request's remaining token and cache budget, so speculation can
        never over-emit or outrun the KV lease."""
        if not self.prefilling and not self.running:
            return 0
        if self._tel is not None:
            self._tel.tracer.phase("pack")
        L = self._spec_len_max
        k_live = min(self.spec_depth, L - 1)
        # drafts first — the stream width depends on how many verify lanes
        # ride this tick
        drafts: list[tuple[int, Request, np.ndarray]] = []
        spec_tokens = 0
        for slot, req in sorted(self.running.items(),
                                key=lambda sr: sr[1].admit_seq):
            d_cap = min(k_live, req.max_new_tokens - req.gen_count - 1,
                        self.cache_len - 1 - int(self.slot_pos[slot]))
            d = self._drafter.propose(slot, d_cap) if d_cap > 0 \
                else np.zeros(0, np.int32)
            drafts.append((slot, req, d))
            spec_tokens += 1 + len(d)
        n_dec = len(drafts)
        budget = max(1, min(int(self.prefill_chunk), self.packed_width))
        demand = sum(len(r.prompt) - r.prefilled
                     for r in self.prefilling.values())
        pre_budget = min(max(1, budget - n_dec), demand) if demand else 0
        width = min(_bucket(max(1, pre_budget + spec_tokens)),
                    self.packed_width)
        width = max(width, pre_budget + spec_tokens)
        tokens = np.zeros((1, width), np.int32)
        slot_id = np.full((width,), -1, np.int32)
        posw = np.zeros((width,), np.int32)
        start = np.zeros((self.max_batch,), np.int32)
        seg_len = np.zeros((self.max_batch,), np.int32)
        is_dec = np.zeros((width,), bool)
        spec_rows = np.zeros((self.max_batch,), bool)
        sample = np.zeros((self.max_batch,), bool)
        gidx = np.full((self.max_batch,), self.cache_len, np.int32)
        spec_idx = np.zeros((self.max_batch, L), np.int32)
        draft_len = np.zeros((self.max_batch,), np.int32)
        done = np.zeros((self.max_batch,), bool)
        cursor = 0
        packed: list[tuple[int, Request, int]] = []
        for slot, req in sorted(self.prefilling.items(),
                                key=lambda sr: sr[1].admit_seq):
            if cursor >= pre_budget:
                break   # later arrivals re-pack from `prefilled` next tick
            n = min(len(req.prompt) - req.prefilled, pre_budget - cursor)
            tokens[0, cursor:cursor + n] = \
                req.prompt[req.prefilled:req.prefilled + n]
            slot_id[cursor:cursor + n] = slot
            posw[cursor:cursor + n] = np.arange(req.prefilled,
                                                req.prefilled + n)
            start[slot] = req.prefilled
            seg_len[slot] = n
            if req.prefilled + n >= len(req.prompt):
                done[slot] = sample[slot] = True
                gidx[slot] = 0               # first token -> gen ring head
                # draft_len = 0, so accept = 0 and the sampled token is the
                # argmax at the segment's last lane — the first token
                spec_idx[slot, :] = cursor + n - 1
            packed.append((slot, req, n))
            cursor += n
        pre_cursor = cursor
        for slot, req, d in drafts:
            seg = 1 + len(d)
            spos = int(self.slot_pos[slot])
            # lane 0 carries a placeholder the jitted step fills from the
            # device token ring; drafts ride host-side
            if len(d):
                tokens[0, cursor + 1:cursor + seg] = d
            slot_id[cursor:cursor + seg] = slot
            posw[cursor:cursor + seg] = np.arange(spos, spos + seg)
            is_dec[cursor] = True
            start[slot] = spos
            seg_len[slot] = seg
            spec_rows[slot] = sample[slot] = True
            gidx[slot] = min(req.gen_count, self.cache_len)  # ==len => drop
            spec_idx[slot, :] = cursor + np.minimum(np.arange(L), seg - 1)
            draft_len[slot] = len(d)
            cursor += seg
        t_disp = self.clock()
        if self._tel is not None:
            self._tel.tracer.phase("dispatch")
        (self.caches, self._slot_tok, self._gen_buf, accept_d,
         toks_d) = self._step_spec(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(slot_id), jnp.asarray(posw), jnp.asarray(start),
            jnp.asarray(seg_len), jnp.asarray(is_dec),
            jnp.asarray(spec_rows), jnp.asarray(sample), jnp.asarray(gidx),
            jnp.asarray(spec_idx), jnp.asarray(draft_len), self._slot_tok,
            self._gen_buf, self._bt() if self.paged else None)
        self.model_dispatches += 1
        self._tick_dispatches += 1
        self._prefill_shapes.add(width)
        if packed:
            self.prefill_calls += 1
            self._record_prefill_pad(width - spec_tokens, pre_cursor,
                                     len(packed))
        self._tick_packed_segments += n_dec
        # acceptance decides how far every slot advanced: the one host sync
        # of the tick (accept + per-offset argmaxes feed the drafter)
        accept = np.asarray(accept_d)
        tks = np.asarray(toks_d)
        if self._tel is not None:
            self._tel.tracer.phase("sample")
        if n_dec:
            dt = self.clock() - t_disp
            self.decode_latency.record(dt)
            if self._tel is not None and dt > 0.0:
                self._tel_h_decode.record(dt)
        now = self.clock()
        for slot, req, n in packed:
            req.prefilled += n
            req.prefill_chunks += 1
            if done[slot]:
                req.gen_count = 1            # first token is on device
                self._stamp_first_token(req, now)
                self.slot_pos[slot] = len(req.prompt)
                self.running[slot] = self.prefilling.pop(slot)
                self._cache_insert(req)
                self._drafter.begin(slot, req)
                self._drafter.extend(slot, tks[slot, :1])
        n_emitted = 0
        for slot, req, d in drafts:
            a = int(accept[slot])
            self._drafter.extend(slot, tks[slot, :a + 1])
            self.slot_pos[slot] += a + 1
            req.gen_count += a + 1
            n_emitted += a + 1
            self._tick_spec_proposed += len(d)
            self._tick_spec_accepted += a
            if self._tel is not None:
                self._tel_h_spec.record(float(a))
        if self._tick_spec_proposed:
            self.spec_proposed += self._tick_spec_proposed
            self.spec_accepted += self._tick_spec_accepted
            self._accept_window.append((self._tick_spec_accepted,
                                        self._tick_spec_proposed))
            if self._tel is not None:
                self._tel_c_spec_prop.inc(self._tick_spec_proposed)
                self._tel_c_spec_acc.inc(self._tick_spec_accepted)
        self._tick_spec_lanes = spec_tokens - n_dec
        self._tick_decode = n_emitted
        self._tick_decode_slots = n_dec
        n_tokens = n_emitted + int(done.sum())
        if n_tokens:
            self.throughput.record(n_tokens)
        return n_tokens

    # ----------------------------------------------- bucketed chunked prefill
    def _prefill_tick_bucketed(self) -> None:
        """Advance every prefilling slot by one chunk in a single padded
        call.  The chunk width is the power-of-two bucket covering the
        largest chunk this tick, so mixed prompt lengths reuse compiles."""
        cap = max(1, int(self.prefill_chunk))
        width = _bucket(max(min(len(r.prompt) - r.prefilled, cap)
                            for r in self.prefilling.values()))
        tokens = np.zeros((self.max_batch, width), np.int32)
        start = np.zeros((self.max_batch,), np.int32)
        lengths = np.zeros((self.max_batch,), np.int32)
        done = np.zeros((self.max_batch,), bool)
        for slot, req in self.prefilling.items():
            n = min(len(req.prompt) - req.prefilled, cap, width)
            tokens[slot, :n] = req.prompt[req.prefilled:req.prefilled + n]
            start[slot] = req.prefilled
            lengths[slot] = n
            done[slot] = req.prefilled + n >= len(req.prompt)
        self.caches, self._slot_tok, self._gen_buf = self._prefill_chunk(
            self.params, self.caches, jnp.asarray(tokens),
            jnp.asarray(start), jnp.asarray(lengths), jnp.asarray(done),
            self._slot_tok, self._gen_buf,
            self._bt() if self.paged else None)
        self.prefill_calls += 1
        self.model_dispatches += 1
        self._tick_dispatches += 1
        self._prefill_shapes.add(width)
        self._record_prefill_pad(width * len(self.prefilling),
                                 int(lengths.sum()),
                                 int((lengths > 0).sum()))
        if done.any():
            # a first token is a completion boundary: wait for the device
            # (no host transfer) so TTFT reflects compute, not dispatch
            self._slot_tok.block_until_ready()
        now = self.clock()
        for slot in list(self.prefilling):
            req = self.prefilling[slot]
            req.prefilled += int(lengths[slot])
            req.prefill_chunks += 1
            if done[slot]:
                req.gen_count = 1            # first token is on device
                self._stamp_first_token(req, now)
                self.slot_pos[slot] = len(req.prompt)
                self.running[slot] = self.prefilling.pop(slot)
                self._cache_insert(req)

    def _cache_insert(self, req: Request) -> None:
        """Prefill-complete hook: adopt the finished prompt's full-block
        prefix into the radix tree (one refcount per block; decode and any
        partial tail land strictly beyond the inserted blocks, so tree-held
        KV is immutable), then hold the tree to its SmartConf-actuated
        share of the block budget."""
        cache = self._prefix_cache
        if cache is None or req.lease is None:
            return
        if cache.insert(req.prompt, req.lease.blocks, self.ticks_run):
            cache.enforce(int(self.kv_cache_share * self.pool.max_blocks))

    # ------------------------------------------------ legacy one-shot prefill
    def _do_prefill_legacy(self, req: Request) -> None:
        """Exact whole-prompt prefill for the modality-frontend families the
        padded path can't serve (vision/encoder-decoder prefixes), and for
        explicit ``prefill_mode='legacy'`` baseline comparisons."""
        assert not self.paged, "legacy prefill has no paged-cache merge path"
        prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
        batch = {"tokens": prompt}
        if self.cfg.frontend == "vision":
            batch["patches"] = jnp.zeros(
                (1, self.cfg.num_patches, self.cfg.frontend_dim), jnp.float32)
        if self.cfg.encoder_decoder:
            batch["frames"] = jnp.zeros(
                (1, self.cfg.enc_seq, self.cfg.d_model), jnp.float32)
        logits, one_cache = self._prefill(self.params, batch)
        self.caches = self._merge(self.caches, one_cache,
                                  jnp.asarray(req.slot, jnp.int32))
        self.prefill_calls += 1
        self.model_dispatches += 1
        self._tick_dispatches += 1
        self._prefill_shapes.add(len(req.prompt))
        self._record_prefill_pad(len(req.prompt), len(req.prompt), 1)
        first = int(jnp.argmax(logits[0]))
        self._slot_tok = self._slot_tok.at[req.slot].set(first)
        self._gen_buf = self._gen_buf.at[req.slot, 0].set(first)
        req.gen_count = 1
        req.prefilled = len(req.prompt)
        req.prefill_chunks = 1
        self._stamp_first_token(req, self.clock())
        npatch = self.cfg.num_patches if self.cfg.frontend == "vision" else 0
        self.slot_pos[req.slot] = len(req.prompt) + npatch

    # --------------------------------------------------------------- decode
    def _decode_tick(self) -> int:
        if not self.running:
            return 0
        active = np.zeros((self.max_batch,), bool)
        gidx = np.full((self.max_batch,), self.cache_len, np.int32)
        for slot, req in self.running.items():
            active[slot] = True
            gidx[slot] = min(req.gen_count, self.cache_len)  # ==len => drop
        pos = jnp.asarray(np.maximum(self.slot_pos, 0).astype(np.int32))
        # the decode-only latency sensor wraps just the dispatch + device
        # wait (no host transfer): the sc_chunk controller acting on its
        # p99 sees real decode compute, not admission/scheduling host work
        # (that whole-tick span is tick_latency's job)
        t_disp = self.clock()
        with self.decode_latency.measure():
            self._slot_tok, self.caches, self._gen_buf = self._decode(
                self.params, self.caches, self._slot_tok, pos,
                jnp.asarray(active), self._gen_buf, jnp.asarray(gidx),
                self._bt() if self.paged else None)
            self._slot_tok.block_until_ready()
        if self._tel is not None:
            dt = self.clock() - t_disp
            if dt > 0.0:
                self._tel_h_decode.record(dt)
        self.model_dispatches += 1
        self._tick_dispatches += 1
        self._decode_dispatched = True
        n = 0
        for slot, req in self.running.items():
            self.slot_pos[slot] += 1
            req.gen_count += 1
            n += 1
        self._tick_decode = n
        self._tick_decode_slots = n
        self.throughput.record(n)
        return n

    def _finish(self) -> None:
        done = [(s, r) for s, r in self.running.items()
                if r.gen_count >= r.max_new_tokens]
        if not done:
            return
        # completion boundary: the only device->host token sync in the loop
        gen = np.asarray(self._gen_buf)
        for slot, req in done:
            req.done_t = self.clock()
            # the prefill tick also decodes, so gen_count can overshoot
            # max_new_tokens by one — cap the readback at the request
            req.generated = [int(t) for t in
                             gen[slot, :min(req.gen_count,
                                            req.max_new_tokens)]]
            req.slo_ok = self._meets_slo(req)
            if req.slo_ok:
                self.slo_good_requests += 1
                self.slo_good_tokens += len(req.generated)
            else:
                self.slo_miss_requests += 1
                self.slo_miss_tokens += len(req.generated)
            if self._tel is not None:
                self._tel.tracer.async_end(
                    "request", req.req_id,
                    args={"slo_ok": bool(req.slo_ok),
                          "tokens": len(req.generated)})
            self.finished.append(req)
            del self.running[slot]
            self._free_slots.append(slot)
            if self._drafter is not None:
                self._drafter.drop(slot)
            if req.lease is not None:
                if self.spec_enabled:
                    # accepted-token KV only: the final sampled token was
                    # never consumed and any rejected draft tail is junk —
                    # cut both out of the lease BEFORE the radix tree may
                    # adopt its blocks, then extend the cacheable prefix
                    # with the request's own output (prompt + accepted
                    # continuation), so a repeat of this stream warm-hits
                    # past the prompt
                    valid = len(req.prompt) + max(0, len(req.generated) - 1)
                    req.lease.truncate(valid)
                    if self._prefix_cache is not None and req.generated:
                        ext = np.concatenate([
                            np.asarray(req.prompt, np.int32),
                            np.asarray(req.generated[:-1], np.int32)])
                        if self._prefix_cache.insert(ext, req.lease.blocks,
                                                     self.ticks_run):
                            self._prefix_cache.enforce(
                                int(self.kv_cache_share
                                    * self.pool.max_blocks))
                req.lease.release()
                req.lease = None
            self.slot_pos[slot] = -1
            if self.paged:
                self._bt_np[slot] = -1
                self._bt_dirty = True

    def _trim_windows(self) -> None:
        """Block-level sliding-window eviction (all-window archs only):
        blocks wholly below every live position's attention window return
        to the pool, and their table entries go to -1 — the paged gather
        masks them, so the kernel never reads a freed block.  The keep
        point is conservative by up to one block (``cur - window`` even
        mid-block) so a token still inside any window is never dropped.
        Mutually exclusive with the prefix cache: a trimmed lease's blocks
        are position-holed and cannot be adopted as a shared prefix."""
        w = int(self.cfg.window)
        T = self.pool.block_tokens
        changed = False
        for reqs in (self.prefilling, self.running):
            for slot, req in reqs.items():
                if req.lease is None:
                    continue
                cur = (int(self.slot_pos[slot])
                       if self.slot_pos[slot] >= 0 else req.prefilled)
                first_keep = max(0, cur - w) // T
                if req.lease.trim_front(first_keep):
                    self._bt_np[slot] = req.lease.table_row()
                    changed = True
        if changed:
            self._bt_dirty = True

    def _meets_slo(self, req: Request) -> bool:
        """Goodput-under-SLO membership: the request's own TTFT met the SLO
        bound and it completed inside its deadline.  Tokens served outside
        either are wasted capacity, not goodput."""
        if (req.deadline_s is not None and req.done_t is not None
                and req.done_t - req.submitted_t > req.deadline_s):
            return False
        if (self.slo is not None and req.first_token_t is not None
                and req.first_token_t - req.submitted_t > self.slo.ttft_s):
            return False
        return True

    @property
    def goodput_tokens(self) -> int:
        """Cumulative generated tokens of finished requests that met their
        SLO — the serving metric the paper's control loop optimizes for
        (raw tokens/s counts wasted work; goodput cannot)."""
        return self.slo_good_tokens

    def close(self) -> None:
        if self._closed:          # idempotent: drain paths may close twice
            return
        self._closed = True
        for sc in (self.sc_queue, self.sc_kv, self.sc_chunk, self.sc_admit,
                   self.sc_cache, self.sc_spec):
            if sc is not None:
                sc.close()
