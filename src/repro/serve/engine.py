"""Continuous-batching serve engine with SmartConf-governed admission.

This is the framework's HB3813/HB6728 (paper §6.2, Fig. 6/8): two PerfConfs
share the hard ``hbm_bytes`` constraint —

  * ``serve.max_queue_tokens``  (indirect; deputy = tokens waiting in the
    admission queue) — a larger queue absorbs request bursts but queued
    prompts hold host/device memory;
  * ``serve.kv_block_budget``   (indirect; deputy = live KV blocks) — more
    resident sequences increase decode batch efficiency but eat HBM.

Both are ``super_hard`` on the same metric, so their controllers split the
error via the §5.4 interaction factor (N = 2).  A third, soft PerfConf
``serve.prefill_chunk_tokens`` bounds decode-latency interference from long
prefills (HB2149-style trade-off).

Engine loop (one `tick`):
  admission -> scheduling (chunked prefill, KV allocation) -> fused decode
  step over all running slots -> completion/free -> controller updates.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import (ControllerModel, GoalSpec, HBMAccountant,
                        LatencySensor, SmartConfIndirect, SmartConf,
                        ThroughputSensor)
from repro.core.smartconf import ConfRegistry
from repro.models import zoo
from .kv_cache import KVBlockPool, kv_bytes_per_token

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int
    prompt_bytes: int = 0
    submitted_t: float = 0.0
    first_token_t: float | None = None
    done_t: float | None = None
    generated: list = dataclasses.field(default_factory=list)
    slot: int | None = None
    prefilled: int = 0          # prompt tokens already prefilled (chunking)


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_batch: int = 4,
                 cache_len: int = 256, hbm_budget_bytes: int | None = None,
                 block_tokens: int = 16, enable_smartconf: bool = True,
                 latency_goal_s: float | None = None,
                 registry: ConfRegistry | None = None,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.clock = clock

        self.accountant = HBMAccountant(budget_bytes=hbm_budget_bytes)
        weight_bytes = sum(np.prod(x.shape) * x.dtype.itemsize
                           for x in jax.tree.leaves(params))
        self.accountant.set("weights", int(weight_bytes))

        self.pool = KVBlockPool(cfg, block_tokens=block_tokens,
                                max_blocks=2**30, accountant=self.accountant)
        self.registry = registry or ConfRegistry()

        # engine state
        self.waiting: collections.deque[Request] = collections.deque()
        self.queued: collections.deque[Request] = collections.deque()
        self.queued_tokens = 0
        self.running: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.rejected = 0
        self._next_slot = list(range(max_batch))

        # model caches (one fused batch across slots)
        self.caches = zoo.init_cache(cfg, max_batch, cache_len)
        self.slot_pos = np.full((max_batch,), -1, np.int64)
        self.slot_tokens = np.zeros((max_batch,), np.int32)
        self._decode = jax.jit(
            lambda p, c, t, q: zoo.decode_step(cfg, p, c, t, q))
        self._prefill = jax.jit(
            lambda p, b: zoo.prefill(cfg, p, b, cache_len=cache_len))

        # sensors
        self.decode_latency = LatencySensor()
        self.ttft = LatencySensor()
        self.throughput = ThroughputSensor(window_seconds=5.0)

        # SmartConf PerfConfs
        self.enable_smartconf = enable_smartconf
        self.max_queue_tokens = 4 * cache_len
        self.prefill_chunk = cache_len
        self.sc_queue = None
        self.sc_kv = None
        self.sc_chunk = None
        if enable_smartconf and hbm_budget_bytes:
            token_bytes = 8  # queue holds int32 prompt+label views per token
            goal = GoalSpec(float(hbm_budget_bytes), hard=True,
                            super_hard=True)
            self.sc_queue = SmartConfIndirect(
                "serve.max_queue_tokens", metric="hbm_bytes", goal=goal,
                initial=0.0, registry=self.registry,
                model=ControllerModel(alpha=float(token_bytes), lam=0.05,
                                      delta=1.15, conf_min=0.0,
                                      conf_max=1e9))
            self.sc_kv = SmartConfIndirect(
                "serve.kv_block_budget", metric="hbm_bytes", goal=goal,
                initial=1.0, registry=self.registry,
                model=ControllerModel(alpha=float(self.pool.block_bytes),
                                      lam=0.05, delta=1.15, conf_min=1.0,
                                      conf_max=1e9))
            if latency_goal_s is not None:
                # alpha: prefill seconds per token, measured lazily; start 1e-4
                self.sc_chunk = SmartConf(
                    "serve.prefill_chunk_tokens", metric="decode_p99_s",
                    goal=GoalSpec(latency_goal_s, hard=False),
                    initial=float(cache_len), registry=self.registry,
                    model=ControllerModel(alpha=1e-4, lam=0.1, delta=1.3,
                                          conf_min=float(block_tokens),
                                          conf_max=float(cache_len)))

    # ------------------------------------------------------------------ API
    def submit(self, req: Request) -> None:
        req.prompt_bytes = int(req.prompt.nbytes * 2)
        req.submitted_t = self.clock()
        self.waiting.append(req)

    def hbm_bytes(self) -> int:
        return self.accountant.total()

    # ------------------------------------------------------------- one tick
    def tick(self) -> dict:
        t0 = self.clock()
        self._update_controllers()
        self._admit()
        self._schedule()
        n_tokens = self._decode_tick()
        self._finish()
        self.decode_latency.record(self.clock() - t0)
        return {
            "queued": len(self.queued), "running": len(self.running),
            "finished": len(self.finished), "hbm": self.hbm_bytes(),
            "tokens": n_tokens,
        }

    def run(self, ticks: int) -> list[dict]:
        return [self.tick() for _ in range(ticks)]

    # ------------------------------------------------------------ internals
    def _update_controllers(self) -> None:
        if not self.enable_smartconf or self.sc_queue is None:
            return
        hbm = float(self.hbm_bytes())
        self.sc_queue.set_perf(hbm, self.queued_tokens)
        self.max_queue_tokens = max(0, int(self.sc_queue.get_conf()))
        self.sc_kv.set_perf(hbm, self.pool.used_blocks)
        self.pool.set_budget(max(1, int(self.sc_kv.get_conf())))
        if self.sc_chunk is not None:
            self.sc_chunk.set_perf(self.decode_latency.p99())
            self.prefill_chunk = max(1, int(self.sc_chunk.get_conf()))

    def _admit(self) -> None:
        moved = True
        while moved and self.waiting:
            req = self.waiting[0]
            if self.queued_tokens + len(req.prompt) > self.max_queue_tokens:
                break
            self.waiting.popleft()
            self.queued.append(req)
            self.queued_tokens += len(req.prompt)
            self.accountant.charge("queue", req.prompt_bytes)
            moved = True

    def _schedule(self) -> None:
        while self.queued and self._next_slot:
            req = self.queued[0]
            total = len(req.prompt) + req.max_new_tokens
            if not self.pool.ensure(req.req_id, min(total, self.cache_len)):
                break  # KV budget exhausted; stay queued
            self.queued.popleft()
            self.queued_tokens -= len(req.prompt)
            self.accountant.credit("queue", req.prompt_bytes)
            req.slot = self._next_slot.pop(0)
            self._do_prefill(req)
            self.running[req.slot] = req

    def _do_prefill(self, req: Request) -> None:
        """Prefill the whole prompt (chunk bookkeeping records interference)."""
        prompt = jnp.asarray(req.prompt[None, :], jnp.int32)
        batch = {"tokens": prompt}
        if self.cfg.frontend == "vision":
            batch["patches"] = jnp.zeros(
                (1, self.cfg.num_patches, self.cfg.frontend_dim), jnp.float32)
        if self.cfg.encoder_decoder:
            batch["frames"] = jnp.zeros(
                (1, self.cfg.enc_seq, self.cfg.d_model), jnp.float32)
        logits, one_cache = self._prefill(self.params, batch)
        self._merge_cache(one_cache, req.slot)
        first = int(jnp.argmax(logits[0]))
        req.generated.append(first)
        req.first_token_t = self.clock()
        self.ttft.record(req.first_token_t - req.submitted_t)
        npatch = self.cfg.num_patches if self.cfg.frontend == "vision" else 0
        self.slot_pos[req.slot] = len(req.prompt) + npatch
        self.slot_tokens[req.slot] = first
        req.prefilled = len(req.prompt)

    def _merge_cache(self, one_cache, slot: int) -> None:
        def merge(full, one):
            axis = None
            for i, (f, o) in enumerate(zip(full.shape, one.shape)):
                if o == 1 and f == self.max_batch:
                    axis = i
                    break
                if f != o:
                    return full  # shape mismatch (e.g. enc_out cache len)
            if axis is None:
                return full
            idx = [slice(None)] * full.ndim
            idx[axis] = slice(slot, slot + 1)
            return full.at[tuple(idx)].set(one.astype(full.dtype))

        self.caches = jax.tree.map(merge, self.caches, one_cache)

    def _decode_tick(self) -> int:
        if not self.running:
            return 0
        tok = jnp.asarray(self.slot_tokens)
        pos = jnp.asarray(np.maximum(self.slot_pos, 0).astype(np.int32))
        logits, self.caches = self._decode(self.params, self.caches, tok, pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        n = 0
        for slot, req in list(self.running.items()):
            self.slot_pos[slot] += 1
            self.slot_tokens[slot] = nxt[slot]
            req.generated.append(int(nxt[slot]))
            n += 1
        self.throughput.record(n)
        return n

    def _finish(self) -> None:
        for slot, req in list(self.running.items()):
            if len(req.generated) >= req.max_new_tokens:
                req.done_t = self.clock()
                self.finished.append(req)
                del self.running[slot]
                self._next_slot.append(slot)
                self.pool.free(req.req_id)
                self.slot_pos[slot] = -1
                self.slot_tokens[slot] = 0

    def close(self) -> None:
        for sc in (self.sc_queue, self.sc_kv, self.sc_chunk):
            if sc is not None:
                sc.close()
