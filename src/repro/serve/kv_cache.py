"""KV-cache pool with block-level HBM accounting.

The serve engine allocates cache *blocks* (fixed token granularity) per
sequence; the pool's byte ledger is the deputy-facing sensor behind the
``serve.kv_block_budget`` SmartConf (indirect, hard on ``hbm_bytes``).
Model-side cache tensors are preallocated at engine batch capacity; the pool
tracks logical occupancy (which is what OOMs a real deployment when paged).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.core.sensors import HBMAccountant

__all__ = ["DenseKVLease", "KVBlockPool", "kv_bytes_per_token",
           "QUEUE_TOKEN_BYTES"]

# Host+device bytes one *queued* prompt token holds (int32 token + int32
# label/scratch view).  Both the admission-queue deputy accounting in
# ``ServeEngine.submit`` and the ``serve.max_queue_tokens`` controller gain
# (alpha = bytes released per queued token shed) derive from this constant,
# so the deputy metric and the controller model can never drift apart.
QUEUE_TOKEN_BYTES = 8


def kv_bytes_per_token(cfg: ArchConfig) -> int:
    """HBM bytes one token of context occupies across all layers."""
    dt = 2 if cfg.dtype == "bfloat16" else 4
    hd = cfg.resolved_head_dim
    per_layer_attn = 2 * cfg.num_kv_heads * hd * dt
    total = 0
    pattern = cfg.block_pattern
    for i in range(cfg.num_layers):
        base = pattern[i % len(pattern)].split("+")[0]
        if base in ("rwkv6", "rglru"):
            continue  # O(1) state, not per-token
        total += per_layer_attn
    return total


@dataclasses.dataclass
class _Seq:
    seq_id: int
    blocks: int
    tokens: int = 0     # logical tokens covered (for fragmentation stats)


class DenseKVLease:
    """Dense-mode twin of :class:`~repro.serve.paging.KVLease`: the same
    ``extend`` / ``release`` handle surface over the logical ledger, so the
    engine's scheduling path is KV-mode-agnostic.  Dense caches are
    per-slot rings — nothing is shared, so there is no fork/COW here."""

    __slots__ = ("_pool", "_key", "released")

    def __init__(self, pool: "KVBlockPool", key: int) -> None:
        self._pool = pool
        self._key = key
        self.released = False

    def extend(self, tokens: int) -> bool:
        if self.released:
            raise ValueError("extend on released lease")
        return self._pool.ensure(self._key, tokens)

    def truncate(self, tokens: int) -> int:
        """Shrink to cover at most ``tokens`` logical tokens, crediting
        whole trailing blocks back to the ledger (the speculative-decode
        finish path).  Returns the number of blocks freed."""
        if self.released:
            raise ValueError("truncate on released lease")
        return self._pool.shrink(self._key, tokens)

    def release(self) -> None:
        if self.released:
            return
        self.released = True
        self._pool.free(self._key)


class KVBlockPool:
    def __init__(self, cfg: ArchConfig, *, block_tokens: int = 64,
                 max_blocks: int = 4096,
                 accountant: HBMAccountant | None = None) -> None:
        self.cfg = cfg
        self.block_tokens = block_tokens
        self.block_bytes = kv_bytes_per_token(cfg) * block_tokens
        self.max_blocks = max_blocks
        self.accountant = accountant
        self._seqs: dict[int, _Seq] = {}
        self.used_blocks = 0
        self.alloc_failures = 0
        self._next_lease = 0

    def lease(self, tokens: int, shared=None) -> DenseKVLease | None:
        """Handle-API twin of ``PagedKVAllocator.lease`` (``shared`` is
        accepted for signature parity and must be empty — dense caches
        cannot share).  Returns ``None`` if the budget blocks it."""
        assert not shared, "dense KV has no shared blocks"
        key = -1 - self._next_lease   # negative: never collides with the
        self._next_lease += 1         # seq_id-keyed legacy surface
        if not self.ensure(key, tokens):
            return None
        return DenseKVLease(self, key)

    # budget is the SmartConf-actuated threshold (deputy = used_blocks)
    def set_budget(self, max_blocks: int) -> None:
        """Threshold update; running sequences above the new budget are
        tolerated until they free (paper §4.2 temporary inconsistency)."""
        self.max_blocks = max(1, int(max_blocks))

    def ensure(self, seq_id: int, tokens: int) -> bool:
        """Grow seq to cover ``tokens``; False if the budget blocks it."""
        need = (tokens + self.block_tokens - 1) // self.block_tokens
        seq = self._seqs.get(seq_id)
        have = seq.blocks if seq else 0
        delta = need - have
        if delta <= 0:
            if seq is not None:
                seq.tokens = max(seq.tokens, tokens)
            return True
        if self.used_blocks + delta > self.max_blocks:
            self.alloc_failures += 1
            return False
        if seq is None:
            seq = self._seqs[seq_id] = _Seq(seq_id, 0)
        seq.blocks += delta
        seq.tokens = max(seq.tokens, tokens)
        self.used_blocks += delta
        if self.accountant is not None:
            self.accountant.charge("kv_cache", delta * self.block_bytes)
        return True

    def shrink(self, seq_id: int, tokens: int) -> int:
        """Shrink seq to cover at most ``tokens``; inverse of ``ensure``.
        Returns blocks freed (0 when the extent already fits)."""
        seq = self._seqs.get(seq_id)
        if seq is None:
            return 0
        tokens = max(0, int(tokens))
        keep = (tokens + self.block_tokens - 1) // self.block_tokens
        freed = seq.blocks - keep
        seq.tokens = min(seq.tokens, tokens)
        if freed <= 0:
            return 0
        seq.blocks = keep
        self.used_blocks -= freed
        if self.accountant is not None:
            self.accountant.credit("kv_cache", freed * self.block_bytes)
        return freed

    def free(self, seq_id: int) -> None:
        seq = self._seqs.pop(seq_id, None)
        if seq is None:
            return
        self.used_blocks -= seq.blocks
        if self.accountant is not None:
            self.accountant.credit("kv_cache", seq.blocks * self.block_bytes)

    @property
    def used_bytes(self) -> int:
        return self.used_blocks * self.block_bytes

    @property
    def live_seqs(self) -> int:
        return len(self._seqs)

    @property
    def over_budget(self) -> bool:
        """Occupancy above the SmartConf budget — §4.2 temporary
        inconsistency while live sequences drain."""
        return self.used_blocks > self.max_blocks

    @property
    def frag_tokens(self) -> int:
        """Allocated-but-unused tail tokens across live sequences (the
        block-granularity internal fragmentation the sensors export)."""
        return sum(s.blocks * self.block_tokens - s.tokens
                   for s in self._seqs.values())
