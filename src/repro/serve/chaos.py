"""Fault injection for the serving engine (chaos harness).

Four fault families, all deterministic from a seed and a tick schedule:

* **slow ticks** — random ticks cost extra virtual seconds (a straggler
  device, a GC pause).  Latency sensors see the spike; controllers must
  react without oscillating.
* **budget cuts** — ``serve.kv_block_budget`` is slashed mid-run (a
  co-tenant claimed the HBM).  On SmartConf engines the cut shrinks the
  controller's actuation ceiling (:meth:`SmartConf.clamp_conf_max`), so
  the knob cannot bounce back above physical capacity; on static engines
  it is applied directly via :meth:`ServeEngine.set_kv_budget`.
* **sensor faults** — controller-facing sensor reads return NaN, a
  physically impossible spike, or zero for a window of ticks
  (installed as ``engine.sensor_tap``).  The SmartConf guardrails must
  absorb these: an unguarded controller crashes on ``int(nan)``.
* **worker preemption** — :class:`PreemptionHandler` is triggered, the
  engine must drain (requeue in-flight work, refuse new submissions with
  a typed reason), and resume cleanly when the flag clears.

A :class:`ChaosMonkey` is both the driver tick-hook (``__call__`` returns
extra virtual seconds) and the sensor tap; ``install(engine)`` wires both.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .engine import ServeEngine

__all__ = ["ChaosSpec", "ChaosMonkey"]


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Tick-indexed fault schedule.  ``None`` disables a fault family."""

    seed: int = 0
    # slow ticks: each tick independently pays +slow_tick_s with this prob
    slow_tick_prob: float = 0.0
    slow_tick_s: float = 0.05
    # mid-run KV budget cut (fraction of the budget at cut time), with
    # optional restore later
    budget_cut_tick: int | None = None
    budget_cut_frac: float = 0.5
    budget_restore_tick: int | None = None
    # sensor fault window [tick, tick + ticks): taps named sensors
    sensor_fault_tick: int | None = None
    sensor_fault_ticks: int = 8
    sensor_fault_mode: str = "nan"          # "nan" | "spike" | "dropout"
    sensor_names: tuple[str, ...] = ("decode_p99_s", "ttft_p99_s")
    # worker preemption: trigger at tick, clear `resume_ticks` later
    preempt_tick: int | None = None
    preempt_resume_ticks: int = 3


class ChaosMonkey:
    """Executes a :class:`ChaosSpec` against one engine.

    Use as the :class:`~repro.serve.traffic.OpenLoopDriver` chaos hook::

        monkey = ChaosMonkey(spec).install(engine)
        driver = OpenLoopDriver(engine, arrivals, clock=vc, chaos=monkey)

    ``events`` records every injected fault as ``(tick, name)`` so tests
    and the bench can assert the schedule actually fired.
    """

    def __init__(self, spec: ChaosSpec) -> None:
        self.spec = spec
        self.rng = np.random.default_rng(spec.seed)
        self.engine: ServeEngine | None = None
        self.events: list[tuple[int, str]] = []
        self._tick = -1
        self._orig_budget: int | None = None
        self._orig_cap: float | None = None

    # -- wiring ------------------------------------------------------------

    def install(self, engine: ServeEngine) -> "ChaosMonkey":
        self.engine = engine
        engine.sensor_tap = self._tap
        return self

    def _note(self, name: str) -> None:
        """Record an injected fault — in ``events`` for the benches, and
        on the engine's telemetry trace (instant marker + flight-recorder
        dump) so fault <-> controller-response causality is visible in
        one timeline."""
        self.events.append((self._tick, name))
        if self.engine is not None:
            self.engine.note_chaos(name)

    # -- sensor corruption -------------------------------------------------

    def _fault_window_active(self) -> bool:
        s = self.spec
        return (s.sensor_fault_tick is not None
                and s.sensor_fault_tick <= self._tick
                < s.sensor_fault_tick + s.sensor_fault_ticks)

    def _tap(self, name: str, value: float) -> float:
        if not self._fault_window_active() or name not in self.spec.sensor_names:
            return value
        self._note(f"sensor_{self.spec.sensor_fault_mode}:{name}")
        if self.spec.sensor_fault_mode == "nan":
            return math.nan
        if self.spec.sensor_fault_mode == "spike":
            return 1e12                      # physically impossible reading
        if self.spec.sensor_fault_mode == "dropout":
            return 0.0
        raise ValueError(
            f"unknown sensor_fault_mode: {self.spec.sensor_fault_mode!r}")

    # -- budget cuts -------------------------------------------------------

    def _cut_budget(self, eng: ServeEngine) -> None:
        blocks = max(1, int(eng.pool.max_blocks * self.spec.budget_cut_frac))
        self._orig_budget = eng.pool.max_blocks
        if eng.sc_kv is not None:
            self._orig_cap = float(eng.sc_kv.controller.model.conf_max)
            eng.sc_kv.clamp_conf_max(float(blocks))
        eng.set_kv_budget(blocks)
        self._note(f"budget_cut:{blocks}")

    def _restore_budget(self, eng: ServeEngine) -> None:
        if self._orig_budget is None:
            return
        if eng.sc_kv is not None and self._orig_cap is not None:
            eng.sc_kv.clamp_conf_max(self._orig_cap)
        else:
            eng.set_kv_budget(self._orig_budget)
        self._note("budget_restore")

    # -- driver hook -------------------------------------------------------

    def __call__(self, driver, tick: int) -> float:
        eng = self.engine if self.engine is not None else driver.engine
        if self.engine is None:
            self.install(eng)
        self._tick = tick
        s = self.spec

        if s.budget_cut_tick is not None and tick == s.budget_cut_tick:
            self._cut_budget(eng)
        if s.budget_restore_tick is not None and tick == s.budget_restore_tick:
            self._restore_budget(eng)

        if s.preempt_tick is not None:
            if tick == s.preempt_tick:
                eng.preemption.trigger()
                self._note("preempt")
            elif tick == s.preempt_tick + s.preempt_resume_ticks:
                eng.preemption.reset()
                self._note("resume")

        extra = 0.0
        if s.slow_tick_prob > 0.0 and self.rng.uniform() < s.slow_tick_prob:
            extra = s.slow_tick_s
            self._note("slow_tick")
        return extra
