"""Typed engine configuration: ServeOptions + SLOSpec.

``ServeEngine`` accumulated a sprawl of keyword knobs (KV mode, prefill
mode, budgets, SLO/telemetry/chaos hooks) plus two environment toggles
(``REPRO_PREFILL_MODE``, ``REPRO_TELEMETRY``) that were read at scattered
points.  :class:`ServeOptions` is the one typed bag for all of it, and
:meth:`ServeOptions.resolve` is the SINGLE env-resolution point — the
engine, the launcher (``launch/serve.py``) and the bench runner
(``benchmarks/run.py``) all thread the same object.  The engine still
accepts the legacy keyword form (``ServeEngine(cfg, params, max_batch=8,
...)``) by building a ``ServeOptions`` internally, so existing call sites
keep working.
"""

from __future__ import annotations

import dataclasses
import os
from typing import TYPE_CHECKING, Callable

if TYPE_CHECKING:  # runtime-free: avoid importing telemetry at module load
    from repro.core.telemetry import Telemetry

__all__ = ["SLOSpec", "ServeOptions"]


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Serving-level objectives the engine is *measured* against.

    ``ttft_s`` is the per-request TTFT bound: a finished request only counts
    toward goodput if its own TTFT met it, and the fleet goal the
    ``serve.admit_tier_max`` brownout controller drives is TTFT-p99 <=
    ``ttft_s``.  ``decode_s`` (optional) is the decode-latency p99 goal the
    ``serve.prefill_chunk_tokens`` controller targets.  ``window`` sizes the
    SLO latency sensors: small enough that the controllers see the current
    regime, not a stale mix across a load shift."""

    ttft_s: float
    decode_s: float | None = None
    window: int = 64


@dataclasses.dataclass(frozen=True)
class ServeOptions:
    """Everything configurable about a ``ServeEngine``, in one typed place.

    Fields mirror the legacy keyword surface one-for-one; the additions are
    the prefix-cache knobs (``prefix_cache`` / ``kv_cache_share`` /
    ``prefix_hit_rate_goal``), the sliding-window eviction gate
    (``window_evict``), and the hook fields (``sensor_tap``,
    ``telemetry``).  ``resolve()`` applies the environment exactly once;
    the two trailing ``*_env*`` fields are its outputs, not caller
    inputs."""

    max_batch: int = 4
    cache_len: int = 256
    hbm_budget_bytes: int | None = None
    block_tokens: int = 16
    enable_smartconf: bool = True
    latency_goal_s: float | None = None
    prefill_mode: str = "auto"
    kv_mode: str = "auto"
    slo: SLOSpec | None = None
    num_tiers: int = 3
    admit_tier_max: int | None = None
    # --- prefix cache (radix tree over refcounted paged blocks) ---
    prefix_cache: bool = False          # opt-in; requires paged KV
    kv_cache_share: float = 0.5         # cache's share of the block budget
    prefix_hit_rate_goal: float = 0.3   # sc_cache goal (direction="lower")
    # --- block-level sliding-window eviction (all-window archs) ---
    window_evict: bool = True
    # --- self-speculative decode (packed prefill mode only) ---
    spec_depth: int = 0                 # initial draft depth k; 0 = off
    spec_depth_max: int = 8             # conf_max for sc_spec (<= 15)
    spec_adaptive: bool = True          # sc_spec actuates serve.spec_depth
    accept_rate_goal: float = 0.5       # sc_spec setpoint (direction="lower")
    # --- mesh serving (tensor-parallel packed ticks + replica router) ---
    mesh: str | None = None             # "DxM" host mesh, e.g. "2x4"; None = off
    replicas: int = 1                   # data-parallel engines behind the router
    router_adaptive: bool = True        # SmartConf-actuate route.replica_weights
    router_weight_max: float = 8.0      # conf_max for each weight controller
    # --- hooks ---
    sensor_tap: Callable[[str, float], float] | None = None
    telemetry: "Telemetry | None" = None
    # --- resolve() outputs (env state, recorded for the engine) ---
    prefill_env_forced: bool = False
    telemetry_env: bool = False
    spec_env_forced: bool = False
    mesh_env_forced: bool = False

    def resolve(self, env=os.environ) -> "ServeOptions":
        """The single environment-resolution point.

        ``REPRO_PREFILL_MODE`` re-routes what ``prefill_mode='auto'``
        resolves to (the CI matrix leg) without touching explicit mode
        requests; ``prefill_env_forced`` records that the choice came from
        the environment, so the engine falls back loudly instead of
        raising on archs that cannot serve it.  ``one_shot`` is accepted
        as an alias for ``legacy`` in both the field and the env var.
        ``REPRO_TELEMETRY`` (any value but empty/``0``) force-enables
        telemetry when no hub was passed.  ``REPRO_SPEC_DEPTH`` (a positive
        int) force-enables speculative decode at that depth when the caller
        left ``spec_depth=0`` (the CI spec leg); ``spec_env_forced`` records
        the provenance so the engine silently degrades to k=0 on engines
        that cannot speculate instead of raising.  ``REPRO_SERVE_MESH``
        (``"DxM"``, e.g. ``2x4``) requests a tensor-parallel serving mesh
        when the caller left ``mesh=None`` (the CI mesh-serve leg);
        ``mesh_env_forced`` records the provenance so engines that cannot
        shard (legacy prefill, too few devices, indivisible heads) degrade
        to single-device instead of raising."""
        # idempotent: the engine resolves whatever it is handed, so a
        # caller-resolved options object must keep its *_env* outputs
        pm = self.prefill_mode
        if pm == "one_shot":
            pm = "legacy"
        forced = self.prefill_env_forced
        if pm == "auto":
            e = env.get("REPRO_PREFILL_MODE", "").strip() or "auto"
            e = "legacy" if e == "one_shot" else e
            if e != "auto":
                pm, forced = e, True
        tel_env = env.get("REPRO_TELEMETRY", "").strip() not in ("", "0")
        sd, sd_forced = self.spec_depth, self.spec_env_forced
        if sd == 0:
            e = env.get("REPRO_SPEC_DEPTH", "").strip()
            if e and e != "0":
                sd, sd_forced = int(e), True
        mesh, mesh_forced = self.mesh, self.mesh_env_forced
        if mesh is None:
            e = env.get("REPRO_SERVE_MESH", "").strip()
            if e and e != "0":
                mesh, mesh_forced = e, True
        return dataclasses.replace(self, prefill_mode=pm,
                                   prefill_env_forced=forced,
                                   telemetry_env=tel_env,
                                   spec_depth=sd, spec_env_forced=sd_forced,
                                   mesh=mesh, mesh_env_forced=mesh_forced)
