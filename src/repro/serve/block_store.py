"""Sharded KV block store: mesh placement for the serve engine's caches.

Layer 2 of mesh serving.  The engine's KV state — paged physical block
stores ``[num_blocks, Kv, T, D]`` or dense per-slot rings ``[B, n, Kv, D]``
— shards over the serving mesh's ``model`` axis on the **Kv head dim**, the
same placement ``distributed.collectives.tp_paged_segment_attention`` pins
on its store operands.  Everything that is not a K/V plane (dense ``pos``
planes, recurrent scan state, token rings) replicates.

Design rules this module owns:

* **Host-side allocator stays device-count-agnostic.**  Block *indices*
  (``PagedKVAllocator`` free lists, leases, block tables) are global
  logical names; only the backing arrays shard.  Nothing in
  ``serve/paging.py`` knows the mesh exists — per-device HBM is the global
  ledger divided by the model-axis size (:meth:`CacheShardingPlan.
  shard_bytes`), and ``serve.kv_block_budget`` actuation, COW copies, and
  store resizes are plain global-index array ops that stay shard-local
  because they never touch the Kv dim.
* **Placement survives donation.**  The engine's step functions donate the
  cache operand; without an explicit constraint XLA is free to hand the
  output back with a different layout, silently turning every later tick
  into a resharding copy.  :meth:`CacheShardingPlan.constrain` is applied
  to the cache *outputs inside* each jitted step so the fixed placement is
  part of the compiled program; :meth:`CacheShardingPlan.place` re-pins
  after the two eager resize paths (budget shrink via ``jnp.take``, demand
  grow via pad).
* **Indivisible head counts replicate, never raise.**  A leaf whose Kv dim
  the model axis does not divide (MQA ``kv_heads=1`` under ``model=4``)
  gets a replicated spec; the attention wrappers make the matching per-op
  fallback, so the engine still runs token-identically — just unsharded.
"""

from __future__ import annotations

import warnings

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["parse_mesh_spec", "build_serve_mesh", "CacheShardingPlan"]


def parse_mesh_spec(spec: str) -> tuple[int, int]:
    """``"DxM"`` -> ``(data, model)``, e.g. ``"2x4"`` -> ``(2, 4)``."""
    parts = str(spec).lower().replace(" ", "").split("x")
    try:
        if len(parts) != 2:
            raise ValueError
        data, model = int(parts[0]), int(parts[1])
    except ValueError:
        raise ValueError(
            f"mesh spec {spec!r} is not 'DxM' (e.g. '2x4' = data=2, model=4)"
        ) from None
    if data < 1 or model < 1:
        raise ValueError(f"mesh spec {spec!r}: both axes must be >= 1")
    return data, model


def build_serve_mesh(spec: str, *, heads: int, kv_heads: int,
                     prefill_impl: str, env_forced: bool):
    """Resolve a ``"DxM"`` serving-mesh request into a live Mesh or None.

    Serving TP rides the packed stream (the one compiled dispatch the
    shard_map wraps) and needs the model axis to divide both head counts
    (contiguous GQA-preserving head chunks).  An infeasible request raises
    with the reason when the caller asked explicitly; when the environment
    forced it (``REPRO_SERVE_MESH``, the CI leg sweeping every arch) the
    engine degrades to single-device with a warning instead — provenance
    recorded by ``ServeOptions.mesh_env_forced``."""
    from repro.launch.mesh import make_host_mesh

    data, model = parse_mesh_spec(spec)
    problems = []
    if prefill_impl != "packed":
        problems.append(f"prefill_impl={prefill_impl!r} (TP ticks ride the "
                        "packed stream)")
    if model > 1 and (heads % model or kv_heads % model):
        problems.append(f"model={model} does not divide heads={heads} / "
                        f"kv_heads={kv_heads}")
    n = len(jax.devices())
    if data * model > n:
        problems.append(f"mesh {data}x{model} needs {data * model} devices, "
                        f"{n} visible (XLA_FLAGS=--xla_force_host_platform_"
                        f"device_count={data * model})")
    if problems:
        if env_forced:
            warnings.warn(
                f"REPRO_SERVE_MESH={spec}: serving single-device instead — "
                + "; ".join(problems), RuntimeWarning, stacklevel=2)
            return None
        raise ValueError(f"serve mesh {spec!r} is infeasible: "
                         + "; ".join(problems))
    return make_host_mesh(data=data, model=model)


def _leaf_key(path) -> str | None:
    last = path[-1]
    return getattr(last, "key", None)


class CacheShardingPlan:
    """Per-leaf placement of an engine cache tree over the serving mesh.

    K/V planes shard on the Kv head dim over ``model`` (paged stores
    ``[N, Kv, T, D]`` at axis 1, group-stacked ``[G, N, Kv, T, D]`` at 2;
    dense rings ``[B, n, Kv, D]`` at 2, stacked at 3); every other leaf
    — and any Kv dim the axis does not divide — replicates."""

    def __init__(self, mesh, *, paged: bool):
        self.mesh = mesh
        self.paged = paged
        self.model_size = int(mesh.shape["model"])

    def leaf_spec(self, path, leaf) -> P:
        if _leaf_key(path) not in ("k", "v") or leaf.ndim not in (4, 5):
            return P()
        if self.paged:
            ax = 1 if leaf.ndim == 4 else 2
        else:
            ax = 2 if leaf.ndim == 4 else 3
        if leaf.shape[ax] % self.model_size:
            return P()
        parts = [None] * leaf.ndim
        parts[ax] = "model"
        return P(*parts)

    def place(self, caches):
        """Eagerly pin every leaf (host-side ``device_put``): initial
        placement and the re-pin after eager store resizes."""
        return jax.tree_util.tree_map_with_path(
            lambda p, a: jax.device_put(
                a, NamedSharding(self.mesh, self.leaf_spec(p, a))), caches)

    def constrain(self, caches):
        """In-graph constraint for the cache outputs of the jitted steps:
        donation must hand buffers back in the SAME placement."""
        return jax.tree_util.tree_map_with_path(
            lambda p, a: jax.lax.with_sharding_constraint(
                a, NamedSharding(self.mesh, self.leaf_spec(p, a))), caches)

    def replicate(self, x):
        """In-graph fully-replicated pin (token rings and other small
        device state whose placement should not drift across ticks)."""
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P()))

    def shard_bytes(self, caches) -> int:
        """Per-device bytes of the cache tree under this plan.  For a paged
        store (K/V planes only) ``shard_bytes * model_size`` equals the
        single-device total exactly — the HBM gauge identity the mesh
        tests pin."""
        total = 0
        for path, a in jax.tree_util.tree_flatten_with_path(caches)[0]:
            spec = self.leaf_spec(path, a)
            denom = self.model_size if "model" in tuple(spec) else 1
            total += int(a.size) * a.dtype.itemsize // denom
        return total
