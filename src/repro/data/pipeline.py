"""Synthetic LM data pipeline with SmartConf-controlled prefetch.

Deterministic PRNG token stream, host-sharded; a background producer thread
fills a bounded prefetch queue.  The queue depth (``data.prefetch_depth``) is
an *indirect, hard* PerfConf (deputy = buffered batches; metric = host RSS
bytes), the CA6059 analogue in this framework: deeper prefetch absorbs
producer jitter (straggling input shards) at the cost of host memory.

Straggler mitigation: a per-batch production deadline; if the producer
misses it, a synthetic *backup batch* is substituted (duplicate-of-last
semantics, standard backup-task trick) and the event is counted.
"""

from __future__ import annotations

import queue
import threading
import time

import numpy as np

from repro.core.sensors import HBMAccountant, QueueGauge

__all__ = ["SyntheticTokens", "PrefetchPipeline"]


class SyntheticTokens:
    """Deterministic, restartable token source (host-sharded)."""

    def __init__(self, vocab_size: int, batch_size: int, seq_len: int, *,
                 host_id: int = 0, num_hosts: int = 1, seed: int = 0) -> None:
        assert batch_size % num_hosts == 0
        self.vocab_size = vocab_size
        self.batch_size = batch_size
        self.local_batch = batch_size // num_hosts
        self.seq_len = seq_len
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.seed = seed
        self.step = 0

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
        self.seed = int(state["seed"])

    def next_batch(self) -> dict:
        # per-(step, host) independent stream => restart-exact and elastic
        rng = np.random.default_rng(
            (self.seed, self.step, self.host_id))
        tokens = rng.integers(0, self.vocab_size,
                              (self.local_batch, self.seq_len + 1),
                              dtype=np.int32)
        self.step += 1
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def batch_nbytes(self) -> int:
        return self.local_batch * (self.seq_len + 1) * 4 * 2


class PrefetchPipeline:
    """Bounded background prefetch over any ``next_batch`` source."""

    def __init__(self, source, *, depth: int = 2,
                 accountant: HBMAccountant | None = None,
                 produce_deadline_s: float | None = None,
                 delay_fn=None) -> None:
        self.source = source
        self._depth = max(1, int(depth))
        self._queue: queue.Queue = queue.Queue(maxsize=self._depth)
        self.gauge = QueueGauge()
        self.accountant = accountant
        self.produce_deadline_s = produce_deadline_s
        self.delay_fn = delay_fn          # test hook: simulate slow shards
        self.backup_batches = 0           # straggler substitutions
        self._last = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # -- SmartConf actuation -------------------------------------------------
    def set_depth(self, depth: int) -> None:
        """Adjust the prefetch bound at runtime.  Shrinking does not drop
        already-buffered batches (temporary deputy>conf inconsistency is
        tolerated, exactly the paper's §4.2 guidance)."""
        self._depth = max(1, int(depth))

    @property
    def depth(self) -> int:
        return self._depth

    def buffered(self) -> int:
        return self.gauge.items

    def buffered_bytes(self) -> int:
        return self.gauge.nbytes

    # -- producer ------------------------------------------------------------
    def _producer(self) -> None:
        while not self._stop.is_set():
            if self.gauge.items >= self._depth:
                time.sleep(0.001)
                continue
            t0 = time.monotonic()
            if self.delay_fn is not None:
                time.sleep(self.delay_fn())
            batch = self.source.next_batch()
            took = time.monotonic() - t0
            if (self.produce_deadline_s is not None
                    and took > self.produce_deadline_s
                    and self._last is not None):
                # straggling shard: ship the backup batch instead
                batch = self._last
                self.backup_batches += 1
            self._last = batch
            nbytes = sum(a.nbytes for a in batch.values())
            self.gauge.add(nbytes)
            if self.accountant is not None:
                self.accountant.charge("prefetch", nbytes)
            self._queue.put(batch)

    def get(self, timeout: float = 30.0) -> dict:
        batch = self._queue.get(timeout=timeout)
        nbytes = sum(a.nbytes for a in batch.values())
        self.gauge.remove(nbytes)
        if self.accountant is not None:
            self.accountant.credit("prefetch", nbytes)
        return batch

    def close(self) -> None:
        self._stop.set()
        try:
            while True:
                self._queue.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
