"""Quickstart: put one PerfConf under SmartConf control in ~20 lines.

A toy bounded queue feeds a fixed-rate worker; the queue cap trades
throughput (deeper queue = busier worker) against memory (items are 1MB).
SmartConf profiles the relationship, synthesizes the controller, and holds
memory at the user's goal through a workload shift — no hand tuning.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import GoalSpec, SmartConfIndirect, fit_model

rng = np.random.default_rng(0)

# ---- 1. profile: sweep the cap, record (queue depth, memory) samples ------
BASE_MB = 200.0
samples = []
for cap in (50, 100, 200, 400):
    q = 0.0
    for t in range(50):
        q = min(q + rng.poisson(30), cap)
        mem = BASE_MB + q * 1.0 + 28 * np.sin(t / 5) + rng.normal(0, 12)
        samples.append((q, mem))
        q = max(0.0, q - 25)

by_bin = {}
for dep, mem in samples:
    by_bin.setdefault(round(dep / 25) * 25, []).append(mem)
model = fit_model(sorted(by_bin), [by_bin[k] for k in sorted(by_bin)],
                  conf_min=0, conf_max=5000)
print(f"synthesized: alpha={model.alpha:.2f} MB/item, pole auto, "
      f"lambda={model.lam:.3f}")

# ---- 2. the user states a goal; the developer wires two calls -------------
sc = SmartConfIndirect("demo.max_queue", metric="memory_mb",
                       goal=GoalSpec(500.0, hard=True), initial=0.0,
                       model=model)

# ---- 3. run: the controller adapts the cap, even when items double in size
q, served, viol, cap = 0.0, 0, 0, 0.0
for t in range(300):
    # workload shift: item size ramps 1MB -> 2MB over ~30 ticks from t=150
    item_mb = 1.0 + min(max(t - 150, 0) / 30.0, 1.0)
    q = min(q + rng.poisson(30), max(cap, 0))  # admission at the current cap
    mem = BASE_MB + q * item_mb + rng.normal(0, 3)   # peak memory this tick
    viol += mem > 500.0
    sc.set_perf(mem, q)                        # paper: setPerf(actual, deputy)
    cap = sc.get_conf()                        # paper: getConf()
    take = min(q, 25)
    q -= take
    served += take
    if t % 60 == 0:
        print(f"t={t:3d} item={item_mb:.2f}MB cap={cap:4.0f} queue={q:4.0f} "
              f"mem={mem:5.0f}MB (goal 500)")

print(f"\nserved={served} violations={viol} "
      f"(virtual goal was {sc.controller.virtual_goal:.0f}MB)")
assert viol == 0
