"""Serving driver: continuous batching with SmartConf-governed admission.

A burst of requests hits a small LM behind the engine; the interacting
``max_queue_tokens`` / ``kv_block_budget`` controllers keep device memory
under the hard budget while maximizing batch occupancy (the paper's
HB3813/HB6728 scenario on a real model).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np
import jax

from repro.configs import get_config, reduced
from repro.models import zoo
from repro.serve import Request, ServeEngine

cfg = reduced(get_config("h2o-danube-3-4b"))
params, _ = zoo.init(cfg, jax.random.key(0))
weight_bytes = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                   for x in jax.tree.leaves(params))
budget = weight_bytes + 2_000_000
print(f"model: {cfg.name}  weights {weight_bytes/1e6:.1f}MB  "
      f"HBM budget {budget/1e6:.1f}MB")

eng = ServeEngine(cfg, params, max_batch=4, cache_len=128,
                  hbm_budget_bytes=budget, block_tokens=16)

rng = np.random.default_rng(0)
for i in range(16):
    prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(8, 48)))
    eng.submit(Request(i, prompt.astype(np.int32), max_new_tokens=16))

tick = 0
while len(eng.finished) < 16 and tick < 400:
    stats = eng.tick()
    tick += 1
    if tick % 20 == 0:
        print(f"tick {tick:3d}  queued={stats['queued']:2d} "
              f"running={stats['running']} finished={stats['finished']:2d} "
              f"hbm={stats['hbm']/1e6:6.1f}MB "
              f"queue_cap={eng.max_queue_tokens} "
              f"kv_budget={eng.pool.max_blocks}")

print(f"\nfinished {len(eng.finished)}/16 in {tick} ticks; "
      f"HBM violations: {eng.accountant.violations}; "
      f"peak {eng.accountant.peak_bytes/1e6:.1f}MB of {budget/1e6:.1f}MB")
print(f"mean TTFT {eng.ttft.mean()*1e3:.1f}ms; "
      f"decode p99 {eng.decode_latency.p99()*1e3:.1f}ms")
print(f"prefill[{eng.prefill_impl}]: {eng.prefill_calls} calls, "
      f"{eng.prefill_compiles} compiled programs for "
      f"{len({len(r.prompt) for r in eng.finished})} distinct prompt lengths")
assert eng.accountant.violations == 0
eng.close()
