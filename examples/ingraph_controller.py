"""Beyond-paper demo: the SmartConf controller INSIDE a compiled decode loop.

The host-side controller (paper §4) runs between engine ticks; knobs that
must react token-by-token (here: a decode token *budget* that throttles a
speculative branch when measured step cost rises) need the controller in the
jitted program itself.  `repro.core.jax_controller` is that twin: pytree
state, branchless two-pole logic, scan/vmap/shard_map compatible.

The toy plant: per-token "HBM pressure" grows with the token budget; a hard
goal caps it.  The whole control loop — sensor, Eq. 2, two-pole switch,
actuation — runs inside one lax.scan, no host round-trips.

Run:  PYTHONPATH=src python examples/ingraph_controller.py
"""

import jax
import jax.numpy as jnp

from repro.core import ControllerModel, GoalSpec
from repro.core import jax_controller as jc

GOAL = 1000.0  # MB

model = ControllerModel(alpha=8.0, delta=1.4, lam=0.07,
                        conf_min=1.0, conf_max=128.0, integer=False)
spec = jc.make_spec(model, GoalSpec(GOAL, hard=True))
state = jc.init_state(8.0)


@jax.jit
def decode_trace(state, steps=300):
    def body(carry, t):
        st, base = carry
        # plant: pressure = base(t) + alpha * budget, with a mid-run shift
        base = jnp.where(t == 150, base + 300.0, base)
        budget = st.conf
        pressure = base + 8.0 * budget + 20.0 * jnp.sin(t / 7.0)
        st, new_budget = jc.controller_step(spec, st, pressure)
        return (st, base), (pressure, budget)

    (_, _), (pressure, budget) = jax.lax.scan(body, (state, 300.0),
                                              jnp.arange(steps))
    return pressure, budget


pressure, budget = decode_trace(state)
viol = int(jnp.sum(pressure > GOAL))
print(f"in-graph controller over 300 compiled steps: "
      f"violations={viol}, budget {float(budget[0]):.0f} -> "
      f"{float(budget[140]):.0f} (pre-shift) -> {float(budget[-1]):.0f} "
      f"(post-shift), pressure settles at {float(pressure[-20:].mean()):.0f} "
      f"(virtual goal {float(spec.virtual_goal):.0f}, hard goal {GOAL:.0f})")
assert viol <= 2  # transient at the t=150 step disturbance only
