"""Paper §6.5 / Fig. 8 demo: two PerfConfs, one hard memory constraint.

A request queue (1MB items) and a response queue (1.8MB items) share a
495MB budget.  Writes dominate first; reads join at t=50.  Both controllers
are marked super-hard on the same metric, so SmartConf splits the error
(N = 2) and rebalances the caps as the mix shifts — memory never crosses
the red line.

Run:  PYTHONPATH=src python examples/interacting_queues.py
"""

import sys

sys.path.insert(0, "benchmarks")

from benchmarks.bench_interacting import GOAL, TwoQueueEnv, _profile_alpha  # noqa: E402
import dataclasses  # noqa: E402

import numpy as np  # noqa: E402

from repro.core import simenv as se  # noqa: E402
from repro.core.smartconf import ConfRegistry, SmartConfIndirect  # noqa: E402

registry = ConfRegistry()
m1 = dataclasses.replace(_profile_alpha(1.0), lam=0.06)
m2 = dataclasses.replace(_profile_alpha(1.8), lam=0.06)
sc1 = SmartConfIndirect("req_queue.max", metric="mem", goal=GOAL,
                        initial=0.0, model=m1, registry=registry)
sc2 = SmartConfIndirect("resp_queue.max", metric="mem", goal=GOAL,
                        initial=0.0, model=m2, registry=registry)
print(f"controllers on 'mem': N = {sc1.controller.n_interacting} "
      f"(super-hard => error split)")

env = TwoQueueEnv()
viol, served, trace = env.run(
    [se.SmartConfPolicy(sc1, True), se.SmartConfPolicy(sc2, True)], seed=1)

for t in (10, 40, 60, 100, 200, 399):
    print(f"t={t:3d}  mem={trace['mem'][t]:5.0f}/495MB  "
          f"cap_req={trace['c1'][t]:5.0f} cap_resp={trace['c2'][t]:5.0f}  "
          f"q_req={trace['q1'][t]:4.0f} q_resp={trace['q2'][t]:4.0f}")

print(f"\nviolations: {viol} (hard goal held), served: {served:.0f}")
assert viol == 0
