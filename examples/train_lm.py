"""End-to-end training driver: a ~100M-param LM with the full substrate —
synthetic data pipeline (SmartConf-managed prefetch), AdamW, checkpointing
with controller-tuned interval, preemption-safe restart.

Default invocation is CI-sized; ``--preset 100m --steps 300`` is the real
driver (a ~100M model for a few hundred steps; expect TPU/beefy-CPU time).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps N] [--preset 100m]
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.configs.base import reduced
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def build_cfg(preset: str):
    base = get_config("yi-6b")           # llama-family backbone
    if preset == "100m":
        return dataclasses.replace(
            base, name="lm-100m", num_layers=12, d_model=512, num_heads=8,
            num_kv_heads=8, head_dim=64, d_ff=2048, vocab_size=32000,
            dtype="float32")             # ~92M params
    return reduced(base)                 # CI-sized


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--workdir", default="/tmp/repro_train_lm")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    print(f"model: {cfg.name}  ~{cfg.param_count()/1e6:.1f}M params")
    tc = TrainerConfig(workdir=args.workdir, total_steps=args.steps,
                       ckpt_interval=max(args.steps // 4, 1),
                       batch_size=args.batch, seq_len=args.seq)
    opt = adamw.AdamWConfig(lr=3e-4, warmup_steps=max(args.steps // 10, 1),
                            total_steps=args.steps)
    tr = Trainer(cfg, opt, tc)
    if tr.step:
        print(f"resumed from checkpoint at step {tr.step}")
    log = tr.run()
    for m in log[:: max(len(log) // 10, 1)]:
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}  lr {m['lr']:.2e}  "
              f"gnorm {m['grad_norm']:.2f}")
    print(f"final loss: {log[-1]['loss']:.4f} (from {log[0]['loss']:.4f})")
    print(f"prefetch depth now: {tr.pipeline.depth}; "
          f"ckpt interval now: {tr.ckpt.interval_steps}")
    tr.close()


if __name__ == "__main__":
    main()
